"""Per-architecture smoke tests (reduced configs, CPU).

* one forward/train step: output shapes + no NaNs (assignment requirement)
* decode consistency: prefill(s[:k]) + step-by-step decode reproduces the
  teacher-forced forward logits — exercises every cache type (GQA kv, sliding
  window, MLA latent, SSD state+conv, cross static kv).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.configs.base import SHAPES, input_specs
from repro.models import (
    decode_step, forward, loss_fn, model_params, prefill, split_periods
)

jax.config.update("jax_default_matmul_precision", "highest")


def _batch(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 4)
    batch = {"labels": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    tokens = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    if cfg.frontend == "embeds":
        batch["embeds"] = jnp.take(
            model_params(ks[2], cfg)["embed"], tokens, axis=0) * 0.0 + \
            jax.random.normal(ks[3], (B, S, cfg.d_model)) * 0.05
    else:
        batch["tokens"] = tokens
    if cfg.frontend == "tokens+vision":
        batch["vision_embeds"] = jax.random.normal(
            ks[3], (B, cfg.n_image_tokens, cfg.d_vision)
        ) * 0.05
    return batch, tokens


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    params = model_params(jax.random.PRNGKey(0), cfg)
    batch, _ = _batch(cfg, jax.random.PRNGKey(1))
    logits = forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = reduced_config(arch)
    if cfg.frontend == "embeds":
        # audio decode embeds code ids through the vocab table; build the
        # teacher-forced reference the same way (tokens path).
        cfg = dataclasses.replace(cfg, frontend="tokens")
    params = model_params(jax.random.PRNGKey(0), cfg)
    B, S, k = 2, 24, 16
    batch, tokens = _batch(cfg, jax.random.PRNGKey(1), B=B, S=S)
    full_logits = forward(params, cfg, batch)        # (B,S,V)

    pre_batch = {kk: (v[:, :k] if v.ndim > 1 and v.shape[1] == S else v)
                 for kk, v in batch.items() if kk != "labels"}
    logits_k, cache = prefill(params, cfg, pre_batch, S_max=S)
    np.testing.assert_allclose(
        np.asarray(logits_k), np.asarray(full_logits[:, k - 1]), rtol=2e-3, atol=2e-3
    )
    # decode the rest token by token
    for t in range(k, S):
        step_logits, cache = decode_step(params, cfg, cache, {"token": tokens[:, t]})
        np.testing.assert_allclose(
            np.asarray(step_logits),
            np.asarray(full_logits[:, t]),
            rtol=5e-3,
            atol=5e-3,
            err_msg=f"{arch}: decode step {t} diverged from forward",
        )


def test_split_periods_structures():
    cases = {
        "gemma3-1b": (6, 4, 2),
        "jamba-1.5-large-398b": (8, 9, 0),
        "llama-3.2-vision-90b": (5, 20, 0),
        "qwen2-72b": (1, 80, 0),
        "mamba2-370m": (1, 48, 0),
    }
    for arch, (p, k, t) in cases.items():
        cfg = get_config(arch)
        period, n_per, tail = split_periods(cfg.layer_pattern)
        assert (len(period), n_per, len(tail)) == (p, k, t), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (non-reduced) configs carry the exact assigned hyperparams."""
    spec = {
        "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, vocab=49155, n_experts=40,
                                     top_k=8),
        "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                                n_kv_heads=8, vocab=163840, n_experts=384,
                                top_k=8),
        "gemma3-1b": dict(n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
                          d_ff=6912, vocab=262144),
        "qwen2-72b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                          d_ff=29568, vocab=152064, qkv_bias=True),
        "minicpm3-4b": dict(n_layers=62, d_model=2560, n_heads=40,
                            d_ff=6400, vocab=73448, use_mla=True),
        "gemma3-4b": dict(n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
                          d_ff=10240, vocab=262144),
        "mamba2-370m": dict(n_layers=48, d_model=1024, d_ff=0, vocab=50280,
                            ssm_state=128),
        "llama-3.2-vision-90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=28672, vocab=128256),
        "musicgen-large": dict(n_layers=48, d_model=2048, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab=2048),
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                     n_kv_heads=8, vocab=65536, n_experts=16,
                                     top_k=2),
    }[arch]
    cfg = get_config(arch)
    for kk, vv in spec.items():
        assert getattr(cfg, kk) == vv, (arch, kk, getattr(cfg, kk), vv)


def test_param_counts_plausible():
    """6*N*D sanity: param counts land near the archs' nameplate sizes."""
    expect = {
        "kimi-k2-1t-a32b": (0.9e12, 1.3e12),
        "qwen2-72b": (6.5e10, 8.2e10),
        "jamba-1.5-large-398b": (3.2e11, 4.6e11),
        "mamba2-370m": (2.5e8, 5.5e8),
        "granite-moe-3b-a800m": (2.5e9, 4.0e9),
        "gemma3-1b": (0.7e9, 1.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, f"{n:.3e}")
    # active << total for MoE (granite 0.96B/3.4B, kimi 31B/1.04T)
    for arch, ratio in (("kimi-k2-1t-a32b", 0.05), ("granite-moe-3b-a800m", 0.35)):
        cfg = get_config(arch)
        assert cfg.param_count(active_only=True) < ratio * cfg.param_count()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_runnable_shapes(arch):
    cfg = get_config(arch)
    for shape in cfg.runnable_shapes():
        specs = input_specs(cfg, shape)
        cell = SHAPES[shape]
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)
            assert v.shape[0] == cell.global_batch
    if cfg.family in ("ssm", "hybrid") or "gemma3" in arch:
        assert "long_500k" in cfg.runnable_shapes()
    else:
        assert "long_500k" in cfg.skip_shapes
