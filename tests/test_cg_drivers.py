"""The unified CG core: scanned/host driver parity + diagnostics.

Pins two contracts the lam-path refactor leaned on:

* ``conjugate_gradient`` (lax.scan, static shape, masked no-ops) and
  ``conjugate_gradient_host`` (python loop, may stop early) are shells over
  ONE shared core — same initialization, same masked update, same residual
  bookkeeping — so tol-driven early stopping agrees between them, and the
  host driver's early ``break`` TRUNCATES ``residual_norms`` to
  ``iterations + 1`` entries (the out-of-core solve's documented shape).
* ``falkon_solve``'s power-iteration ``cond_estimate`` tracks the true
  condition number of the preconditioned operator W (the Thm 2 diagnostic).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import synthetic_regression
from repro.core import (
    FalkonConfig,
    conjugate_gradient,
    conjugate_gradient_host,
    falkon_solve,
    make_preconditioner,
    uniform_centers,
)
from repro.core.falkon import _falkon_operator
from repro.ops import get_ops


def _spd(q, seed=0, shift=None):
    A0 = jax.random.normal(jax.random.PRNGKey(seed), (q, q))
    A = A0 @ A0.T + (shift if shift is not None else q) * jnp.eye(q)
    return A


def test_host_matches_scanned_full_run():
    """tol=0: the host driver runs all t iterations and the two drivers'
    iterates/residual histories coincide (same shared update, loop style is
    the only difference)."""
    A = _spd(32)
    b = jax.random.normal(jax.random.PRNGKey(1), (32,))
    mv = lambda v: A @ v
    scan = conjugate_gradient(mv, b, t=25)
    host = conjugate_gradient_host(mv, b, t=25)
    assert host.residual_norms.shape == scan.residual_norms.shape == (26,)
    np.testing.assert_allclose(
        np.asarray(host.x), np.asarray(scan.x), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(host.residual_norms),
        np.asarray(scan.residual_norms),
        rtol=1e-4,
        atol=1e-7,
    )


def test_host_tol_early_stop_truncates_residual_norms():
    """THE pinned contract: the host driver skips remaining data passes once
    every column converges, so residual_norms has iterations+1 entries —
    not the scanned driver's full t+1."""
    A = _spd(20)
    b = jax.random.normal(jax.random.PRNGKey(2), (20,))
    mv = lambda v: A @ v
    t = 200
    host = conjugate_gradient_host(mv, b, t=t, tol=1e-5)
    it = int(host.iterations)
    assert 0 < it < t, "tolerance should stop the loop early"
    assert host.residual_norms.shape == (it + 1,)
    np.testing.assert_allclose(
        np.asarray(host.x), np.asarray(jnp.linalg.solve(A, b)), rtol=1e-3, atol=1e-4
    )


def test_host_scanned_tol_parity():
    """Same tol, same system: both drivers apply the same number of real
    updates and agree on the solution; the scanned history's extra entries
    are frozen at the converged value (masked no-ops)."""
    A = _spd(20)
    b = jax.random.normal(jax.random.PRNGKey(2), (20,))
    mv = lambda v: A @ v
    t = 200
    scan = conjugate_gradient(mv, b, t=t, tol=1e-5)
    host = conjugate_gradient_host(mv, b, t=t, tol=1e-5)
    it_h, it_s = int(host.iterations), int(scan.iterations)
    # compiled-vs-eager arithmetic may flip the knife-edge iteration
    assert abs(it_h - it_s) <= 1
    assert scan.residual_norms.shape == (t + 1,)
    k = min(it_h, it_s)
    np.testing.assert_allclose(
        np.asarray(host.residual_norms[: k + 1]),
        np.asarray(scan.residual_norms[: k + 1]),
        rtol=1e-3,
        atol=1e-7,
    )
    np.testing.assert_allclose(
        np.asarray(host.x), np.asarray(scan.x), rtol=1e-4, atol=1e-5
    )
    # the scanned tail is frozen once everything converged
    tail = np.asarray(scan.residual_norms[it_s:])
    np.testing.assert_array_equal(tail, np.full_like(tail, tail[0]))


def test_host_multirhs_stops_when_all_columns_converge():
    A = _spd(24)
    # very different column scales => different per-column convergence times
    B = jax.random.normal(jax.random.PRNGKey(3), (24, 3)) * jnp.array([1.0, 1e-3, 10.0])
    mv = lambda v: A @ v
    host = conjugate_gradient_host(mv, B, t=300, tol=1e-5)
    it = int(host.iterations)
    assert 0 < it < 300
    assert host.residual_norms.shape == (it + 1, 3)
    sol = jnp.linalg.solve(A, B)
    np.testing.assert_allclose(
        np.asarray(host.x), np.asarray(sol), rtol=1e-3, atol=1e-4
    )


# ---------------------------------------------------------------------------
# estimate_cond: the power-iteration diagnostic
# ---------------------------------------------------------------------------
def _tiny_falkon(lam=1e-3, n=300, M=48):
    X, y = synthetic_regression(jax.random.PRNGKey(0), n)
    cfg = FalkonConfig(
        kernel_params=(("sigma", 1.5),),
        lam=lam,
        num_centers=M,
        iterations=5,
        block_size=128,
    )
    kern = cfg.make_kernel()
    sel = uniform_centers(jax.random.PRNGKey(1), X, M)
    ops = get_ops("jnp", kern, block_size=128)
    KMM = ops.gram(sel.centers, sel.centers)
    pre = make_preconditioner(KMM, lam, n)
    return X, y, sel.centers, pre, kern, cfg, ops


def test_estimate_cond_tracks_true_condition_number():
    X, y, centers, pre, kern, cfg, ops = _tiny_falkon()
    state = falkon_solve(
        X, y, centers, pre, kern, cfg.lam, 5, ops=ops, estimate_cond=True
    )
    est = float(state.cond_estimate)

    # densify W = B^T H B by applying the operator to the identity
    mv = lambda g: ops.sweep(X, centers, g, None)
    W = _falkon_operator(mv, pre, cfg.lam, X.shape[0])
    Wmat = W(jnp.eye(pre.q, dtype=X.dtype))
    eig = jnp.linalg.eigvalsh(0.5 * (Wmat + Wmat.T))
    true_cond = float(eig[-1] / eig[0])

    assert est >= 1.0
    # 12 power iterations on a preconditioned (tightly clustered) spectrum:
    # order-of-magnitude agreement is the diagnostic's contract
    assert true_cond / 3.0 <= est <= true_cond * 3.0, (est, true_cond)


def test_estimate_cond_flag_off_returns_zero_and_saves_sweeps():
    from repro.ops import CountingOps
    X, y, centers, pre, kern, cfg, ops = _tiny_falkon()
    c_on = CountingOps(ops)
    on = falkon_solve(
        X, y, centers, pre, kern, cfg.lam, 5, ops=c_on, estimate_cond=True
    )
    c_off = CountingOps(ops)
    off = falkon_solve(
        X, y, centers, pre, kern, cfg.lam, 5, ops=c_off, estimate_cond=False
    )
    assert float(off.cond_estimate) == 0.0
    assert float(on.cond_estimate) > 0.0
    assert c_off.sweeps < c_on.sweeps  # the diagnostic costs extra sweeps
    np.testing.assert_array_equal(np.asarray(on.alpha), np.asarray(off.alpha))


def test_config_estimate_cond_threads_through_fit():
    from repro.core import falkon_fit
    X, y = synthetic_regression(jax.random.PRNGKey(0), 200)
    cfg = FalkonConfig(num_centers=32, iterations=3, block_size=64, estimate_cond=False)
    _, state = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
    assert float(state.cond_estimate) == 0.0


@pytest.mark.parametrize("storage", [None, "bfloat16"])
def test_host_scanned_storage_contract(storage):
    """The reduced-storage iterate contract reaches both drivers via the
    shared core (loose tolerance: eager-vs-compiled rounding differs at
    bf16 ulps)."""
    A = _spd(16, shift=16.0)
    b = jax.random.normal(jax.random.PRNGKey(4), (16,))
    mv = lambda v: A @ v.astype(jnp.float32)
    scan = conjugate_gradient(mv, b, t=30, storage_dtype=storage)
    host = conjugate_gradient_host(mv, b, t=30, storage_dtype=storage)
    want = jnp.dtype(storage) if storage else b.dtype
    assert scan.x.dtype == host.x.dtype == want
    tol = 5e-2 if storage else 1e-5
    np.testing.assert_allclose(
        np.asarray(host.x, np.float32),
        np.asarray(scan.x, np.float32),
        rtol=tol,
        atol=tol,
    )
