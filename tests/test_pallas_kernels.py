"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle.

Kernels run in interpret mode on CPU (Python emulation of the kernel body);
the BlockSpec tiling/padding/masking logic is fully exercised.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FalkonConfig, GaussianKernel, falkon_fit
from repro.kernels.kernel_matvec import (kernel_matmul_pallas, pairwise_kernel_pallas)
from repro.kernels.ops import fused_knm_matvec
from repro.kernels.ref import (
    fused_knm_matvec_ref, kernel_matmul_ref, pairwise_kernel_ref
)

SHAPES = [
    # (m, n, d, p) — ragged, tile-aligned, sub-tile, prime-ish
    (64, 64, 8, 1),
    (256, 512, 128, 4),
    (300, 257, 33, 3),
    (17, 900, 5, 1),
    (513, 129, 130, 2),
]
KINDS = ["gaussian", "laplacian", "matern32"]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("kind", KINDS)
def test_kernel_matmul_matches_oracle(shape, kind):
    m, n, d, p = shape
    k = jax.random.PRNGKey(hash((shape, kind)) % 2**31)
    k1, k2, k3 = jax.random.split(k, 3)
    A = jax.random.normal(k1, (m, d))
    B = jax.random.normal(k2, (n, d))
    V = jax.random.normal(k3, (n, p))
    got = kernel_matmul_pallas(A, B, V, kind=kind, scale=1.4, interpret=True)
    ref = kernel_matmul_ref(A, B, V, kind, 1.4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matmul_dtypes(dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    A = jax.random.normal(k1, (200, 16)).astype(dtype)
    B = jax.random.normal(k2, (150, 16)).astype(dtype)
    V = jax.random.normal(k3, (150, 2)).astype(dtype)
    got = kernel_matmul_pallas(A, B, V, kind="gaussian", scale=1.0, interpret=True)
    ref = kernel_matmul_ref(
        A.astype(jnp.float32),
        B.astype(jnp.float32),
        V.astype(jnp.float32),
        "gaussian",
        1.0,
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref), **_tol(dtype)
    )


@pytest.mark.parametrize("block", [(32, 64), (128, 128), (256, 512)])
def test_kernel_matmul_block_invariance(block):
    bm, bn = block
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    A = jax.random.normal(k1, (300, 20))
    B = jax.random.normal(k2, (411, 20))
    V = jax.random.normal(k3, (411, 3))
    got = kernel_matmul_pallas(
        A, B, V, kind="gaussian", scale=2.0, block_m=bm, block_n=bn, interpret=True
    )
    ref = kernel_matmul_ref(A, B, V, "gaussian", 2.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("kind", KINDS)
def test_pairwise_kernel_matches_oracle(shape, kind):
    m, n, d, _ = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    A = jax.random.normal(k1, (m, d))
    B = jax.random.normal(k2, (n, d))
    got = pairwise_kernel_pallas(A, B, kind=kind, scale=1.1, interpret=True)
    ref = pairwise_kernel_ref(A, B, kind, 1.1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=5e-5, atol=5e-5)


def test_fused_sweep_matches_oracle_vector_and_matrix_rhs():
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(3), 4)
    X = jax.random.normal(k1, (513, 21))
    C = jax.random.normal(k2, (97, 21))
    kern = GaussianKernel(sigma=1.3)
    u1 = jax.random.normal(k3, (97,))
    v1 = jax.random.normal(k4, (513,))
    got = fused_knm_matvec(X, C, u1, v1, kern)
    ref = fused_knm_matvec_ref(X, C, u1, v1, "gaussian", 1.3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=5e-4, atol=5e-4)
    u2 = jax.random.normal(k3, (97, 5))
    v2 = jax.random.normal(k4, (513, 5))
    got2 = fused_knm_matvec(X, C, u2, v2, kern)
    ref2 = fused_knm_matvec_ref(X, C, u2, v2, "gaussian", 1.3)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(ref2), rtol=5e-4, atol=5e-4)
    # v = None path
    got3 = fused_knm_matvec(X, C, u1, None, kern)
    ref3 = fused_knm_matvec_ref(X, C, u1, None, "gaussian", 1.3)
    np.testing.assert_allclose(np.asarray(got3), np.asarray(ref3), rtol=5e-4, atol=5e-4)


def test_falkon_end_to_end_with_pallas_matvec(rng):
    """FalkonConfig(matvec_impl='pallas') produces the same estimator as the
    jnp path — the kernel is a true drop-in for the hot loop."""
    from conftest import synthetic_regression
    X, y = synthetic_regression(rng, 640)
    base = dict(
        kernel="gaussian",
        kernel_params=(("sigma", 2.0),),
        lam=1e-4,
        num_centers=96,
        iterations=50,
        block_size=128,
    )
    est_j, _ = falkon_fit(
        jax.random.PRNGKey(1), X, y, FalkonConfig(**base, matvec_impl="jnp")
    )
    est_p, _ = falkon_fit(
        jax.random.PRNGKey(1), X, y, FalkonConfig(**base, matvec_impl="pallas")
    )
    p_j, p_p = est_j.predict(X), est_p.predict(X)
    rel = float(jnp.linalg.norm(p_p - p_j) / jnp.linalg.norm(p_j))
    assert rel < 2e-3, rel


def test_kernel_matmul_under_jit_and_grad_safety():
    """The wrapper jits cleanly (dry-run requirement)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    A = jax.random.normal(k1, (64, 12))
    B = jax.random.normal(k2, (80, 12))
    V = jax.random.normal(k3, (80, 1))
    f = jax.jit(
        lambda a,
        b,
        v: kernel_matmul_pallas(a, b, v, kind="gaussian", scale=1.0, interpret=True),
    )
    got = f(A, B, V)
    ref = kernel_matmul_ref(A, B, V, "gaussian", 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=5e-4, atol=5e-4)
