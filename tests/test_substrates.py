"""Substrate tests: optimizers, schedules, checkpointing, compression,
data pipeline, trainer fault tolerance, sharding rules, HLO cost analyzer."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_step, load_checkpoint, save_checkpoint, step_dir)
from repro.data import ShardedLoader, TokenStreamConfig, token_stream
from repro.distributed.compression import (
    compressed_grads, dequantize_int8, init_residuals, quantize_int8
)
from repro.distributed.mesh import AxisRules
from repro.optim import (
    adafactor, adamw, clip_by_global_norm, global_norm, sgdm, warmup_cosine
)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------
def _quadratic_params(key):
    return {"w": jax.random.normal(key, (8, 4)), "b": jnp.ones((4,))}


@pytest.mark.parametrize("make_opt", [adamw, adafactor, sgdm])
def test_optimizers_reduce_quadratic(make_opt):
    opt = make_opt()
    params = _quadratic_params(jax.random.PRNGKey(0))
    target = jax.tree.map(lambda p: p * 0 + 0.5, params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, 0.05)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_sublinear():
    opt = adafactor()
    p = {"w": jnp.zeros((256, 512))}
    st = opt.init(p)
    n_state = sum(x.size for x in jax.tree.leaves(st))
    assert n_state < 256 * 512 / 50  # rows+cols << full matrix


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 30


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) < 2e-4
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-4
    assert float(lr(jnp.asarray(99))) < 3e-4


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------
def test_int8_quantization_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.51 + 1e-6  # half-ulp of the scale


def test_error_feedback_reduces_bias():
    """With error feedback, the *accumulated* compressed gradient converges to
    the accumulated true gradient (residual stays bounded)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,)) * 1e-3}
    res = init_residuals(g)
    total_true = jnp.zeros((64,))
    total_comp = jnp.zeros((64,))
    for i in range(50):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        comp, res = compressed_grads(gi, res)
        total_true += gi["w"]
        total_comp += comp["w"]
    drift = float(
        jnp.linalg.norm(total_comp - total_true) / jnp.linalg.norm(total_true)
    )
    assert drift < 0.05, drift


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_atomicity():
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16)},
    }
    with tempfile.TemporaryDirectory() as d:
        p = step_dir(d, 3)
        save_checkpoint(p, tree, 3, blocking=True)
        like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
        out, step = load_checkpoint(p, like)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        assert out["b"]["c"].dtype == jnp.bfloat16
        assert latest_step(d) == 3
        # shape mismatch must be caught loudly (not silently truncated)
        bad = {"a": jnp.zeros((4, 4)), "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
        with pytest.raises(ValueError):
            load_checkpoint(p, bad)


def test_checkpoint_async_then_restore():
    tree = {"w": jnp.full((16,), 7.0)}
    with tempfile.TemporaryDirectory() as d:
        t = save_checkpoint(step_dir(d, 1), tree, 1, blocking=False)
        t.join()
        out, _ = load_checkpoint(step_dir(d, 1), tree)
        assert float(out["w"][0]) == 7.0


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------
def test_token_stream_deterministic_and_restartable():
    cfg = TokenStreamConfig(vocab=64, seq_len=16, batch=2)
    a = [next(token_stream(cfg, seed=3)) for _ in range(1)][0]
    b = [next(token_stream(cfg, seed=3)) for _ in range(1)][0]
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(a["tokens"][:, 1:]), np.asarray(a["labels"][:,:-1])
    )


def test_sharded_loader_prefetch():
    cfg = TokenStreamConfig(vocab=16, seq_len=8, batch=2)

    def gen():
        it = token_stream(cfg, seed=0)
        for _ in range(5):
            yield next(it)

    loader = ShardedLoader(gen(), mesh=None, prefetch=2)
    batches = list(loader)
    assert len(batches) == 5
    assert batches[0]["tokens"].shape == (2, 8)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------
def test_axis_rules_divisibility_fallback():
    # no mesh available with >1 device here; use a fake mesh via spec logic
    import jax.sharding as shd
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = shd.Mesh(devs, ("data", "model"))
    rules = AxisRules(mesh=mesh)
    # every dim divides a size-1 axis: spec assigns named axes
    spec = rules.spec_for((8, 16, 64), ("batch", None, "heads"))
    assert spec[0] == ("data",) or spec[0] == "data"


def test_axis_rules_replicates_non_divisible():
    """Check against a simulated 16-way axis using the pure spec logic."""
    import jax.sharding as shd

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    rules = AxisRules(mesh=FakeMesh())
    # gemma3-1b: 4 heads on a 16-way model axis -> replicated; ff shards
    spec = rules.spec_for((1152, 4, 256), ("embed", "heads", None))
    assert len(spec) == 0 or all(s is None for s in spec)
    spec2 = rules.spec_for((1152, 6912), ("embed", "ff"))
    assert spec2[1] == "model" or spec2[1] == ("model",)
    # kv cache: batch/data + seq absorbs model when kv_heads can't shard
    spec3 = rules.spec_for((128, 32768, 8, 128), ("batch", "kv_seq", "kv_heads", None))
    flat = [s for s in spec3]
    assert any(s in ("model", ("model",)) for s in flat if s), spec3


# ---------------------------------------------------------------------------
# HLO cost analyzer
# ---------------------------------------------------------------------------
def test_hlo_cost_trip_count_scaling():
    from repro.roofline.hlo_cost import analyze
    M = 256

    def loop(a, b):
        def body(c, _):
            return c @ b, None
        out, _ = jax.lax.scan(body, a, None, length=7)
        return out

    a = jax.ShapeDtypeStruct((M, M), jnp.float32)
    b = jax.ShapeDtypeStruct((M, M), jnp.float32)
    compiled = jax.jit(loop).lower(a, b).compile()
    cost = analyze(compiled.as_text())
    assert abs(cost.flops / (7 * 2 * M**3) - 1.0) < 0.01
    assert cost.unbounded_whiles == 0
