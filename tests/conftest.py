import os
import sys

# Tests run on the single real CPU device (the 512-device override belongs to
# the dry-run ONLY — see src/repro/launch/dryrun.py). Distributed tests spawn
# subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def synthetic_regression(key, n, d=5, noise=0.05, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, d), dtype)
    w = jax.random.normal(k2, (d,), dtype)
    y = jnp.sin(X @ w) + noise * jax.random.normal(k3, (n,), dtype)
    return X, y
