import gc
import os
import sys

# Tests run on the single real CPU device (the 512-device override belongs to
# the dry-run ONLY — see src/repro/launch/dryrun.py). Distributed tests spawn
# subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402

# The CI precision matrix runs the tier-1 suite once per axis with
# REPRO_TEST_PRECISION in {fp32, bf16}. Cheap precision-policy unit tests
# always parametrize over both policies; the expensive cases (the M=32768
# acceptance sweep, CG-parity fits, streaming fits in tests/test_precision.py)
# follow this value so each CI axis exercises its own policy end-to-end.
TEST_PRECISION = os.environ.get("REPRO_TEST_PRECISION", "fp32")
assert TEST_PRECISION in ("fp32", "bf16"), TEST_PRECISION


@pytest.fixture(autouse=True, scope="module")
def _bound_compiled_executables():
    """Free XLA executables after every test module.

    Each compiled executable mmaps its own code pages and the CPU client
    never unmaps them while cached; over the full suite the accumulated
    compiles can exhaust the kernel's vm.max_map_count (default 65530),
    and the failed mmap surfaces as a segfault inside backend_compile on
    whichever unlucky test compiles next. Clearing per module bounds the
    peak map count at one module's worth of executables; the price is
    cross-module recompiles, which the suite can afford.
    """
    yield
    gc.collect()
    jax.clear_caches()


@pytest.fixture(scope="session")
def test_precision() -> str:
    """The precision axis this test process runs under (env-selected)."""
    return TEST_PRECISION


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def synthetic_regression(key, n, d=5, noise=0.05, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, d), dtype)
    w = jax.random.normal(k2, (d,), dtype)
    y = jnp.sin(X @ w) + noise * jax.random.normal(k3, (n,), dtype)
    return X, y
