"""KernelOps backend layer: jnp-vs-pallas parity and the fusion guarantee.

* sweep / apply / gram parity across all registered kernels, ragged
  (non-tile-multiple) shapes, 1-D and multi-output u, and v=None —
  tolerance <= 1e-4 on fp32 inputs.
* single-pass property: the fused Pallas sweep's tile-eval counter equals
  ceil(n/bm) * ceil(M/bn) — each Gram tile computed exactly once per sweep
  (the legacy two-matmul composition evaluates each tile twice).
* registry behavior: unknown impl/precision rejected; backend selection is
  purely spec-driven (no class-name sniffing left to fool).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FalkonConfig, GaussianKernel, falkon_fit, make_kernel, spec_of)
from repro.core.kernels import KernelSpec
from repro.kernels.kernel_matvec import fused_sweep_pallas, sweep_tile_grid
from repro.kernels.ops import two_pass_knm_matvec
from repro.ops import available_ops, get_ops

KERNELS = [
    ("gaussian", dict(sigma=1.3)),
    ("laplacian", dict(sigma=1.1)),
    ("matern32", dict(sigma=1.7)),
    ("linear", dict(scale=1.5)),
    ("polynomial", dict(degree=2, c=0.5, scale=2.0)),
]
# ragged / tile-aligned / sub-tile row counts
SHAPES = [(300, 97, 13), (256, 128, 8), (37, 200, 5), (513, 129, 33)]

TOL = dict(rtol=1e-4, atol=1e-4)


def _data(n, M, d, p=None, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    X = jax.random.normal(ks[0], (n, d))
    C = jax.random.normal(ks[1], (M, d))
    ush = (M,) if p is None else (M, p)
    vsh = (n,) if p is None else (n, p)
    return X, C, jax.random.normal(ks[2], ush), jax.random.normal(ks[3], vsh)


def test_registry_contents():
    assert set(available_ops()) >= {"jnp", "pallas"}
    with pytest.raises(ValueError, match="unknown KernelOps impl"):
        get_ops("cuda", GaussianKernel())
    with pytest.raises(ValueError, match="unknown precision"):
        get_ops("jnp", GaussianKernel(), precision="fp8")


def test_spec_driven_selection_no_name_sniffing():
    """Selection keys off the registered spec, not the class name."""
    assert spec_of(GaussianKernel(sigma=2.5)) == KernelSpec(
        "gaussian", (("sigma", 2.5),)
    )

    @dataclasses.dataclass(frozen=True)
    class GaussianLookalikeKernel:   # name would have fooled the old sniffing
        sigma: float = 1.0

    with pytest.raises(TypeError, match="KernelSpec"):
        get_ops("pallas", GaussianLookalikeKernel()).sweep(*_data(64, 32, 4)[:3], None)


@pytest.mark.parametrize("kernel_name,params", KERNELS)
@pytest.mark.parametrize("shape", SHAPES[:2])
def test_sweep_parity_all_kernels(kernel_name, params, shape):
    n, M, d = shape
    kern = make_kernel(kernel_name, **params)
    # deterministic seed (str hash is randomized per interpreter run)
    seed = [k for k, _ in KERNELS].index(kernel_name) * 10 + SHAPES.index(shape)
    X, C, u, v = _data(n, M, d, seed=seed)
    ref = get_ops("jnp", kern, block_size=64).sweep(X, C, u, v)
    got = get_ops("pallas", kern, block_size=128).sweep(X, C, u, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("p", [None, 3])
def test_sweep_parity_shapes_and_rhs(shape, p):
    n, M, d = shape
    kern = GaussianKernel(sigma=1.5)
    X, C, u, v = _data(n, M, d, p=p, seed=7)
    jops = get_ops("jnp", kern, block_size=100)   # ragged jnp blocks too
    pops = get_ops("pallas", kern, block_size=128)
    np.testing.assert_allclose(
        np.asarray(pops.sweep(X, C, u, v)), np.asarray(jops.sweep(X, C, u, v)), **TOL
    )
    # v=None path
    np.testing.assert_allclose(
        np.asarray(pops.sweep(X, C, u, None)),
        np.asarray(jops.sweep(X, C, u, None)),
        **TOL,
    )


@pytest.mark.parametrize("kernel_name,params", KERNELS)
def test_apply_and_gram_parity(kernel_name, params):
    n, M, d = 211, 77, 9
    kern = make_kernel(kernel_name, **params)
    X, C, u, _ = _data(n, M, d, seed=3)
    jops = get_ops("jnp", kern, block_size=64)
    pops = get_ops("pallas", kern, block_size=128)
    np.testing.assert_allclose(
        np.asarray(pops.apply(X, C, u)), np.asarray(jops.apply(X, C, u)), **TOL
    )
    np.testing.assert_allclose(
        np.asarray(pops.gram(X, C)), np.asarray(jops.gram(X, C)), **TOL
    )
    # multi-output apply
    U = jax.random.normal(jax.random.PRNGKey(9), (M, 4))
    np.testing.assert_allclose(
        np.asarray(pops.apply(X, C, U)), np.asarray(jops.apply(X, C, U)), **TOL
    )


def test_fused_sweep_single_pass_tile_count():
    """The fusion claim, measured: one Gram-tile evaluation per (i, j) tile
    per sweep — half of what the two-matmul composition performs."""
    n, M, d = 300, 97, 13
    kern = GaussianKernel(sigma=1.5)
    X, C, u, v = _data(n, M, d, seed=11)
    bm, bn = 64, 128
    w, count = fused_sweep_pallas(
        X,
        C,
        u,
        v,
        spec=spec_of(kern),
        block_m=bm,
        block_n=bn,
        interpret=True,
        return_tile_count=True,
    )
    nbi, nbj = sweep_tile_grid(n, M, bm, bn)
    assert int(count) == nbi * nbj, (int(count), nbi, nbj)
    # same answer as the two-pass composition, which costs 2x tile evals
    two = two_pass_knm_matvec(X, C, u, v, kern)
    np.testing.assert_allclose(np.asarray(w), np.asarray(two), **TOL)


def test_pallas_ops_sweep_with_stats_counts_once():
    n, M, d = 256, 128, 8
    kern = GaussianKernel(sigma=2.0)
    X, C, u, v = _data(n, M, d, seed=13)
    ops = get_ops("pallas", kern, block_size=128)
    w, count = ops.sweep_with_stats(X, C, u, v)
    nbi, nbj = sweep_tile_grid(n, M, 128, 512)
    assert int(count) == nbi * nbj
    np.testing.assert_allclose(
        np.asarray(w), np.asarray(get_ops("jnp", kern).sweep(X, C, u, v)), **TOL
    )


def test_bf16_precision_policy():
    """bf16 end-to-end data-space storage / compensated fp32 accumulation:
    the M-sized w comes back at the coefficient dtype (float32 by policy
    override — see PrecisionPolicy) and stays close to the fp32 reference."""
    n, M, d = 256, 96, 16
    kern = GaussianKernel(sigma=2.0)
    X, C, u, v = _data(n, M, d, seed=5)
    ref = get_ops("jnp", kern).sweep(X, C, u, v)
    got = get_ops("pallas", kern, precision="bf16").sweep(X, C, u, v)
    assert got.dtype == ref.dtype            # w at coeffs width (fp32)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel < 2e-2, rel


def test_falkon_config_ops_impl_and_deprecated_alias(rng):
    from conftest import synthetic_regression
    X, y = synthetic_regression(rng, 384)
    base = dict(
        kernel="gaussian",
        kernel_params=(("sigma", 2.0),),
        lam=1e-4,
        num_centers=64,
        iterations=25,
        block_size=128,
    )
    est_j, _ = falkon_fit(
        jax.random.PRNGKey(1), X, y, FalkonConfig(**base, ops_impl="jnp")
    )
    est_p, _ = falkon_fit(
        jax.random.PRNGKey(1), X, y, FalkonConfig(**base, ops_impl="pallas")
    )
    est_old, _ = falkon_fit(
        jax.random.PRNGKey(1), X, y, FalkonConfig(**base, matvec_impl="pallas")
    )
    p_j, p_p = est_j.predict(X), est_p.predict(X)
    rel = float(jnp.linalg.norm(p_p - p_j) / jnp.linalg.norm(p_j))
    assert rel < 2e-3, rel
    # deprecated alias routes to the same backend
    assert FalkonConfig(**base, matvec_impl="pallas").impl == "pallas"
    np.testing.assert_allclose(
        np.asarray(est_old.predict(X)), np.asarray(p_p), rtol=1e-5, atol=1e-5
    )
