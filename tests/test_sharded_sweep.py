"""Out-of-core (j-sharded) sweep: parity vs the jnp reference + the planner.

* ``sharded_sweep_pallas`` parity across all five registered kernels, ragged
  M not divisible by the shard size, multi-rhs u, and v=None — <= 1e-4 fp32
  against the jnp reference backend.
* The M >= 32k acceptance point: the pallas backend's ``sweep`` routed by
  the planner onto the j-sharded path (CPU-interpreted Pallas) matches the
  jnp reference to <= 1e-4 while the fused path's VMEM model says "no".
* ``plan_sweep`` / ``KernelOps.plan()``: fused-to-two-pass-to-j-sharded
  transitions driven by the VMEM budget model, shard sizing, budget
  overrides, and the structured ``SweepPlanWarning`` on fallback.
"""
import jax
import numpy as np
import pytest

from repro.core import make_kernel, spec_of
from repro.kernels.kernel_matvec import sharded_sweep_pallas, sweep_block_dims
from repro.ops import SweepPlanWarning, get_ops, plan_sweep

KERNELS = [
    ("gaussian", dict(sigma=1.3)),
    ("laplacian", dict(sigma=1.1)),
    ("matern32", dict(sigma=1.7)),
    ("linear", dict(scale=1.5)),
    ("polynomial", dict(degree=2, c=0.5, scale=2.0)),
]

TOL = dict(rtol=1e-4, atol=1e-4)


def _data(n, M, d, p=None, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    ush = (M,) if p is None else (M, p)
    vsh = (n,) if p is None else (n, p)
    return (
        jax.random.normal(ks[0], (n, d)),
        jax.random.normal(ks[1], (M, d)),
        jax.random.normal(ks[2], ush),
        jax.random.normal(ks[3], vsh),
    )


@pytest.mark.parametrize("kernel_name,params", KERNELS)
def test_sharded_parity_all_kernels_ragged_shards(kernel_name, params):
    """M=333 with shard_m=128: shards of 128/128/77 — ragged in both the
    shard count and the final shard's row count."""
    n, M, d = 200, 333, 13
    kern = make_kernel(kernel_name, **params)
    seed = [k for k, _ in KERNELS].index(kernel_name)
    X, C, u, v = _data(n, M, d, seed=seed)
    ref = get_ops("jnp", kern, block_size=64).sweep(X, C, u, v)
    got = sharded_sweep_pallas(X, C, u, v, spec=spec_of(kern), shard_m=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


@pytest.mark.parametrize("p", [None, 3])
@pytest.mark.parametrize("shard_m", [100, 512])
def test_sharded_parity_multirhs_and_vnone(p, shard_m):
    n, M, d = 150, 257, 9
    kern = make_kernel("gaussian", sigma=1.5)
    X, C, u, v = _data(n, M, d, p=p, seed=7)
    jops = get_ops("jnp", kern, block_size=64)
    for vv in (v, None):
        got = sharded_sweep_pallas(X, C, u, vv, spec=spec_of(kern), shard_m=shard_m)
        ref = jops.sweep(X, C, u, vv)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


def test_big_m_backend_routes_j_sharded_and_matches_reference():
    """The acceptance point: M = 32768 >= 32k on CPU-interpreted Pallas.

    The planner must refuse the fused path (its strip+accumulator is ~50MB
    against a 12MB budget), warn structurally, take the j-sharded path in
    more than one shard, and still match the jnp reference to <= 1e-4 fp32.
    """
    n, M, d, p = 256, 32768, 7, 2
    kern = make_kernel("gaussian", sigma=1.5)
    pops = get_ops("pallas", kern, block_size=128)

    plan = pops.plan(n, M, d, p)
    assert plan.path == "j_sharded"
    assert plan.shard_m is not None and plan.shard_m < M
    assert plan.total_bytes > plan.vmem_budget_bytes

    X, C, u, v = _data(n, M, d, p=p, seed=11)
    with pytest.warns(SweepPlanWarning) as rec:
        got = pops.sweep(X, C, u, v)
    assert rec[0].message.plan.path == "j_sharded"
    ref = get_ops("jnp", kern, block_size=4096).sweep(X, C, u, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


def test_planner_transitions_with_budget():
    """fused -> two_pass -> j_sharded as the budget shrinks, M fixed."""
    bm, bn = sweep_block_dims(4096, 2048, 256, 512)
    big = plan_sweep(4096, 2048, 32, 1, bm=bm, bn=bn, vmem_budget=64 * 2**20)
    assert big.path == "fused" and big.shard_m is None
    mid = plan_sweep(4096, 2048, 32, 1, bm=bm, bn=bn, vmem_budget=4 * 2**20)
    assert mid.path in ("two_pass", "j_sharded")
    tiny = plan_sweep(4096, 2048, 32, 1, bm=bm, bn=bn, vmem_budget=2**19)
    assert tiny.path == "j_sharded"
    assert tiny.shard_m is not None
    assert tiny.shard_m % bn == 0, "shards must stay tile-aligned"
    # the reason string carries the budget numbers (the structured part of
    # the fallback warning)
    assert str(tiny.vmem_budget_bytes) in tiny.reason


def test_planner_env_budget_override(monkeypatch):
    kern = make_kernel("gaussian", sigma=2.0)
    pops = get_ops("pallas", kern, block_size=2048)
    assert pops.plan(2048, 2048, 32, 1).path == "fused"
    monkeypatch.setenv("REPRO_VMEM_BUDGET_MB", "1")
    assert pops.plan(2048, 2048, 32, 1).path != "fused"


def test_jnp_backend_reports_plan_too():
    jops = get_ops("jnp", make_kernel("gaussian", sigma=2.0), block_size=512)
    plan = jops.plan(10_000, 4096, 32)
    assert plan.path == "jnp"
    assert "lax.scan" in plan.reason


def test_sweep_with_stats_rejects_out_of_core_shapes():
    """The tile counter only exists on the fused kernel; shapes the planner
    routes out-of-core must be rejected, not silently measured elsewhere."""
    kern = make_kernel("gaussian", sigma=1.5)
    pops = get_ops("pallas", kern, block_size=128)
    X, C, u, v = _data(64, 32768, 5, seed=3)
    with pytest.raises(ValueError, match="VMEM budget"):
        pops.sweep_with_stats(X, C, u, v)


def test_small_shapes_still_take_the_fused_path():
    """Regression guard: the planner must not push in-core shapes (the
    entire pre-existing test matrix) off the single-evaluation fused path."""
    kern = make_kernel("gaussian", sigma=1.5)
    pops = get_ops("pallas", kern, block_size=128)
    for n, M in [(300, 97), (513, 129), (2048, 1024)]:
        assert pops.plan(n, M, 16, 1).path == "fused", (n, M)
