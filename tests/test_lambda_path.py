"""The lam-path solver: one data sweep serves every hyperparameter.

Acceptance, keyed to the batched-path refactor:

* ``falkon_fit_path`` over an L=8 lam grid matches L independent
  ``falkon_fit`` runs on each alpha — on the fused, two_pass, j_sharded AND
  streaming sweep paths, under the fp32 and bf16 policies. The parity
  tolerance is policy-scaled: 1e-4 relative for fp32; for bf16 the floor is
  the policy's own storage quantization (the CG iterates round through
  eps_bf16 ~ 3.9e-3 in BOTH runs, so any eps_fp32-level reordering between
  the stacked and per-system pipelines surfaces at bf16 ulps) — we pin the
  documented 1e-2 policy ceiling there, matching tests/test_precision.py.
* The path fit issues ~1/L the data sweeps — asserted exactly via the
  ``CountingOps`` facade.
* The planner charges the widened p = L*p column block (``systems=``), so
  fat paths route off the fused path like fat multi-rhs blocks do.
* The leverage-score pilot-Gram build is shared across a lam grid.
* A validation split selects the same lam the L sequential fits select.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TEST_PRECISION, synthetic_regression
from repro.core import (
    FalkonConfig,
    approximate_leverage_scores,
    approximate_leverage_scores_path,
    build_leverage_pilot,
    falkon_fit,
    falkon_fit_path,
    falkon_fit_path_streaming,
    falkon_fit_streaming,
    leverage_scores_from_pilot,
    make_kernel,
    make_preconditioner,
    make_preconditioner_path,
)
from repro.ops import CountingOps, SweepPlanWarning, get_ops, plan_sweep

LAMS = tuple(float(10.0**e) for e in np.linspace(-4.0, -1.0, 8))
#: fp32: the acceptance bound. bf16: the policy's documented error ceiling —
#: both runs quantize the CG iterates at eps_bf16, which is the parity floor.
REL_TOL = {"fp32": 1e-4, "bf16": 1e-2}


def _problem(n=400, d=5, seed=0):
    return synthetic_regression(jax.random.PRNGKey(seed), n, d=d)


def _cfg(**kw):
    defaults = dict(
        kernel_params=(("sigma", 1.0),),
        num_centers=64,
        iterations=30,
        block_size=128,
        jitter=1e-5,
        estimate_cond=False,
    )
    defaults.update(kw)
    return FalkonConfig(**defaults)


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.maximum(jnp.linalg.norm(b), 1e-30))


def _assert_path_matches_sequential(X, y, cfg, lams, tol):
    """Shared acceptance core: same key, L sequential fits vs one path fit."""
    key = jax.random.PRNGKey(1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", SweepPlanWarning)
        res = falkon_fit_path(key, X, y, cfg, lams)
        for i, lam in enumerate(lams):
            est, _ = falkon_fit(key, X, y, dataclasses.replace(cfg, lam=lam))
            rel = _rel(res.estimators[i].alpha, est.alpha)
            assert rel <= tol, f"lam={lam:.2e}: rel alpha gap {rel:.2e} > {tol}"
    return res


# ---------------------------------------------------------------------------
# Parity: jnp reference + every planner-routed Pallas path + streaming
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("precision", ["fp32", "bf16"])
def test_path_matches_sequential_jnp(precision):
    X, y = _problem()
    cfg = _cfg(ops_impl="jnp", precision=precision)
    res = _assert_path_matches_sequential(X, y, cfg, LAMS, REL_TOL[precision])
    assert len(res.estimators) == len(LAMS)
    assert res.state.alphas.shape == (len(LAMS), 64)


def test_path_matches_sequential_pallas_fused():
    """Fused single-pass Pallas sweep (interpret mode on CPU), the CI axis's
    precision policy."""
    X, y = _problem(n=192)
    cfg = _cfg(ops_impl="pallas", precision=TEST_PRECISION, iterations=8)
    ops = cfg.make_ops()
    assert ops.plan(192, 64, 5, 1, systems=len(LAMS)).path == "fused"
    _assert_path_matches_sequential(X, y, cfg, LAMS, REL_TOL[TEST_PRECISION])


@pytest.mark.parametrize("route,n,M,t,budget_mb,sigma,jitter,lam_lo", [
    ("two_pass", 192, 64, 6, 0.05, 1.0, 1e-5, -4.0),
    # j_sharded needs M > the 512-lane shard floor; M=640 of n=768 points
    # makes K_MM near-singular, so this point runs better-conditioned
    # (smaller sigma, bigger jitter, lam >= 1e-3) to keep the fp-noise
    # amplification below the parity tolerance.
    ("j_sharded", 768, 640, 4, 0.1, 0.5, 1e-4, -3.0),
])
def test_path_matches_sequential_pallas_out_of_core(
    monkeypatch, route, n, M, t, budget_mb, sigma, jitter, lam_lo
):
    """The out-of-core sweep schedules under a shrunken VMEM budget: the
    path solve and the sequential fits both route onto ``route`` and still
    agree per alpha."""
    monkeypatch.setenv("REPRO_VMEM_BUDGET_MB", str(budget_mb))
    X, y = _problem(n=n)
    lams = tuple(float(10.0**e) for e in np.linspace(lam_lo, -1.0, 8))
    cfg = _cfg(
        ops_impl="pallas",
        precision=TEST_PRECISION,
        iterations=t,
        num_centers=M,
        kernel_params=(("sigma", sigma),),
        jitter=jitter,
    )
    plan = cfg.make_ops().plan(n, M, 5, 1, systems=len(lams))
    assert plan.path == route, plan
    _assert_path_matches_sequential(X, y, cfg, lams, REL_TOL[TEST_PRECISION])


def test_path_matches_sequential_streaming():
    """Host-streamed chunks: one pass over the stream per CG iteration
    serves all L systems (ragged chunking, same sampled centers by key)."""
    from repro.data.streaming import ArrayChunkSource

    X, y = _problem()
    src = ArrayChunkSource(np.asarray(X), np.asarray(y), chunk_rows=96)
    # better-conditioned than the in-core points: the host CG's per-chunk
    # accumulation order differs between the stacked and thin blocks, and
    # under bf16 iterate storage that reordering costs extra bf16 ulps
    cfg = _cfg(ops_impl="jnp", precision=TEST_PRECISION, jitter=1e-4)
    lams = tuple(float(10.0**e) for e in np.linspace(-3.0, -1.0, 8))
    key = jax.random.PRNGKey(1)
    res = falkon_fit_path_streaming(key, src, cfg, lams)
    tol = REL_TOL[TEST_PRECISION]
    for i, lam in enumerate(lams):
        est, _ = falkon_fit_streaming(key, src, dataclasses.replace(cfg, lam=lam))
        rel = _rel(res.estimators[i].alpha, est.alpha)
        assert rel <= tol, f"lam={lam:.2e}: rel alpha gap {rel:.2e} > {tol}"


# ---------------------------------------------------------------------------
# The claim itself: ~1/L the data sweeps, counted at the ops facade
# ---------------------------------------------------------------------------
def test_path_issues_one_fit_of_sweeps():
    """The path fit's program contains ONE sweep per CG step (RHS + in-scan
    matvec) regardless of L; L sequential fits contain L of each. The
    scanned CG traces its matvec once and executes it t times, so the
    counted call-site ratio equals the executed data-pass ratio: exactly L.
    """
    X, y = _problem()
    cfg = _cfg(ops_impl="jnp")
    kern = cfg.make_kernel()
    key = jax.random.PRNGKey(1)

    path_ops = CountingOps(get_ops("jnp", kern, block_size=cfg.block_size))
    falkon_fit_path(key, X, y, cfg, LAMS, ops=path_ops)

    seq_ops = CountingOps(get_ops("jnp", kern, block_size=cfg.block_size))
    for lam in LAMS:
        falkon_fit(key, X, y, dataclasses.replace(cfg, lam=lam), ops=seq_ops)

    L = len(LAMS)
    assert path_ops.sweeps == 2                  # RHS pass + the scanned matvec
    assert seq_ops.sweeps == L * path_ops.sweeps  # the 1/L sweep claim
    assert path_ops.grams == 1 and seq_ops.grams == L  # one chol(K_MM) total


def test_path_validation_scoring_is_one_apply():
    """Scoring L lams over the val set is ONE stacked apply, not L."""
    X, y = _problem()
    cfg = _cfg(ops_impl="jnp")
    ops = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=cfg.block_size))
    res = falkon_fit_path(
        jax.random.PRNGKey(1), X, y, cfg, LAMS, X_val=X[:100], y_val=y[:100], ops=ops
    )
    assert ops.applies == 1
    assert res.val_scores.shape == (len(LAMS),)
    assert res.best is res.estimators[res.best_index]


def test_path_validation_selects_sequential_argmin():
    X, y = _problem(seed=3)
    Xv, yv = _problem(seed=9)
    cfg = _cfg(ops_impl="jnp")
    key = jax.random.PRNGKey(1)
    res = falkon_fit_path(key, X, y, cfg, LAMS, X_val=Xv, y_val=yv)
    seq_mse = []
    for lam in LAMS:
        est, _ = falkon_fit(key, X, y, dataclasses.replace(cfg, lam=lam))
        seq_mse.append(float(jnp.mean((est.predict(Xv) - yv) ** 2)))
    assert res.best_index == int(np.argmin(seq_mse))
    np.testing.assert_allclose(
        np.asarray(res.val_scores), seq_mse, rtol=1e-3, atol=1e-5
    )


def test_path_multirhs():
    """Multiclass targets: the stacked block is (q, L*p), split back to
    (L, M, p) coefficient stacks."""
    X, _ = _problem()
    labels = jnp.argmax(jax.random.normal(jax.random.PRNGKey(5), (400, 3)), -1)
    Y = jax.nn.one_hot(labels, 3)
    cfg = _cfg(ops_impl="jnp", iterations=30)
    lams = LAMS[2:6]
    key = jax.random.PRNGKey(1)
    res = falkon_fit_path(key, X, Y, cfg, lams)
    assert res.state.alphas.shape == (4, 64, 3)
    for i, lam in enumerate(lams):
        est, _ = falkon_fit(key, X, Y, dataclasses.replace(cfg, lam=lam))
        assert _rel(res.estimators[i].alpha, est.alpha) <= 1e-4
        assert res.estimators[i].predict(X[:7]).shape == (7, 3)


# ---------------------------------------------------------------------------
# Planner: the widened p = L*p column block routes fat paths off fused
# ---------------------------------------------------------------------------
def test_planner_charges_widened_path_block():
    kern = make_kernel("gaussian", sigma=2.0)
    pops = get_ops("pallas", kern, block_size=2048)
    thin = pops.plan(2048, 2048, 32, 1)
    assert thin.path == "fused" and thin.systems == 1
    fat = pops.plan(2048, 2048, 32, 1, systems=512)
    assert fat.p == 512 and fat.systems == 512
    assert fat.path != "fused", "a 512-system path block must not fit fused"
    # jnp backend reports the same widening through the uniform SweepPlan
    jplan = get_ops("jnp", kern).plan(2048, 2048, 32, 2, systems=8)
    assert jplan.p == 16 and jplan.systems == 8


def test_plan_sweep_systems_equivalent_to_prewidened_p():
    kw = dict(bm=256, bn=512, vmem_budget=4 * 2**20)
    a = plan_sweep(8192, 4096, 32, 2, systems=8, **kw)
    b = plan_sweep(8192, 4096, 32, 16, **kw)
    assert a.path == b.path and a.p == b.p == 16
    assert a.scratch_bytes == b.scratch_bytes and a.io_bytes == b.io_bytes
    assert a.systems == 8 and b.systems == 1


# ---------------------------------------------------------------------------
# Preconditioner path: shared stage + batched A stack
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rank_deficient", [False, True])
def test_preconditioner_path_matches_singles(rank_deficient):
    kern = make_kernel("gaussian", sigma=1.5)
    C = jax.random.normal(jax.random.PRNGKey(2), (48, 4))
    KMM = kern(C, C)
    lams = LAMS[:5]
    pp = make_preconditioner_path(KMM, lams, 1000, rank_deficient=rank_deficient)
    U = jax.random.normal(jax.random.PRNGKey(3), (pp.q, len(lams) * 2))
    right = pp.right(U)
    left = pp.left(
        jax.random.normal(jax.random.PRNGKey(4), (KMM.shape[0], len(lams) * 2))
    )
    for i, lam in enumerate(lams):
        single = make_preconditioner(KMM, lam, 1000, rank_deficient=rank_deficient)
        np.testing.assert_array_equal(np.asarray(pp.A[i]), np.asarray(single.A))
        # per-system column groups of the stacked maps == the single maps
        # (loose: T^{-1}A^{-1} amplifies batched-vs-plain trsm rounding)
        cols = slice(i * 2, (i + 1) * 2)
        np.testing.assert_allclose(
            np.asarray(right[:, cols]),
            np.asarray(single.right(U[:, cols])),
            rtol=2e-4,
            atol=2e-4,
        )
        sysp = pp.system(i)
        np.testing.assert_array_equal(np.asarray(sysp.A), np.asarray(single.A))
    assert left.shape == (pp.q, len(lams) * 2)


def test_preconditioner_path_expand_rhs_matches_left():
    kern = make_kernel("gaussian", sigma=1.5)
    C = jax.random.normal(jax.random.PRNGKey(2), (32, 4))
    KMM = kern(C, C)
    lams = LAMS[:3]
    pp = make_preconditioner_path(KMM, lams, 500)
    w = jax.random.normal(jax.random.PRNGKey(7), (32,))
    b = pp.expand_rhs(w)                       # (q, L)
    for i, lam in enumerate(lams):
        single = make_preconditioner(KMM, lam, 500)
        np.testing.assert_allclose(
            np.asarray(b[:, i]), np.asarray(single.left(w)), rtol=1e-4, atol=1e-5
        )


def test_preconditioner_path_rejects_empty_grid():
    KMM = jnp.eye(8)
    with pytest.raises(ValueError, match="non-empty"):
        make_preconditioner_path(KMM, [], 100)


def test_preconditioner_path_rejects_nonpositive_lams():
    """Direct builder callers get an error, not the batched Cholesky's
    silent NaNs (the fit wrappers validate separately)."""
    KMM = jnp.eye(8)
    with pytest.raises(ValueError, match="> 0"):
        make_preconditioner_path(KMM, [1e-3, -1e-3], 100)
    with pytest.raises(ValueError, match="> 0"):
        make_preconditioner_path(KMM, [0.0], 100)


# ---------------------------------------------------------------------------
# Leverage scores: pilot-Gram build shared across the lam grid
# ---------------------------------------------------------------------------
def test_leverage_pilot_reuse_matches_single_shot():
    X, _ = _problem(n=300)
    kern = make_kernel("gaussian", sigma=2.0)
    key = jax.random.PRNGKey(11)
    pilot = build_leverage_pilot(key, X, kern, pilot_size=64, block_size=128)
    for lam in (1e-4, 1e-2):
        composed = leverage_scores_from_pilot(pilot, X, kern, lam, block_size=128)
        one_shot = approximate_leverage_scores(
            key, X, kern, lam, pilot_size=64, block_size=128
        )
        np.testing.assert_allclose(
            np.asarray(composed), np.asarray(one_shot), rtol=1e-6
        )
    grid = approximate_leverage_scores_path(
        key, X, kern, (1e-4, 1e-2), pilot_size=64, block_size=128
    )
    assert grid.shape == (2, 300)
    np.testing.assert_allclose(
        np.asarray(grid[1]),
        np.asarray(approximate_leverage_scores(key, X, kern, 1e-2,
                                               pilot_size=64,
                                               block_size=128)),
        rtol=1e-6)


def test_path_fit_leverage_selection_shares_centers():
    X, y = _problem()
    cfg = _cfg(center_selection="leverage", pilot_size=96, iterations=15)
    res = falkon_fit_path(jax.random.PRNGKey(1), X, y, cfg, LAMS[:4])
    assert all(est.centers is res.estimators[0].centers for est in res.estimators)
    for est in res.estimators:
        assert bool(jnp.all(jnp.isfinite(est.alpha)))
    mse = float(jnp.mean((res.estimators[0].predict(X) - y) ** 2))
    assert mse < 0.3


# ---------------------------------------------------------------------------
# API guards
# ---------------------------------------------------------------------------
def test_path_fit_rejects_bad_grids():
    X, y = _problem(n=64)
    cfg = _cfg(num_centers=16, iterations=2)
    with pytest.raises(ValueError, match="non-empty"):
        falkon_fit_path(jax.random.PRNGKey(0), X, y, cfg, [])
    with pytest.raises(ValueError, match="> 0"):
        falkon_fit_path(jax.random.PRNGKey(0), X, y, cfg, [1e-3, 0.0])
    with pytest.raises(ValueError, match="y_val"):
        falkon_fit_path(jax.random.PRNGKey(0), X, y, cfg, [1e-3], X_val=X)
    with pytest.raises(ValueError, match="together"):
        falkon_fit_path(jax.random.PRNGKey(0), X, y, cfg, [1e-3], y_val=y)
