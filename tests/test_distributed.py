"""Distributed-path tests.

These need >1 device, so they run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test process
keeps the single real device, per the dry-run isolation rule).
"""
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_distributed_matvec_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.compat import use_mesh
        from repro.core import GaussianKernel, knm_matvec, make_distributed_matvec
        assert len(jax.devices()) == 8
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        kern = GaussianKernel(sigma=1.5)
        k = jax.random.PRNGKey(0)
        X = jax.random.normal(k, (512, 6))
        C = X[:64]
        u = jax.random.normal(jax.random.PRNGKey(1), (64,))
        v = jax.random.normal(jax.random.PRNGKey(2), (512,))
        ref = knm_matvec(X, C, u, v, kern, block_size=128)
        dmv = make_distributed_matvec(mesh, ("data",), kern, block_size=64)
        Xs = jax.device_put(X, NamedSharding(mesh, P("data")))
        vs = jax.device_put(v, NamedSharding(mesh, P("data")))
        with use_mesh(mesh):
            got = dmv(Xs, C, u, vs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-3)
        print("OK")
    """)


def test_distributed_fit_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.compat import use_mesh
        from repro.core import FalkonConfig, falkon_fit
        mesh = jax.make_mesh((8,), ("data",))
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        X = jax.random.normal(k1, (1024, 5))
        w = jax.random.normal(k2, (5,))
        y = jnp.sin(X @ w) + 0.05 * jax.random.normal(k3, (1024,))
        cfg = FalkonConfig(kernel="gaussian", kernel_params=(("sigma", 2.0),),
                           lam=1e-4, num_centers=128, iterations=20,
                           block_size=128)
        est_1, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
        with use_mesh(mesh):
            est_8, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg, mesh=mesh,
                                  data_axes=("data",))
        # alpha itself is ill-conditioned in fp32; predictions are the
        # well-posed quantity (same reason Thm 1 bounds excess risk, not alpha)
        p1, p8 = est_1.predict(X), est_8.predict(X)
        rel = float(jnp.linalg.norm(p8 - p1) / jnp.linalg.norm(p1))
        assert rel < 2e-3, rel
        print("OK")
    """)


def test_distributed_fit_multipod_axes():
    """The FALKON sweep shards over BOTH ('pod','data') axes — the multi-pod
    configuration of DESIGN.md §6 in miniature."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.compat import use_mesh
        from repro.core import FalkonConfig, falkon_fit
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        X = jax.random.normal(k1, (512, 5))
        w = jax.random.normal(k2, (5,))
        y = jnp.sin(X @ w) + 0.05 * jax.random.normal(k3, (512,))
        cfg = FalkonConfig(kernel="gaussian", kernel_params=(("sigma", 2.0),),
                           lam=1e-4, num_centers=64, iterations=15,
                           block_size=64)
        est_1, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
        with use_mesh(mesh):
            est_d, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg, mesh=mesh,
                                  data_axes=("pod", "data"))
        p1, pd = est_1.predict(X), est_d.predict(X)
        rel = float(jnp.linalg.norm(pd - p1) / jnp.linalg.norm(p1))
        assert rel < 2e-3, rel
        print("OK")
    """)


def test_mini_dryrun_train_and_decode():
    """End-to-end dry-run machinery on an 8-device mesh: pspec resolution,
    lower + compile, memory/cost analysis, HLO collective parse — the same
    code path the 512-device production dry-run uses."""
    _run("""
        import jax, jax.numpy as jnp, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import reduced_config
        from repro.configs.base import input_specs
        from repro.distributed.mesh import AxisRules, use_rules
        from repro.models import cache_pspecs, cache_specs, model_param_structs
        from repro.models.model import model_param_pspecs
        from repro.roofline.analysis import derive_roofline, memory_report
        from repro.train.steps import (TrainConfig, batch_pspecs,
                                       make_serve_step, make_train_step,
                                       train_state_pspecs, train_state_structs)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                       is_leaf=lambda x: isinstance(x, P))
        for arch in ("jamba-1.5-large-398b", "granite-moe-3b-a800m"):
            cfg = dataclasses.replace(reduced_config(arch), remat="full",
                                      fsdp=True)
            rules = AxisRules(mesh=mesh, fsdp=True)
            with mesh, use_rules(rules):
                # train cell
                tcfg = TrainConfig(microbatch=2)
                step = make_train_step(cfg, tcfg)
                ss = train_state_structs(cfg, tcfg)
                sp = train_state_pspecs(cfg, tcfg, rules)
                bstructs = {
                    "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
                bp = batch_pspecs(cfg, bstructs, rules)
                comp = jax.jit(step, in_shardings=(named(sp), named(bp)),
                               donate_argnums=(0,)).lower(ss, bstructs).compile()
                roof = derive_roofline(comp, chips=8, model_flops=1.0)
                assert roof.flops_per_device > 0
                assert memory_report(comp)["total_per_device"] > 0
                # decode cell
                serve = make_serve_step(cfg)
                ps = model_param_structs(cfg)
                pp = model_param_pspecs(cfg, rules)
                cs = cache_specs(cfg, 8, 64)
                cp = cache_pspecs(cfg, 8, 64, rules)
                bs = {"token": jax.ShapeDtypeStruct((8,), jnp.int32)}
                comp2 = jax.jit(serve, in_shardings=(
                    named(pp), named(cp), named(bp := batch_pspecs(cfg, bs, rules))),
                    donate_argnums=(1,)).lower(ps, cs, bs).compile()
                assert memory_report(comp2)["total_per_device"] > 0
            print(arch, "OK")
    """)


def test_shardmap_moe_matches_local():
    """Expert-parallel (all_to_all) MoE == local-dispatch MoE numerically."""
    _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.distributed.mesh import AxisRules, use_rules
        from repro.models import layers as L
        from repro.models.params import init_params
        cfg = dataclasses.replace(reduced_config("granite-moe-3b-a800m"),
                                  n_experts=4, expert_pad_multiple=2, top_k=2,
                                  capacity_factor=4.0)
        p = init_params(jax.random.PRNGKey(0), L.moe_pd(cfg), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * .5
        ref = L._moe_local(p, x, cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = AxisRules(mesh=mesh)
        with mesh, use_rules(rules):
            got = jax.jit(lambda p, x: L.moe_apply(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print("OK")
    """)


def test_elastic_restore_across_meshes():
    """Fault tolerance: train on a (2,2,2) pod mesh, checkpoint, restore the
    same state onto a (4,2) single-pod mesh (elastic rescale), resume, and
    get bit-identical metrics to an uninterrupted run."""
    _run("""
        import os, tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import load_checkpoint, save_checkpoint, step_dir
        from repro.configs import reduced_config
        from repro.distributed.mesh import AxisRules, use_rules
        from repro.train import TrainConfig, init_train_state, make_train_step
        from repro.train.steps import train_state_pspecs

        cfg = reduced_config("qwen2-72b")
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                              0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32),
                                              0, cfg.vocab)}
        named = lambda mesh, t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))

        mesh_a = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules_a = AxisRules(mesh=mesh_a)
        state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        with mesh_a, use_rules(rules_a):
            step = jax.jit(make_train_step(cfg, tcfg))
            state, m1 = step(state, batch)
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(step_dir(d, 1), state, 1, blocking=True)

            # restore onto a DIFFERENT mesh with its own shardings
            mesh_b = jax.make_mesh((4, 2), ("data", "model"))
            rules_b = AxisRules(mesh=mesh_b)
            shardings = named(mesh_b, train_state_pspecs(cfg, tcfg, rules_b))
            restored, stp = load_checkpoint(step_dir(d, 1), state,
                                            shardings=shardings)
            assert stp == 1
            with mesh_b, use_rules(rules_b):
                step_b = jax.jit(make_train_step(cfg, tcfg))
                _, m2 = step_b(restored, batch)

            # uninterrupted reference on mesh_a
            with mesh_a, use_rules(rules_a):
                _, m_ref = step(state, batch)
        np.testing.assert_allclose(float(m2["loss"]), float(m_ref["loss"]),
                                   rtol=2e-4)
        print("OK elastic restore", float(m2["loss"]))
    """)
