"""Distributed-path tests.

These need >1 device, so they run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test process
keeps the single real device, per the dry-run isolation rule).
"""
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_distributed_sweep_matches_single_device():
    """DistributedOps.sweep over a (4,2) mesh data axis == the wrapped
    backend's sweep, for both jnp and pallas inner backends, with exactly
    one (M, p) psum of comm per call."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import GaussianKernel
        from repro.ops import DistributedOps, get_ops
        assert len(jax.devices()) == 8
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        kern = GaussianKernel(sigma=1.5)
        X = jax.random.normal(jax.random.PRNGKey(0), (512, 6))
        C = X[:64]
        u = jax.random.normal(jax.random.PRNGKey(1), (64,))
        v = jax.random.normal(jax.random.PRNGKey(2), (512,))
        for impl in ("jnp", "pallas"):
            inner = get_ops(impl, kern, block_size=64)
            ref = inner.sweep(X, C, u, v)
            dist = DistributedOps(inner, mesh, ("data",))
            got = dist.sweep(X, C, u, v)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-4, atol=2e-3)
            # apply is row-local: no psum, bit-identical to the inner backend
            np.testing.assert_array_equal(
                np.asarray(dist.apply(X, C, u)),
                np.asarray(inner.apply(X, C, u)))
            assert dist.psums == 1, (impl, dist.psums)
            assert dist.psum_floats == 64, (impl, dist.psum_floats)
            print(impl, "OK")
    """)


def test_distributed_fit_matches_single_device():
    _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.core import FalkonConfig, falkon_fit
        mesh = jax.make_mesh((8,), ("data",))
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        X = jax.random.normal(k1, (1024, 5))
        w = jax.random.normal(k2, (5,))
        y = jnp.sin(X @ w) + 0.05 * jax.random.normal(k3, (1024,))
        cfg = FalkonConfig(kernel="gaussian", kernel_params=(("sigma", 2.0),),
                           lam=1e-4, num_centers=128, iterations=20,
                           block_size=128)
        est_1, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
        cfg_8 = dataclasses.replace(cfg, mesh=mesh)
        est_8, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg_8)
        # alpha itself is ill-conditioned in fp32; predictions are the
        # well-posed quantity (same reason Thm 1 bounds excess risk, not alpha)
        p1, p8 = est_1.predict(X), est_8.predict(X)
        rel = float(jnp.linalg.norm(p8 - p1) / jnp.linalg.norm(p1))
        assert rel < 2e-3, rel
        # legacy mesh=/data_axes= kwargs are the same route as config.mesh
        est_kw, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg, mesh=mesh,
                               data_axes=("data",))
        assert bool(jnp.all(est_kw.alpha == est_8.alpha))
        print("OK")
    """)


def test_distributed_fit_multipod_axes():
    """The FALKON sweep shards over BOTH ('pod','data') axes — the multi-pod
    configuration of DESIGN.md §6 in miniature."""
    _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.core import FalkonConfig, falkon_fit
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        X = jax.random.normal(k1, (512, 5))
        w = jax.random.normal(k2, (5,))
        y = jnp.sin(X @ w) + 0.05 * jax.random.normal(k3, (512,))
        cfg = FalkonConfig(kernel="gaussian", kernel_params=(("sigma", 2.0),),
                           lam=1e-4, num_centers=64, iterations=15,
                           block_size=64, mesh=mesh, data_axes=("pod", "data"))
        est_1, _ = falkon_fit(jax.random.PRNGKey(1), X, y,
                              dataclasses.replace(cfg, mesh=None))
        est_d, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
        p1, pd = est_1.predict(X), est_d.predict(X)
        rel = float(jnp.linalg.norm(pd - p1) / jnp.linalg.norm(p1))
        assert rel < 2e-3, rel
        print("OK")
    """)


def test_counting_ops_under_shard_map():
    """A CountingOps wrapped by DistributedOps proves the distributed fit
    traces the SAME number of sweeps and gram builds as a single-device
    fit — no hidden per-shard re-sweeps — and that every sweep costs
    exactly one (M, p) psum."""
    _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.core import FalkonConfig, falkon_fit
        from repro.core.falkon import _resolve_ops
        from repro.ops import CountingOps, DistributedOps, get_ops
        mesh = jax.make_mesh((8,), ("data",))
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        X = jax.random.normal(k1, (512, 5))
        y = jnp.sin(X @ jax.random.normal(k2, (5,)))
        cfg = FalkonConfig(kernel="gaussian", kernel_params=(("sigma", 2.0),),
                           lam=1e-4, num_centers=64, iterations=10,
                           block_size=64)
        count_1 = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=64))
        falkon_fit(jax.random.PRNGKey(1), X, y, cfg, ops=count_1)
        count_8 = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=64))
        cfg_8 = dataclasses.replace(cfg, mesh=mesh)
        # _resolve_ops wraps the CountingOps in DistributedOps, so the
        # counter records the trace-time program points the shards replay
        dist = _resolve_ops(cfg_8, cfg.make_kernel(), count_8)
        assert isinstance(dist, DistributedOps)
        falkon_fit(jax.random.PRNGKey(1), X, y, cfg_8, ops=dist)
        assert count_8.sweeps == count_1.sweeps, (count_8.sweeps, count_1.sweeps)
        assert count_8.grams == count_1.grams, (count_8.grams, count_1.grams)
        assert count_8.applies == count_1.applies
        # one (M, p) psum per sweep and nothing else on the wire
        assert dist.psums == count_8.sweeps, (dist.psums, count_8.sweeps)
        assert dist.psum_floats == count_8.sweeps * 64
        print("OK sweeps", count_8.sweeps, "grams", count_8.grams)
    """)


def test_counting_outside_distributed_not_double_wrapped():
    """The other composition order the _resolve_ops docstring promises:
    CountingOps(DistributedOps(inner)) with config.mesh set must pass
    through unwrapped — a second DistributedOps would nest shard_map over
    the same mesh axes (trace failure / double reduction)."""
    _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.core import FalkonConfig, falkon_fit
        from repro.core.falkon import _resolve_ops
        from repro.ops import CountingOps, DistributedOps, get_ops
        mesh = jax.make_mesh((8,), ("data",))
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        X = jax.random.normal(k1, (512, 5))
        y = jnp.sin(X @ jax.random.normal(k2, (5,)))
        cfg = FalkonConfig(kernel="gaussian", kernel_params=(("sigma", 2.0),),
                           lam=1e-4, num_centers=64, iterations=10,
                           block_size=64, mesh=mesh)
        inner = get_ops("jnp", cfg.make_kernel(), block_size=64)
        counted = CountingOps(DistributedOps(inner, mesh, ("data",)))
        resolved = _resolve_ops(cfg, cfg.make_kernel(), counted)
        assert resolved is counted, type(resolved)  # no second wrap
        est_c, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg, ops=counted)
        assert counted.sweeps > 0
        est_p, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
        assert bool(jnp.all(est_c.alpha == est_p.alpha))
        print("OK sweeps", counted.sweeps)
    """)


def test_ragged_shard_mask_pad_parity():
    """n not divisible by the data axis: the padded final shard contributes
    exactly zero. At the same padded length, junk rows + row_mask is
    bit-identical to internal zero-padding (fp32) across jnp and pallas
    inner backends and the VMEM-starved fallback route; bf16 holds to its
    compensated-accumulation tolerance."""
    _run("""
        import os, jax, jax.numpy as jnp, numpy as np
        from repro.core import GaussianKernel
        from repro.ops import DistributedOps, get_ops
        mesh = jax.make_mesh((8,), ("data",))
        kern = GaussianKernel(sigma=1.5)
        n, n_pad = 397, 400            # 397 % 8 != 0; ceil(397/8)*8 = 400
        X = jax.random.normal(jax.random.PRNGKey(0), (n, 6))
        C = X[:48]
        u = jax.random.normal(jax.random.PRNGKey(1), (48,))
        v = jax.random.normal(jax.random.PRNGKey(2), (n,))
        junk = 1e3 * jax.random.normal(jax.random.PRNGKey(3), (n_pad - n, 6))
        X_junk = jnp.concatenate([X, junk])
        v_junk = jnp.concatenate([v, jnp.full((n_pad - n,), 1e6)])
        mask = (jnp.arange(n_pad) < n)

        def check(impl, **kw):
            inner = get_ops(impl, kern, block_size=64, **kw)
            dist = DistributedOps(inner, mesh, ("data",))
            ref = inner.sweep(X, C, u, v)                 # single device
            got = dist.sweep(X, C, u, v)                  # internal zero-pad
            masked = dist.sweep(X_junk, C, u, v_junk, row_mask=mask)
            tol = dict(rtol=2e-4, atol=2e-3)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **tol)
            if inner.policy.storage == "float32":
                # masked junk rows are EXACTLY invisible: bit-identical
                np.testing.assert_array_equal(np.asarray(masked),
                                              np.asarray(got))
            else:
                np.testing.assert_allclose(np.asarray(masked),
                                           np.asarray(got), **tol)

        check("jnp")
        check("pallas")
        check("jnp", precision="bf16")
        check("pallas", precision="bf16")
        # starve the planner so the pallas sweep leaves the fused path
        os.environ["REPRO_VMEM_BUDGET_MB"] = "0.05"
        inner = get_ops("pallas", kern, block_size=64)
        assert inner.plan(400, 48, 6).path != "fused", inner.plan(400, 48, 6)
        check("pallas")
        del os.environ["REPRO_VMEM_BUDGET_MB"]
        print("OK")
    """)


def test_int8_psum_compression_parity():
    """Opt-in int8 wire compression: quantize/dequantize round-trip before
    the psum bounds the comm payload's precision; results stay within the
    symmetric-int8 quantization tolerance of the uncompressed sweep."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import GaussianKernel
        from repro.ops import DistributedOps, get_ops
        mesh = jax.make_mesh((8,), ("data",))
        kern = GaussianKernel(sigma=1.5)
        X = jax.random.normal(jax.random.PRNGKey(0), (512, 6))
        C = X[:64]
        u = jax.random.normal(jax.random.PRNGKey(1), (64,))
        v = jax.random.normal(jax.random.PRNGKey(2), (512,))
        inner = get_ops("jnp", kern, block_size=64)
        ref = DistributedOps(inner, mesh, ("data",)).sweep(X, C, u, v)
        comp = DistributedOps(inner, mesh, ("data",), compress="int8")
        got = comp.sweep(X, C, u, v)
        rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
        assert 0.0 < rel < 2e-2, rel   # int8 wire: ~1/127 per-shard rounding
        print("OK rel", rel)
    """)


def test_sharded_chunk_sources_cover_the_stream():
    """shard_chunk_sources splits a ChunkSource into per-shard row ranges
    that partition the stream: the shards reassemble the exact rows, and
    per-shard sweeps SUM to the full-stream sweep even when shard
    boundaries cut across chunk boundaries."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import GaussianKernel
        from repro.data import (ArrayChunkSource, StreamingLoader,
                                shard_chunk_sources, streaming_sweep)
        from repro.ops import get_ops
        kern = GaussianKernel(sigma=1.5)
        n = 397                      # ragged vs both chunk size and shards
        X = np.random.RandomState(0).randn(n, 6).astype(np.float32)
        y = np.random.RandomState(1).randn(n).astype(np.float32)
        src = ArrayChunkSource(X, y, chunk_rows=96)
        shards = shard_chunk_sources(src, 8)
        assert len(shards) == 8
        assert sum(s.n_rows for s in shards) == n
        np.testing.assert_array_equal(
            np.concatenate([np.concatenate([c[0] for c in s.chunks()])
                            for s in shards if s.n_rows]), X)
        ops = get_ops("jnp", kern, block_size=64)
        C = jnp.asarray(X[:48])
        u = jax.random.normal(jax.random.PRNGKey(2), (48,))
        full = streaming_sweep(ops, StreamingLoader(src), C, u,
                               use_targets=True)
        parts = [streaming_sweep(ops, StreamingLoader(s), C, u,
                                 use_targets=True)
                 for s in shards if s.n_rows]
        np.testing.assert_allclose(np.asarray(sum(parts)), np.asarray(full),
                                   rtol=2e-5, atol=2e-5)
        print("OK")
    """)


def test_distributed_fit_path_and_streaming():
    """The lambda-path fit stacks L systems into ONE psum'd (M, L*p) block
    per sweep, and the streaming fit inherits the mesh from config — both
    match their single-device counterparts."""
    _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.core import (FalkonConfig, falkon_fit_path,
                                falkon_fit_streaming)
        from repro.core.falkon import _resolve_ops
        from repro.data import ArrayChunkSource
        from repro.ops import CountingOps, get_ops
        mesh = jax.make_mesh((8,), ("data",))
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        X = jax.random.normal(k1, (640, 5))
        y = jnp.sin(X @ jax.random.normal(k2, (5,)))
        y = y + 0.05 * jax.random.normal(k3, (640,))
        cfg = FalkonConfig(kernel="gaussian", kernel_params=(("sigma", 2.0),),
                           lam=1e-4, num_centers=64, iterations=15,
                           block_size=64)
        cfg_8 = dataclasses.replace(cfg, mesh=mesh)
        lams = (1e-2, 1e-3, 1e-4)
        res_1 = falkon_fit_path(jax.random.PRNGKey(1), X, y, cfg, lams,
                                X_val=X[:96], y_val=y[:96])
        count = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=64))
        dist = _resolve_ops(cfg_8, cfg.make_kernel(), count)
        res_8 = falkon_fit_path(jax.random.PRNGKey(1), X, y, cfg_8, lams,
                                X_val=X[:96], y_val=y[:96], ops=dist)
        # the val curves match pointwise (best_index itself can flip on a
        # near-tie under fp32 psum reassociation, so compare the curve)
        np.testing.assert_allclose(np.asarray(res_8.val_scores),
                                   np.asarray(res_1.val_scores),
                                   rtol=5e-2, atol=5e-4)
        for e1, e8 in zip(res_1.estimators, res_8.estimators):
            p1, p8 = e1.predict(X), e8.predict(X)
            rel = float(jnp.linalg.norm(p8 - p1) / jnp.linalg.norm(p1))
            assert rel < 5e-2, rel
        # one psum per batched sweep: the L systems share the wire. The path
        # fit traces exactly two sweeps — the p=1 RHS build and the CG body
        # carrying all L systems as one (M, L) block — so the wire carries
        # M*1 + M*L floats, NOT L independent psums per iteration.
        assert dist.psums == count.sweeps == 2, (dist.psums, count.sweeps)
        assert dist.psum_floats == 64 * (1 + len(lams)), dist.psum_floats

        src = ArrayChunkSource(np.asarray(X), np.asarray(y), chunk_rows=128)
        # converge CG properly: an under-converged solve amplifies the psum
        # reassociation noise through the ill-conditioned operator
        cfg_s = dataclasses.replace(cfg, iterations=25)
        cfg_s8 = dataclasses.replace(cfg_8, iterations=25)
        est_s1, _ = falkon_fit_streaming(jax.random.PRNGKey(1), src, cfg_s)
        est_s8, _ = falkon_fit_streaming(jax.random.PRNGKey(1), src, cfg_s8)
        p1, p8 = est_s1.predict(X), est_s8.predict(X)
        rel = float(jnp.linalg.norm(p8 - p1) / jnp.linalg.norm(p1))
        assert rel < 2e-3, rel
        print("OK")
    """)


def test_mini_dryrun_train_and_decode():
    """End-to-end dry-run machinery on an 8-device mesh: pspec resolution,
    lower + compile, memory/cost analysis, HLO collective parse — the same
    code path the 512-device production dry-run uses."""
    _run("""
        import jax, jax.numpy as jnp, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import reduced_config
        from repro.configs.base import input_specs
        from repro.distributed.mesh import AxisRules, use_rules
        from repro.models import cache_pspecs, cache_specs, model_param_structs
        from repro.models.model import model_param_pspecs
        from repro.roofline.analysis import derive_roofline, memory_report
        from repro.train.steps import (TrainConfig, batch_pspecs,
                                       make_serve_step, make_train_step,
                                       train_state_pspecs, train_state_structs)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                       is_leaf=lambda x: isinstance(x, P))
        for arch in ("jamba-1.5-large-398b", "granite-moe-3b-a800m"):
            cfg = dataclasses.replace(reduced_config(arch), remat="full",
                                      fsdp=True)
            rules = AxisRules(mesh=mesh, fsdp=True)
            with mesh, use_rules(rules):
                # train cell
                tcfg = TrainConfig(microbatch=2)
                step = make_train_step(cfg, tcfg)
                ss = train_state_structs(cfg, tcfg)
                sp = train_state_pspecs(cfg, tcfg, rules)
                bstructs = {
                    "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
                bp = batch_pspecs(cfg, bstructs, rules)
                comp = jax.jit(step, in_shardings=(named(sp), named(bp)),
                               donate_argnums=(0,)).lower(ss, bstructs).compile()
                roof = derive_roofline(comp, chips=8, model_flops=1.0)
                assert roof.flops_per_device > 0
                assert memory_report(comp)["total_per_device"] > 0
                # decode cell
                serve = make_serve_step(cfg)
                ps = model_param_structs(cfg)
                pp = model_param_pspecs(cfg, rules)
                cs = cache_specs(cfg, 8, 64)
                cp = cache_pspecs(cfg, 8, 64, rules)
                bs = {"token": jax.ShapeDtypeStruct((8,), jnp.int32)}
                comp2 = jax.jit(serve, in_shardings=(
                    named(pp), named(cp), named(bp := batch_pspecs(cfg, bs, rules))),
                    donate_argnums=(1,)).lower(ps, cs, bs).compile()
                assert memory_report(comp2)["total_per_device"] > 0
            print(arch, "OK")
    """)


def test_shardmap_moe_matches_local():
    """Expert-parallel (all_to_all) MoE == local-dispatch MoE numerically."""
    _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.distributed.mesh import AxisRules, use_rules
        from repro.models import layers as L
        from repro.models.params import init_params
        cfg = dataclasses.replace(reduced_config("granite-moe-3b-a800m"),
                                  n_experts=4, expert_pad_multiple=2, top_k=2,
                                  capacity_factor=4.0)
        p = init_params(jax.random.PRNGKey(0), L.moe_pd(cfg), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * .5
        ref = L._moe_local(p, x, cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = AxisRules(mesh=mesh)
        with mesh, use_rules(rules):
            got = jax.jit(lambda p, x: L.moe_apply(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print("OK")
    """)


def test_elastic_restore_across_meshes():
    """Fault tolerance: train on a (2,2,2) pod mesh, checkpoint, restore the
    same state onto a (4,2) single-pod mesh (elastic rescale), resume, and
    get bit-identical metrics to an uninterrupted run."""
    _run("""
        import os, tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import load_checkpoint, save_checkpoint, step_dir
        from repro.configs import reduced_config
        from repro.distributed.mesh import AxisRules, use_rules
        from repro.train import TrainConfig, init_train_state, make_train_step
        from repro.train.steps import train_state_pspecs

        cfg = reduced_config("qwen2-72b")
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                              0, cfg.vocab),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32),
                                              0, cfg.vocab)}
        named = lambda mesh, t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))

        mesh_a = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules_a = AxisRules(mesh=mesh_a)
        state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        with mesh_a, use_rules(rules_a):
            step = jax.jit(make_train_step(cfg, tcfg))
            state, m1 = step(state, batch)
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(step_dir(d, 1), state, 1, blocking=True)

            # restore onto a DIFFERENT mesh with its own shardings
            mesh_b = jax.make_mesh((4, 2), ("data", "model"))
            rules_b = AxisRules(mesh=mesh_b)
            shardings = named(mesh_b, train_state_pspecs(cfg, tcfg, rules_b))
            restored, stp = load_checkpoint(step_dir(d, 1), state,
                                            shardings=shardings)
            assert stp == 1
            with mesh_b, use_rules(rules_b):
                step_b = jax.jit(make_train_step(cfg, tcfg))
                _, m2 = step_b(restored, batch)

            # uninterrupted reference on mesh_a
            with mesh_a, use_rules(rules_a):
                _, m_ref = step(state, batch)
        np.testing.assert_allclose(float(m2["loss"]), float(m_ref["loss"]),
                                   rtol=2e-4)
        print("OK elastic restore", float(m2["loss"]))
    """)
