"""Precision-policy suite: bf16 end-to-end vs an fp64 oracle.

* error model, measured: every registered kernel on the fused, two-pass,
  j-sharded and streaming sweep paths stays within the documented relative
  error bound of an fp64 dense oracle — <= 1e-4 for the fp32 policy, <= 1e-2
  for end-to-end bf16 storage with compensated fp32 accumulation (storage
  quantization at eps_bf16 ~ 3.9e-3 dominates; the Kahan tile loops keep the
  summation term at O(eps_fp32)).
* fp32 stays bit-identical: the policy machinery must be a no-op on the
  default path — same arrays out of the backend as out of the raw kernels.
* CG storage contract: bf16 iterates / fp32 scalars converge, and track the
  fp32 solve on the M=32768 acceptance shape (axis-selected via
  REPRO_TEST_PRECISION — the CI precision matrix runs this file once per
  policy).
* planner: the budget model charges u/v/t at their storage dtype and the
  chosen dtypes are visible on ``SweepPlan`` (and its repr / the structured
  fallback warning).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TEST_PRECISION
from repro.compat import enable_x64
from repro.core import make_kernel, spec_of
from repro.core.cg import conjugate_gradient, conjugate_gradient_host
from repro.core.falkon import FalkonConfig, falkon_fit, falkon_fit_streaming
from repro.data import ArrayChunkSource, StreamingLoader, streaming_sweep
from repro.kernels.kernel_matvec import (
    fused_sweep_pallas, kernel_matmul_pallas, sharded_sweep_pallas
)
from repro.ops import (
    POLICIES, PrecisionPolicy, SweepPlanWarning, get_ops, resolve_precision
)

KERNELS = [
    ("gaussian", dict(sigma=1.3)),
    ("laplacian", dict(sigma=1.1)),
    ("matern32", dict(sigma=1.7)),
    ("linear", dict(scale=1.5)),
    ("polynomial", dict(degree=2, c=0.5, scale=2.0)),
]

#: Documented end-to-end relative error ceilings vs the fp64 oracle
#: (mirrored in README / benchmarks/precision_sweep.py).
ERROR_BOUND = {"fp32": 1e-4, "bf16": 1e-2}


def _data(n, M, d, p=None, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    ush = (M,) if p is None else (M, p)
    vsh = (n,) if p is None else (n, p)
    return (
        jax.random.normal(ks[0], (n, d)),
        jax.random.normal(ks[1], (M, d)),
        jax.random.normal(ks[2], ush),
        jax.random.normal(ks[3], vsh),
    )


def _oracle_sweep(kern, X, C, u, v):
    """K^T (K u + v) in float64 — the ground truth every policy is judged
    against (kernel math from the same registered formula, via __call__)."""
    with enable_x64(True):
        X64 = jnp.asarray(np.asarray(X), jnp.float64)
        C64 = jnp.asarray(np.asarray(C), jnp.float64)
        u64 = jnp.asarray(np.asarray(u), jnp.float64)
        K = kern(X64, C64)
        t = K @ u64
        if v is not None:
            t = t + jnp.asarray(np.asarray(v), jnp.float64)
        return np.asarray(K.T @ t, dtype=np.float64)


def _rel_err(got, oracle):
    got = np.asarray(got, dtype=np.float64)
    return float(np.linalg.norm(got - oracle) / np.linalg.norm(oracle))


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------
def test_policy_registry_and_overrides():
    bf16 = resolve_precision("bf16")
    assert bf16 is POLICIES["bf16"]
    assert bf16.storage == "bfloat16" and bf16.accumulate == "float32"
    assert bf16.compensated
    assert bf16.buffer_dtype("gram") == "float32"        # per-buffer override
    assert bf16.buffer_dtype("cholesky") == "float32"
    assert bf16.buffer_dtype("u") == "bfloat16"          # default: storage
    assert bf16.storage_itemsize == 2 and bf16.accumulate_itemsize == 4

    fp32 = resolve_precision("fp32")
    assert fp32.storage == "float32" and not fp32.compensated

    with pytest.raises(ValueError, match="unknown precision"):
        resolve_precision("fp8")

    # a full PrecisionPolicy is accepted wherever a name is; per-buffer
    # overrides are honored (default: coeffs float32 -> w comes back fp32;
    # an empty override set makes even the coefficients ride bf16)
    custom = PrecisionPolicy(name="bf16-raw", storage="bfloat16", compensated=False)
    ops = get_ops("jnp", make_kernel("gaussian", sigma=1.5), precision=custom)
    assert ops.policy is custom
    X, C, u, v = _data(64, 32, 5, seed=0)
    assert ops.sweep(X, C, u, v).dtype == jnp.float32
    raw = PrecisionPolicy(
        name="bf16-all", storage="bfloat16", compensated=False, overrides=()
    )
    assert raw.buffer_dtype("coeffs") == "bfloat16"
    ops_raw = get_ops("jnp", make_kernel("gaussian", sigma=1.5), precision=raw)
    assert ops_raw.sweep(X, C, u, v).dtype == jnp.bfloat16


def test_custom_reduced_policy_widens_coeffs():
    """The coeffs=float32 override must hold for ANY reduced storage dtype
    (not just bfloat16): a float16 policy's sweep still takes/returns fp32
    coefficients, and the plan reports the true dtype names."""
    f16 = PrecisionPolicy(name="f16", storage="float16", compensated=True)
    X, C, u, v = _data(96, 48, 7, seed=2)
    for impl in ("jnp", "pallas"):
        ops = get_ops(
            impl, make_kernel("gaussian", sigma=1.5), block_size=64, precision=f16
        )
        w = ops.sweep(X, C, u.astype(jnp.float16), v)
        assert w.dtype == jnp.float32, impl   # coeffs override wins
    plan = ops.plan(96, 48, 7, 1)
    assert plan.input_dtype == "float16"      # not mislabeled as bfloat16
    assert plan.vector_dtype == "float16"
    assert plan.coeffs_dtype == "float32"


# ---------------------------------------------------------------------------
# error vs the fp64 oracle — all kernels, all sweep paths
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kernel_name,params", KERNELS)
@pytest.mark.parametrize("path", ["fused", "two_pass", "j_sharded"])
def test_bf16_sweep_error_within_bound(kernel_name, params, path):
    n, M, d = 160, 96, 11
    kern = make_kernel(kernel_name, **params)
    seed = [k for k, _ in KERNELS].index(kernel_name) * 7 + 1
    X, C, u, v = _data(n, M, d, seed=seed)
    oracle = _oracle_sweep(kern, X, C, u, v)

    bf = jnp.bfloat16
    Xb, Cb, ub, vb = (a.astype(bf) for a in (X, C, u, v))
    kw = dict(spec=spec_of(kern), block_m=64, compensated=True, interpret=True)
    if path == "fused":
        got = fused_sweep_pallas(Xb, Cb, ub, vb, block_n=64, **kw)
    elif path == "two_pass":
        got = sharded_sweep_pallas(Xb, Cb, ub, vb, shard_m=M, **kw)
    else:
        got = sharded_sweep_pallas(Xb, Cb, ub, vb, shard_m=64, **kw)
    assert got.dtype == bf                   # t spill / output at half width
    assert _rel_err(got, oracle) <= ERROR_BOUND["bf16"]


@pytest.mark.parametrize("kernel_name,params", KERNELS)
def test_backend_sweep_error_both_policies(kernel_name, params):
    """The user-facing path: get_ops(...).sweep under each named policy stays
    within that policy's documented bound, for every registered kernel."""
    n, M, d = 200, 97, 9
    kern = make_kernel(kernel_name, **params)
    seed = [k for k, _ in KERNELS].index(kernel_name) * 3 + 2
    X, C, u, v = _data(n, M, d, seed=seed)
    oracle = _oracle_sweep(kern, X, C, u, v)
    for impl in ("jnp", "pallas"):
        for prec in ("fp32", "bf16"):
            got = get_ops(impl, kern, block_size=64, precision=prec).sweep(X, C, u, v)
            err = _rel_err(got, oracle)
            assert err <= ERROR_BOUND[prec], (impl, prec, err)


def test_streaming_bf16_chunk_dtype_and_error():
    """bf16 chunks cross the host->device boundary at half width and the
    chunk-accumulated sweep stays within the bf16 bound."""
    n, M, d = 300, 64, 8
    kern = make_kernel("gaussian", sigma=1.5)
    X, C, u, v = _data(n, M, d, seed=4)
    oracle = _oracle_sweep(kern, X, C, u, v)

    source = ArrayChunkSource(np.asarray(X), np.asarray(v), chunk_rows=77)
    loader = StreamingLoader(source, prefetch=0, dtype=jnp.bfloat16)
    for xc, yc in loader:
        assert xc.dtype == jnp.bfloat16 and yc.dtype == jnp.bfloat16
    ops = get_ops("jnp", kern, block_size=64, precision="bf16")
    got = streaming_sweep(ops, loader, C, u, use_targets=True)
    assert got.dtype == jnp.float32          # w at coeffs width
    assert _rel_err(got, oracle) <= ERROR_BOUND["bf16"]

    # fp32 loader + fp32 policy: chunked == in-core stays bit-exact with the
    # same block geometry (single chunk == single scan stream)
    src32 = ArrayChunkSource(np.asarray(X), np.asarray(v), chunk_rows=n)
    ld32 = StreamingLoader(src32, prefetch=0, dtype=jnp.float32)
    ops32 = get_ops("jnp", kern, block_size=64)
    np.testing.assert_array_equal(
        np.asarray(streaming_sweep(ops32, ld32, C, u, use_targets=True)),
        np.asarray(ops32.sweep(X, C, u, v)),
    )


# ---------------------------------------------------------------------------
# fp32 must stay bit-identical to the pre-policy code path
# ---------------------------------------------------------------------------
def test_fp32_path_bit_identical_to_raw_kernels():
    n, M, d = 300, 97, 13
    kern = make_kernel("gaussian", sigma=1.5)
    X, C, u, v = _data(n, M, d, seed=6)

    pops = get_ops("pallas", kern, block_size=128)
    raw = fused_sweep_pallas(
        X, C, u, v, spec=spec_of(kern), block_m=128, compensated=False, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(pops.sweep(X, C, u, v)), np.asarray(raw))

    # string name and explicit policy object resolve to the same arrays
    pol = PrecisionPolicy(name="fp32")
    np.testing.assert_array_equal(
        np.asarray(get_ops("jnp", kern, block_size=64).sweep(X, C, u, v)),
        np.asarray(get_ops("jnp", kern, block_size=64,
                           precision=pol).sweep(X, C, u, v)))


def test_compensated_accumulation_not_worse_than_plain():
    """Kahan two-sum must never lose to plain fp32 accumulation (and both
    sit under the fp32 bound) — many j tiles so the reduction is long."""
    m, n, d, p = 64, 4096, 7, 2
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    A = jax.random.normal(ks[0], (m, d))
    B = jax.random.normal(ks[1], (n, d))
    V = jax.random.normal(ks[2], (n, p))
    kern = make_kernel("gaussian", sigma=1.5)
    with enable_x64(True):
        K64 = kern(
            jnp.asarray(np.asarray(A), jnp.float64),
            jnp.asarray(np.asarray(B), jnp.float64),
        )
        oracle = np.asarray(K64 @ jnp.asarray(np.asarray(V), jnp.float64))

    kw = dict(spec=spec_of(kern), block_m=64, block_n=128, interpret=True)
    plain = kernel_matmul_pallas(A, B, V, compensated=False, **kw)
    comp = kernel_matmul_pallas(A, B, V, compensated=True, **kw)
    e_plain, e_comp = _rel_err(plain, oracle), _rel_err(comp, oracle)
    assert e_comp <= ERROR_BOUND["fp32"]
    assert e_comp <= e_plain * 1.5 + 1e-12, (e_comp, e_plain)


# ---------------------------------------------------------------------------
# CG storage contract
# ---------------------------------------------------------------------------
def _spd_system(q=96, p=2, seed=9):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    Q = jax.random.normal(ks[0], (q, q)) / np.sqrt(q)
    A = Q @ Q.T + 0.5 * jnp.eye(q)
    b = jax.random.normal(ks[1], (q, p))
    return A, b


@pytest.mark.parametrize("driver", [conjugate_gradient, conjugate_gradient_host])
def test_cg_bf16_storage_converges_with_fp32_scalars(driver):
    A, b = _spd_system()
    mv = lambda x: A @ x.astype(jnp.float32)
    res32 = driver(mv, b, 40, storage_dtype=None)
    resbf = driver(mv, b, 40, storage_dtype=jnp.bfloat16)
    assert resbf.x.dtype == jnp.bfloat16          # iterates at storage width
    assert resbf.residual_norms.dtype == jnp.float32   # scalars stay fp32
    r32 = np.linalg.norm(np.asarray(A @ res32.x.astype(jnp.float32) - b))
    rbf = np.linalg.norm(np.asarray(A @ resbf.x.astype(jnp.float32) - b))
    bn = np.linalg.norm(np.asarray(b))
    assert r32 / bn < 1e-5
    # bf16 iterate-rounding floor: ~ O(sqrt(cond) * eps_bf16) relative
    assert rbf / bn < 3e-2
    # storage_dtype float32 is the same arithmetic as None (no-op casts)
    res32b = driver(mv, b, 40, storage_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(res32.x), np.asarray(res32b.x))


def test_cg_convergence_parity_on_acceptance_shape():
    """CG on the normal-equation operator at the M=32768 acceptance point:
    the axis policy (REPRO_TEST_PRECISION) must track the fp32 solve."""
    n, M, d = 256, 32768, 7
    kern = make_kernel("gaussian", sigma=1.5)
    X, C, u0, y = _data(n, M, d, seed=11)
    # strongly regularized so 10 plain-CG iterations converge in fp32 — the
    # point here is the precision PARITY of the trajectory, not CG speed on
    # an ill-conditioned normal operator (falkon's preconditioner covers
    # that; this test runs the raw sweep at the acceptance shape).
    lam = 8.0

    def solve(prec):
        ops = get_ops("jnp", kern, block_size=4096, precision=prec)
        mv = lambda g: (ops.sweep(X, C, g, None).astype(jnp.float32) / n
                        + lam * g.astype(jnp.float32))
        b = ops.sweep(X, C, jnp.zeros_like(u0), y).astype(jnp.float32) / n
        storage = jnp.bfloat16 if prec == "bf16" else None
        return conjugate_gradient(mv, b, 10, storage_dtype=storage)

    ref = solve("fp32")
    got = solve(TEST_PRECISION)
    r_ref = float(ref.residual_norms[-1] / ref.residual_norms[0])
    r_got = float(got.residual_norms[-1] / got.residual_norms[0])
    assert r_ref < 1e-3                       # fp32 CG converges on this case
    if TEST_PRECISION == "fp32":
        np.testing.assert_array_equal(np.asarray(got.x), np.asarray(ref.x))
    else:
        assert r_got < 3e-2, r_got            # bf16 iterate rounding floor
        rel = _rel_err(got.x.astype(jnp.float32), np.asarray(ref.x, dtype=np.float64))
        assert rel < 5e-2, rel


# ---------------------------------------------------------------------------
# end-to-end fits under the axis policy
# ---------------------------------------------------------------------------
def test_falkon_fit_parity_under_axis_policy(rng):
    from conftest import synthetic_regression
    X, y = synthetic_regression(rng, 384)
    base = dict(
        kernel="gaussian",
        kernel_params=(("sigma", 2.0),),
        lam=1e-4,
        num_centers=64,
        iterations=25,
        block_size=128,
    )
    est_ref, _ = falkon_fit(
        jax.random.PRNGKey(1), X, y, FalkonConfig(**base, ops_impl="jnp")
    )
    est, _ = falkon_fit(
        jax.random.PRNGKey(1),
        X,
        y,
        FalkonConfig(**base, ops_impl="pallas", precision=TEST_PRECISION),
    )
    p_ref, p = est_ref.predict(X), est.predict(X)
    rel = float(jnp.linalg.norm(p.astype(jnp.float32) - p_ref) / jnp.linalg.norm(p_ref))
    assert rel < (5e-2 if TEST_PRECISION == "bf16" else 2e-3), rel


def test_falkon_fit_streaming_parity_under_axis_policy(rng):
    from conftest import synthetic_regression
    X, y = synthetic_regression(rng, 400)
    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", 2.0),),
        lam=1e-4,
        num_centers=48,
        iterations=20,
        block_size=128,
        precision=TEST_PRECISION,
    )
    centers = np.asarray(X[:48])
    est_in, _ = falkon_fit(
        jax.random.PRNGKey(2),
        X,
        y,
        dataclasses.replace(cfg, center_selection="uniform"),
    )
    source = ArrayChunkSource(np.asarray(X), np.asarray(y), chunk_rows=97)
    est_st, _ = falkon_fit_streaming(
        jax.random.PRNGKey(2), source, cfg, centers=jnp.asarray(centers)
    )
    p_in = est_in.predict(X)
    p_st = est_st.predict(X)
    # different centers -> only sanity-level agreement is meaningful; the
    # strong check is that the streamed fit converged under the policy
    assert np.isfinite(np.asarray(p_st, dtype=np.float64)).all()
    rel = float(jnp.linalg.norm(p_st.astype(jnp.float32) - y) / jnp.linalg.norm(y))
    rel_in = float(jnp.linalg.norm(p_in.astype(jnp.float32) - y) / jnp.linalg.norm(y))
    assert rel < max(2 * rel_in, 0.5), (rel, rel_in)


# ---------------------------------------------------------------------------
# planner: storage-dtype budget model + dtypes on the plan
# ---------------------------------------------------------------------------
def test_plan_carries_dtypes_and_charges_storage():
    kern = make_kernel("gaussian", sigma=2.0)
    p32 = get_ops("pallas", kern, block_size=128).plan(4096, 2048, 32, 1)
    pbf = get_ops("pallas", kern, block_size=128, precision="bf16").plan(
        4096, 2048, 32, 1
    )
    assert p32.vector_dtype == "float32" and not p32.compensated
    assert pbf.input_dtype == "bfloat16"
    assert pbf.vector_dtype == "bfloat16"           # data-space v/t storage
    assert pbf.coeffs_dtype == "float32"            # u/w stay wide
    assert pbf.accum_dtype == "float32" and pbf.compensated
    assert "bfloat16" in repr(pbf)                  # dtypes visible in repr
    # X/C and v io tiles charged at storage width: bf16 io strictly smaller
    assert pbf.io_bytes < p32.io_bytes
    # compensation carry buffers charged in scratch
    assert pbf.scratch_bytes > p32.scratch_bytes
    # the HBM working set approaches the full 2x as n-sized terms dominate
    big32 = get_ops("pallas", kern, block_size=128).plan(262144, 2048, 32, 1)
    bigbf = get_ops("pallas", kern, block_size=128, precision="bf16").plan(
        262144, 2048, 32, 1
    )
    assert big32.hbm_bytes / bigbf.hbm_bytes >= 1.8


def test_sweep_plan_warning_carries_policy_dtypes():
    kern = make_kernel("gaussian", sigma=1.5)
    pops = get_ops("pallas", kern, block_size=128, precision="bf16")
    X, C, u, v = _data(64, 32768, 5, seed=3)
    with pytest.warns(SweepPlanWarning) as rec:
        got = pops.sweep(X, C, u, v)
    plan = rec[0].message.plan
    assert plan.vector_dtype == "bfloat16" and plan.compensated
    assert plan.coeffs_dtype == "float32"
    assert got.dtype == jnp.float32          # w at coeffs width
