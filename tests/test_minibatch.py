"""Mini-batch delayed-projection solver + partial_fit contract tests.

The invariants pinned here (and leaned on by BENCH_minibatch.json's CI
gate):

* one chunk-sized ``ops.sweep`` per stochastic step — exactly, counted
  eagerly through `CountingOps` with ``jit_update=False``;
* the in-core `lax.scan` driver and the host-driven streaming driver are
  the SAME update rule (parity when shuffling is off);
* a projection period covering the whole dataset degenerates to full-batch
  preconditioned gradient descent, so an exact solve is a fixed point —
  the property `partial_fit` warm starts ride on;
* `partial_fit` returns a same-geometry estimator (zero serve retraces
  across a hot `swap_model`) whose quality tracks a from-scratch fit on
  the concatenated data;
* `Preconditioner.beta_of_coeffs` inverts `coeffs` (the warm-start
  pullback);
* `ShuffledChunkSource` emits every row exactly once per pass, reshuffled
  across passes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FalkonConfig,
    MinibatchConfig,
    falkon_fit,
    falkon_fit_minibatch,
    falkon_fit_minibatch_streaming,
    make_preconditioner,
    minibatch_solve,
    minibatch_solve_stream,
)
from repro.data import ArrayChunkSource, ShuffledChunkSource, StreamingLoader
from repro.ops import CountingOps, get_ops
from repro.serve import CoalescingPredictServer

SIGMA = 2.0


def _problem(n, d=6, seed=0):
    """Learnable regression (val MSE far below var(y) after a good fit)."""
    kx, ky, kf = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(kx, (n, d))
    w = jax.random.normal(kf, (d,))
    w = 1.2 * w / jnp.linalg.norm(w)

    def f(Z):
        return jnp.sin(Z @ w) + 0.5 * jnp.cos(0.6 * Z[:, 0] * Z[:, 1])

    y = f(X) + 0.05 * jax.random.normal(ky, (n,))
    Xv = jax.random.normal(jax.random.PRNGKey(seed + 9), (1024, d))
    return X, y, Xv, f(Xv)


def _config(M=128, lam=1e-4, iterations=20):
    return FalkonConfig(
        kernel_params=(("sigma", SIGMA),),
        lam=lam,
        num_centers=M,
        iterations=iterations,
        ops_impl="jnp",
        estimate_cond=False,
    )


def _mse(pred, y):
    return float(jnp.mean((pred - y) ** 2))


# ---------------------------------------------------------------------------
# convergence + the degenerate full-batch case
# ---------------------------------------------------------------------------
def test_minibatch_reaches_full_cg_quality():
    X, y, Xv, yv = _problem(4096)
    cfg = _config()
    est_full, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
    mse_full = _mse(est_full.predict(Xv), yv)

    mb = MinibatchConfig(chunk_rows=512, project_every=2, epochs=8)
    est_mb, result = falkon_fit_minibatch(
        jax.random.PRNGKey(1), X, y, cfg, mb, centers=est_full.centers
    )
    mse_mb = _mse(est_mb.predict(Xv), yv)
    assert mse_full < 0.1 * float(jnp.var(yv))  # the task is learnable
    assert mse_mb < 1.5 * mse_full
    # the projected-gradient trace is the solver's residual history: the
    # late-phase gradient must sit well below the first projection's.
    gn = np.asarray(result.grad_norms)
    assert gn[-1] < 0.2 * gn[0]


def test_full_batch_period_is_fixed_point_of_exact_solve():
    # project_every * chunk_rows >= n makes the accumulated gradient exact,
    # so the delayed-projection rule degenerates to preconditioned GD and a
    # converged CG solution must (approximately) stay put.
    X, y, Xv, _ = _problem(2048)
    cfg = _config(iterations=40)
    est, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)

    mb = MinibatchConfig(
        chunk_rows=X.shape[0],
        project_every=1,
        epochs=3,
        momentum=0.0,
        avg_start=1.0,
        shuffle=False,
    )
    refreshed = est.partial_fit(X, y, mb)
    before = np.asarray(est.predict(Xv))
    after = np.asarray(refreshed.predict(Xv))
    scale = float(np.max(np.abs(before)))
    assert np.max(np.abs(after - before)) < 1e-3 * scale


# ---------------------------------------------------------------------------
# the cost model: one chunk-sized sweep per step, exactly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [2048, 1800])  # divisible + ragged tail
def test_one_chunk_sweep_per_step_exactly(n):
    chunk = 512
    X, y, _, _ = _problem(n)
    cfg = _config(M=64)
    kern = cfg.make_kernel()
    ops = CountingOps(get_ops("jnp", kern, block_size=cfg.block_size))
    centers = X[:64]
    precond = make_preconditioner(ops.gram(centers, centers), cfg.lam, n)

    mb = MinibatchConfig(
        chunk_rows=chunk,
        project_every=2,
        epochs=2,
        power_iters=3,
        shuffle=False,
    )
    loader = StreamingLoader(
        ArrayChunkSource(np.asarray(X), np.asarray(y), chunk_rows=chunk),
        prefetch=0,
    )
    before = ops.sweeps
    result = minibatch_solve_stream(
        loader, centers, precond, cfg.lam, mb, ops=ops, jit_update=False
    )
    num_chunks = -(-n // chunk)
    steps = mb.epochs * num_chunks
    assert int(result.state.step) == steps
    # pilot power iterations + one sweep per stochastic step — EXACTLY.
    assert ops.sweeps - before == mb.power_iters + steps
    # every sweep moved exactly one (padded) chunk of rows.
    assert result.rows_swept == float((mb.power_iters + steps) * chunk)


def test_scan_and_stream_drivers_agree():
    n, chunk = 2048, 512
    X, y, _, _ = _problem(n)
    cfg = _config(M=64)
    kern = cfg.make_kernel()
    ops = get_ops("jnp", kern, block_size=cfg.block_size)
    centers = X[:64]
    precond = make_preconditioner(ops.gram(centers, centers), cfg.lam, n)

    mb = MinibatchConfig(
        chunk_rows=chunk,
        project_every=2,
        epochs=2,
        step_size=0.05,
        shuffle=False,
    )
    r_scan = minibatch_solve(
        X, y, centers, precond, cfg.lam, mb, ops=ops, key=jax.random.PRNGKey(0)
    )
    loader = StreamingLoader(
        ArrayChunkSource(np.asarray(X), np.asarray(y), chunk_rows=chunk),
        prefetch=0,
    )
    r_stream = minibatch_solve_stream(loader, centers, precond, cfg.lam, mb, ops=ops)
    # same update rule, different compilation (one lax.scan vs per-chunk
    # jitted calls): only fp32 accumulation-order drift may separate them.
    np.testing.assert_allclose(
        np.asarray(r_scan.alpha),
        np.asarray(r_stream.alpha),
        rtol=5e-3,
        atol=5e-3,
    )
    np.testing.assert_allclose(
        np.asarray(r_scan.grad_norms),
        np.asarray(r_stream.grad_norms),
        rtol=5e-3,
        atol=1e-6,
    )


def test_streaming_fit_matches_incore_fit_quality():
    n = 2048
    X, y, Xv, yv = _problem(n)
    cfg = _config(M=64, iterations=10)
    mb = MinibatchConfig(chunk_rows=512, project_every=2, epochs=4)
    est_in, _ = falkon_fit_minibatch(jax.random.PRNGKey(1), X, y, cfg, mb)
    source = ArrayChunkSource(np.asarray(X), np.asarray(y), chunk_rows=512)
    est_st, result = falkon_fit_minibatch_streaming(
        jax.random.PRNGKey(1), source, cfg, mb
    )
    assert est_st.alpha.shape == est_in.alpha.shape
    mse_in = _mse(est_in.predict(Xv), yv)
    mse_st = _mse(est_st.predict(Xv), yv)
    assert mse_st < 2.0 * mse_in + 1e-3
    assert int(result.state.projections) == len(result.grad_norms)


# ---------------------------------------------------------------------------
# partial_fit: warm start, quality, zero-retrace serving swap
# ---------------------------------------------------------------------------
def test_partial_fit_tracks_concat_refit():
    X, y, Xv, yv = _problem(3072)
    X0, y0 = X[:2048], y[:2048]
    cfg = _config()
    est0, _ = falkon_fit(jax.random.PRNGKey(1), X0, y0, cfg)

    mb = MinibatchConfig(chunk_rows=512, project_every=2, epochs=4)
    est1 = est0.partial_fit(X[2048:], y[2048:], mb)
    # geometry contract: same centers object, same alpha shape/dtype.
    assert est1.centers is est0.centers
    assert est1.alpha.shape == est0.alpha.shape
    assert est1.alpha.dtype == est0.alpha.dtype

    est_cat, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
    mse_cat = _mse(est_cat.predict(Xv), yv)
    mse_tail = _mse(est1.predict(Xv), yv)
    mse_before = _mse(est0.predict(Xv), yv)
    # the refreshed model stays in the from-scratch fit's quality band and
    # does not regress the deployed model.
    assert mse_tail < 2.0 * mse_cat
    assert mse_tail < 1.5 * mse_before


def test_partial_fit_requires_fit_time_preconditioner():
    X, y, _, _ = _problem(512)
    cfg = _config(M=64, iterations=5)
    est, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
    import dataclasses

    bare = dataclasses.replace(est, precond=None, lam=None)
    with pytest.raises(ValueError, match="preconditioner"):
        bare.partial_fit(X[:128], y[:128])


def test_partial_fit_swap_serves_with_zero_retraces():
    X, y, _, _ = _problem(2048)
    cfg = _config(M=64, iterations=10)
    est, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)

    server = CoalescingPredictServer(est, max_batch=64)
    server.warmup()
    reqs = [np.asarray(X[i : i + 13], np.float32) for i in (0, 40, 80)]
    server.predict_many(reqs)
    assert server.retraces_since_warmup() == 0

    mb = MinibatchConfig(chunk_rows=256, project_every=2, epochs=2)
    est2 = est.partial_fit(X[1024:], y[1024:], mb)
    server.swap_model(est2)
    outs = server.predict_many(reqs)
    assert server.retraces_since_warmup() == 0  # the whole point
    for xb, out in zip(reqs, outs):
        np.testing.assert_allclose(
            out, np.asarray(est2.predict(jnp.asarray(xb))), atol=1e-5
        )


def test_swap_model_refuses_different_geometry():
    X, y, _, _ = _problem(1024)
    est_a, _ = falkon_fit(jax.random.PRNGKey(1), X, y, _config(M=64))
    est_b, _ = falkon_fit(jax.random.PRNGKey(1), X, y, _config(M=128))
    server = CoalescingPredictServer(est_a, max_batch=32)
    server.warmup()
    with pytest.raises(ValueError, match="geometry"):
        server.swap_model(est_b)


def test_beta_of_coeffs_inverts_coeffs():
    X, _, _, _ = _problem(1024)
    cfg = _config(M=64)
    kern = cfg.make_kernel()
    ops = get_ops("jnp", kern, block_size=cfg.block_size)
    centers = X[:64]
    precond = make_preconditioner(ops.gram(centers, centers), cfg.lam, X.shape[0])
    beta = jax.random.normal(jax.random.PRNGKey(3), (precond.q,))
    alpha = precond.coeffs(beta)
    np.testing.assert_allclose(
        np.asarray(precond.beta_of_coeffs(alpha)),
        np.asarray(beta),
        rtol=2e-3,
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# config validation + epoch reshuffling
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "kw",
    [
        dict(chunk_rows=0),
        dict(project_every=-1),
        dict(epochs=0),
        dict(step_size=0.0),
        dict(step_safety=2.5),
        dict(power_iters=0),
        dict(momentum=1.0),
        dict(avg_start=1.5),
        dict(tol=-1e-3),
    ],
)
def test_minibatch_config_rejects(kw):
    with pytest.raises(ValueError):
        MinibatchConfig(**kw)


def test_shuffled_chunk_source_permutes_without_loss():
    n, d = 300, 4
    X = np.arange(n * d, dtype=np.float32).reshape(n, d)
    y = np.arange(n, dtype=np.float32)
    base = ArrayChunkSource(X, y, chunk_rows=64)
    src = ShuffledChunkSource(base, seed=5, buffer_chunks=3)
    assert (src.n_rows, src.dim, src.chunk_rows) == (n, d, 64)

    def collect():
        xs, ys = [], []
        for xc, yc in src.chunks():
            assert xc.shape[0] == yc.shape[0]
            xs.append(xc)
            ys.append(yc)
        return np.concatenate(xs), np.concatenate(ys)

    x1, y1 = collect()
    x2, y2 = collect()
    # every row exactly once per pass, rows aligned with their targets...
    for xp, yp in ((x1, y1), (x2, y2)):
        order = np.argsort(yp)
        np.testing.assert_array_equal(yp[order], y)
        np.testing.assert_array_equal(xp[order], X)
    # ...in a genuinely shuffled and per-pass re-seeded order.
    assert not np.array_equal(y1, y)
    assert not np.array_equal(y1, y2)


def test_shuffled_chunk_source_rejects_bad_buffer():
    base = ArrayChunkSource(np.zeros((8, 2), np.float32), chunk_rows=4)
    with pytest.raises(ValueError):
        ShuffledChunkSource(base, buffer_chunks=0)
