"""Correctness of the FALKON core against the paper's own claims.

Keyed to the paper:
* Lemma 5  — FALKON with enough CG iterations equals the exact Nystrom
             estimator (Eq. 8).
* Thm 1/2  — cond(B^T H B) is small once M is large enough, and the gap to the
             Nystrom estimator decays exponentially in t.
* Thm 3    — with lam = n^{-1/2}, M = c sqrt(n), t = O(log n), FALKON matches
             exact KRR accuracy.
* Appendix A — the general preconditioner (rank-deficient K_MM, leverage-score
             D) still converges to the exact Nystrom solution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import synthetic_regression
from repro.compat import enable_x64
from repro.core import (
    FalkonConfig,
    GaussianKernel,
    conjugate_gradient,
    exact_leverage_scores,
    approximate_leverage_scores,
    falkon_fit,
    falkon_solve,
    knm_apply,
    knm_matvec,
    krr_direct,
    make_preconditioner,
    nystrom_direct,
    nystrom_gradient,
    uniform_centers,
)


def _fit(X, y, **kw):
    defaults = dict(
        kernel="gaussian",
        kernel_params=(("sigma", 2.0),),
        lam=1e-5,
        num_centers=300,
        iterations=40,
        block_size=256,
    )
    defaults.update(kw)
    cfg = FalkonConfig(**defaults)
    return falkon_fit(jax.random.PRNGKey(1), X, y, cfg) + (cfg,)


# ---------------------------------------------------------------------------
# Blocked matvec == dense matvec
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block_size", [64, 100, 256, 1500])
def test_blocked_matvec_matches_dense(rng, block_size):
    X, y = synthetic_regression(rng, 777)
    kern = GaussianKernel(sigma=1.5)
    C = X[:93]
    u = jax.random.normal(jax.random.PRNGKey(7), (93,))
    KnM = kern(X, C)
    expect = KnM.T @ (KnM @ u + y)
    got = knm_matvec(X, C, u, y, kern, block_size=block_size)
    np.testing.assert_allclose(got, expect, rtol=2e-4, atol=2e-3)


def test_blocked_matvec_multirhs(rng):
    X, _ = synthetic_regression(rng, 300)
    kern = GaussianKernel(sigma=1.5)
    C = X[:50]
    U = jax.random.normal(jax.random.PRNGKey(3), (50, 4))
    V = jax.random.normal(jax.random.PRNGKey(4), (300, 4))
    KnM = kern(X, C)
    expect = KnM.T @ (KnM @ U + V)
    got = knm_matvec(X, C, U, V, kern, block_size=128)
    np.testing.assert_allclose(got, expect, rtol=2e-4, atol=2e-3)


def test_knm_apply_matches_dense(rng):
    X, _ = synthetic_regression(rng, 311)
    kern = GaussianKernel(sigma=1.5)
    C = X[:40]
    u = jax.random.normal(jax.random.PRNGKey(5), (40,))
    np.testing.assert_allclose(
        knm_apply(X, C, u, kern, block_size=100), kern(X, C) @ u, rtol=2e-4, atol=2e-3
    )


# ---------------------------------------------------------------------------
# CG
# ---------------------------------------------------------------------------
def test_cg_solves_spd_system(rng):
    A0 = jax.random.normal(rng, (40, 40))
    A = A0 @ A0.T + 40 * jnp.eye(40)
    b = jax.random.normal(jax.random.PRNGKey(2), (40,))
    res = conjugate_gradient(lambda v: A @ v, b, t=40)
    np.testing.assert_allclose(res.x, jnp.linalg.solve(A, b), rtol=1e-3, atol=1e-4)
    assert res.residual_norms[-1] < 1e-3 * res.residual_norms[0]


def test_cg_multirhs_matches_percolumn(rng):
    A0 = jax.random.normal(rng, (30, 30))
    A = A0 @ A0.T + 30 * jnp.eye(30)
    B = jax.random.normal(jax.random.PRNGKey(2), (30, 3))
    res = conjugate_gradient(lambda v: A @ v, B, t=30)
    for j in range(3):
        col = conjugate_gradient(lambda v: A @ v, B[:, j], t=30)
        np.testing.assert_allclose(res.x[:, j], col.x, rtol=1e-3, atol=1e-4)


def test_cg_tol_freezes_converged_state(rng):
    A0 = jax.random.normal(rng, (20, 20))
    A = A0 @ A0.T + 20 * jnp.eye(20)
    b = jax.random.normal(jax.random.PRNGKey(2), (20,))
    res = conjugate_gradient(lambda v: A @ v, b, t=200, tol=1e-5)
    assert int(res.iterations) < 200  # stopped early (masked no-ops)
    np.testing.assert_allclose(res.x, jnp.linalg.solve(A, b), rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# Lemma 5: FALKON -> exact Nystrom estimator
# ---------------------------------------------------------------------------
def test_falkon_converges_to_nystrom(rng):
    with enable_x64(True):
        X, y = synthetic_regression(rng, 1200, dtype=jnp.float64)
        est, state, cfg = _fit(X, y, iterations=60, dtype="float64")
        ny = nystrom_direct(X, y, est.centers, cfg.make_kernel(), cfg.lam, jitter=0.0)
        pred_f, pred_n = est.predict(X), ny.predict(X)
        rel = jnp.linalg.norm(pred_f - pred_n) / jnp.linalg.norm(pred_n)
        assert float(rel) < 1e-5, f"Lemma 5 violated: rel={float(rel):.2e}"


def test_falkon_rank_deficient_path(rng):
    """Appendix A: duplicated centers => singular K_MM; eig path still works."""
    with enable_x64(True):
        X, y = synthetic_regression(rng, 600, dtype=jnp.float64)
        # force duplicates: tile a small set of rows
        Xd = jnp.concatenate([X[:550], X[:50]], axis=0)
        yd = jnp.concatenate([y[:550], y[:50]], axis=0)
        est, state, cfg = _fit(
            Xd, yd, num_centers=200, iterations=60, rank_deficient=True, dtype="float64"
        )
        assert jnp.all(jnp.isfinite(est.alpha))
        mse = float(jnp.mean((est.predict(Xd) - yd) ** 2))
        assert mse < 0.3


def test_falkon_leverage_scores_path(rng):
    with enable_x64(True):
        X, y = synthetic_regression(rng, 800, dtype=jnp.float64)
        est, state, cfg = _fit(
            X,
            y,
            num_centers=250,
            iterations=60,
            lam=1e-4,
            center_selection="leverage",
            dtype="float64",
        )
        assert jnp.all(jnp.isfinite(est.alpha))
        # Thm 4: conditioning under leverage sampling is controlled too
        assert float(state.cond_estimate) < 100.0
        mse = float(jnp.mean((est.predict(X) - y) ** 2))
        assert mse < 0.3


# ---------------------------------------------------------------------------
# Thm 1/2: conditioning and exponential decay in t
# ---------------------------------------------------------------------------
def test_preconditioner_conditioning_improves_with_M(rng):
    with enable_x64(True):
        X, y = synthetic_regression(rng, 1000, dtype=jnp.float64)
        conds = []
        for M in (20, 100, 400):
            est, state, cfg = _fit(
                X, y, num_centers=M, iterations=5, lam=1e-4, dtype="float64"
            )
            conds.append(float(state.cond_estimate))
        # cond(W) -> small constant as M grows (Thm 2: ~17 suffices for nu>=1/2)
        assert conds[-1] < conds[0] + 1e-6
        assert conds[-1] < 30.0


def test_exponential_decay_in_iterations(rng):
    """Gap to the exact Nystrom estimator decays ~exponentially in t (Thm 1)."""
    with enable_x64(True):
        X, y = synthetic_regression(rng, 1000, dtype=jnp.float64)
        cfg = FalkonConfig(
            kernel="gaussian",
            kernel_params=(("sigma", 2.0),),
            lam=1e-4,
            num_centers=300,
            iterations=1,
            block_size=256,
            dtype="float64",
        )
        kern = cfg.make_kernel()
        sel = uniform_centers(jax.random.PRNGKey(1), X, 300)
        ny = nystrom_direct(X, y, sel.centers, kern, cfg.lam, jitter=0.0)
        KMM = kern(sel.centers, sel.centers)
        pre = make_preconditioner(KMM, cfg.lam, X.shape[0])
        gaps = []
        for t in (2, 5, 10, 20):
            st = falkon_solve(X, y, sel.centers, pre, kern, cfg.lam, t, block_size=256)
            gaps.append(float(jnp.linalg.norm(st.alpha - ny.alpha)))
        assert gaps[1] < gaps[0] and gaps[2] < gaps[1] and gaps[3] < gaps[2]
        # at least geometric decay with rate ~e^{-1/2} per iteration on average
        assert gaps[3] < gaps[0] * np.exp(-0.5 * (20 - 2) / 2)


# ---------------------------------------------------------------------------
# Thm 3: matches exact KRR accuracy at paper hyperparameters
# ---------------------------------------------------------------------------
def test_falkon_matches_krr_accuracy(rng):
    X, y = synthetic_regression(rng, 2000)
    Xte, yte = synthetic_regression(jax.random.PRNGKey(99), 500)
    n = X.shape[0]
    lam = 1.0 / np.sqrt(n)
    M = int(3 * np.sqrt(n))
    est, state, cfg = _fit(X, y, lam=lam, num_centers=M, iterations=int(np.log(n) * 3))
    kern = cfg.make_kernel()
    kr = krr_direct(X, y, kern, lam)
    mse_f = float(jnp.mean((est.predict(Xte) - yte) ** 2))
    mse_k = float(jnp.mean((kr.predict(Xte) - yte) ** 2))
    assert mse_f < mse_k * 1.1 + 1e-3, (mse_f, mse_k)


def test_falkon_beats_unpreconditioned_gradient(rng):
    """The point of the paper: at equal iteration budget, preconditioned CG
    beats plain gradient descent on the Nystrom problem."""
    with enable_x64(True):
        X, y = synthetic_regression(rng, 1500, dtype=jnp.float64)
        t = 15
        est, state, cfg = _fit(
            X, y, lam=1e-4, num_centers=300, iterations=t, dtype="float64"
        )
        kern = cfg.make_kernel()
        ny_gd = nystrom_gradient(X, y, est.centers, kern, cfg.lam, t=t, block_size=256)
        ny_exact = nystrom_direct(X, y, est.centers, kern, cfg.lam, jitter=0.0)
        gap_falkon = float(jnp.linalg.norm(est.predict(X) - ny_exact.predict(X)))
        gap_gd = float(jnp.linalg.norm(ny_gd.predict(X) - ny_exact.predict(X)))
        assert gap_falkon < 0.1 * gap_gd, (gap_falkon, gap_gd)


# ---------------------------------------------------------------------------
# Leverage scores
# ---------------------------------------------------------------------------
def test_approximate_leverage_scores_close_to_exact(rng):
    with enable_x64(True):
        X, _ = synthetic_regression(rng, 400, dtype=jnp.float64)
        kern = GaussianKernel(sigma=2.0)
        lam = 1e-3
        exact = exact_leverage_scores(X, kern, lam)
        approx = approximate_leverage_scores(
            jax.random.PRNGKey(0), X, kern, lam, pilot_size=300, block_size=128
        )
        # q-approximation (Def. 1) with a generous q; also rank correlation
        ratio = approx / exact
        assert float(jnp.median(ratio)) > 0.2 and float(jnp.median(ratio)) < 5.0
        corr = np.corrcoef(np.asarray(exact), np.asarray(approx))[0, 1]
        assert corr > 0.9


def test_multiclass_solve(rng):
    """Multiclass (one-vs-all): CG over (M, p) rhs — the TIMIT/IMAGENET path."""
    X, _ = synthetic_regression(rng, 900)
    labels = jnp.argmax(jax.random.normal(jax.random.PRNGKey(5), (900, 4)), -1)
    Y = jax.nn.one_hot(labels, 4)
    est, state, cfg = _fit(X, Y, num_centers=200, iterations=25, lam=1e-4)
    pred = est.predict(X)
    assert pred.shape == (900, 4)
    acc = float(jnp.mean(jnp.argmax(pred, -1) == labels))
    # Memorizing RANDOM 4-way labels with M=200 centers on n=900 points is
    # capacity-limited: the converged FALKON solution reaches ~0.49 here
    # (and beats the fp32 exact-Nystrom direct solve, ~0.37, on the same
    # centers). Assert "far above 25% chance", not an arbitrary memorization
    # level that depends on the PRNG stream.
    assert acc > 0.45


def test_jit_falkon_solve(rng):
    """The whole solve lowers + compiles + runs under jit (dry-run substrate)."""
    X, y = synthetic_regression(rng, 512)
    cfg = FalkonConfig(
        lam=1e-4,
        num_centers=128,
        iterations=10,
        block_size=128,
        kernel_params=(("sigma", 2.0),),
    )
    kern = cfg.make_kernel()
    sel = uniform_centers(jax.random.PRNGKey(1), X, 128)
    KMM = kern(sel.centers, sel.centers)
    pre = make_preconditioner(KMM, cfg.lam, X.shape[0])
    fn = jax.jit(lambda X, y: falkon_solve(X, y, sel.centers, pre, kern,
                                           cfg.lam, 10, block_size=128).alpha)
    alpha = fn(X, y)
    assert alpha.shape == (128,) and bool(jnp.all(jnp.isfinite(alpha)))
