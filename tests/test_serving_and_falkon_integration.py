"""Integration tests: serving loop, FALKON-head-on-features, Pallas-kernel
preconditioner path, and benchmark-module smoke."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import (FalkonConfig, GaussianKernel, falkon_fit, make_preconditioner)
from repro.kernels.ops import pairwise_kernel
from repro.models import decode_step, model_params, prefill
from repro.models.model import _backbone


def test_prefill_then_generate_loop():
    cfg = reduced_config("qwen2-72b")
    params = model_params(jax.random.PRNGKey(0), cfg)
    B, P, G = 2, 12, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    logits, cache = prefill(params, cfg, {"tokens": toks}, S_max=P + G)
    assert int(cache["pos"]) == P
    outs = []
    tok = jnp.argmax(logits, -1)
    for _ in range(G):
        logits, cache = decode_step(params, cfg, cache, {"token": tok})
        tok = jnp.argmax(logits, -1)
        outs.append(tok)
    assert int(cache["pos"]) == P + G
    assert all(bool(jnp.all((t >= 0) & (t < cfg.padded_vocab))) for t in outs)


def test_falkon_head_on_backbone_features():
    """The paper's IMAGENET recipe: kernel head on frozen deep features."""
    cfg = reduced_config("mamba2-370m")
    params = model_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
    h = _backbone(params, cfg, {"tokens": toks})
    X = h.reshape(-1, cfg.d_model)
    ylab = (toks % 4).reshape(-1)
    Y = jax.nn.one_hot(ylab, 4)
    est, _ = falkon_fit(jax.random.PRNGKey(2), X, Y,
                        FalkonConfig(kernel="gaussian",
                                     kernel_params=(("sigma", 2.0),),
                                     lam=1e-6, num_centers=64, iterations=20,
                                     block_size=64))
    acc = float(jnp.mean(jnp.argmax(est.predict(X), -1) == ylab))
    assert acc > 0.4   # token identity is trivially encoded in features


def test_pallas_kmm_in_preconditioner():
    """K_MM built by the Pallas pairwise kernel feeds the Cholesky
    preconditioner identically to the jnp path."""
    X = jax.random.normal(jax.random.PRNGKey(0), (120, 7))
    kern = GaussianKernel(sigma=1.5)
    KMM_ref = kern(X, X)
    KMM_pal = pairwise_kernel(X, X, kern)
    np.testing.assert_allclose(
        np.asarray(KMM_pal), np.asarray(KMM_ref), rtol=1e-5, atol=1e-5
    )
    p1 = make_preconditioner(KMM_ref, 1e-3, 500)
    p2 = make_preconditioner(KMM_pal, 1e-3, 500)
    np.testing.assert_allclose(np.asarray(p1.T), np.asarray(p2.T), rtol=1e-3, atol=1e-4)


def test_moe_expert_padding_masks_padded_experts():
    """Padded experts (40->48) must never receive tokens."""
    cfg = dataclasses.replace(
        reduced_config("granite-moe-3b-a800m"),
        n_experts=3,
        expert_pad_multiple=4,
        top_k=2,
        capacity_factor=4.0,
    )
    assert cfg.padded_experts == 4
    from repro.models import layers as L
    from repro.models.params import init_params
    p = init_params(jax.random.PRNGKey(0), L.moe_pd(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y = L.moe_apply(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    # routing check: argmax over router logits with mask never picks pad
    logits = (x.reshape(-1, cfg.d_model) @ p["router"])
    masked = jnp.where(jnp.arange(4)[None] >= 3, -1e30, logits)
    assert int(jnp.max(jnp.argmax(masked, -1))) < 3


@pytest.mark.parametrize("mod", ["table2_regression", "table3_classification"])
def test_benchmark_modules_import_and_declare_run(mod):
    import importlib
    m = importlib.import_module(f"benchmarks.{mod}")
    assert callable(m.run)
