"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")   # optional dep: skip, never collect-error
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    GaussianKernel, conjugate_gradient, knm_matvec, make_kernel, make_preconditioner
)

SET = settings(max_examples=15, deadline=None)


def _data(seed, n, d):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (n, d))


@SET
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(3, 40),
    d=st.integers(1, 6),
    kname=st.sampled_from(["gaussian", "laplacian", "matern32"]),
)
def test_kernel_gram_is_psd_and_bounded(seed, n, d, kname):
    X = _data(seed, n, d)
    kern = make_kernel(kname, sigma=1.3)
    K = kern(X, X)
    # symmetry
    np.testing.assert_allclose(K, K.T, atol=1e-5)
    # bounded: K(x,x) <= kappa^2 = 1 for these kernels
    assert float(jnp.max(jnp.abs(K))) <= 1.0 + 1e-5
    # PSD (up to fp32 noise)
    evals = jnp.linalg.eigvalsh(K + 1e-5 * jnp.eye(n))
    assert float(jnp.min(evals)) > -1e-3


@SET
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(5, 60),
    m=st.integers(2, 20),
    bs=st.integers(3, 64),
)
def test_blocked_matvec_invariant_to_block_size(seed, n, m, bs):
    X = _data(seed, n, 4)
    C = _data(seed + 1, m, 4)
    u = jax.random.normal(jax.random.PRNGKey(seed + 2), (m,))
    v = jax.random.normal(jax.random.PRNGKey(seed + 3), (n,))
    kern = GaussianKernel(sigma=1.5)
    ref = knm_matvec(X, C, u, v, kern, block_size=n)  # single block
    got = knm_matvec(X, C, u, v, kern, block_size=bs)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@SET
@given(seed=st.integers(0, 2**31 - 1), q=st.integers(2, 25))
def test_cg_matches_direct_solve_on_random_spd(seed, q):
    A0 = jax.random.normal(jax.random.PRNGKey(seed), (q, q))
    A = A0 @ A0.T + q * jnp.eye(q)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (q,))
    x = conjugate_gradient(lambda v: A @ v, b, t=q + 5).x
    np.testing.assert_allclose(x, jnp.linalg.solve(A, b), rtol=2e-2, atol=2e-3)


@SET
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(3, 30), lam=st.floats(1e-5, 1e-1))
def test_preconditioner_whitens_KMM_regime(seed, m, lam):
    """When K_nM^T K_nM / n ~= K_MM^2-free regime n==M (centers==data), the
    preconditioned operator W = B^T H B equals the identity up to the sample
    fluctuation term E (Lemma 2: W = I + E). With X == C exactly, E = 0 so the
    eigenvalues of A^{-T}(T^{-T} KMM^T KMM T^{-1}/M + lam I)A^{-1} are all 1."""
    X = _data(seed, m, 3)
    kern = GaussianKernel(sigma=2.0)
    KMM = kern(X, X).astype(jnp.float32)
    pre = make_preconditioner(KMM, lam, n=m, jitter=1e-6)
    # Build W densely via the operator identities used in falkon.py
    KnM = KMM  # X == C
    def W(u):
        gamma = pre.right(u)
        w = KnM.T @ (KnM @ gamma) / m
        out = pre.left(w)
        from jax.scipy.linalg import solve_triangular
        ai = solve_triangular(pre.A, u, lower=False)
        return out + lam * solve_triangular(pre.A, ai, lower=False, trans=1)
    I = jnp.eye(m)
    Wm = jax.vmap(W, in_axes=1, out_axes=1)(I)
    ev = jnp.linalg.eigvalsh((Wm + Wm.T) / 2)
    np.testing.assert_allclose(np.asarray(ev), 1.0, rtol=0.05, atol=0.05)


@SET
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(4, 30), shift=st.floats(-3.0, 3.0))
def test_gaussian_kernel_translation_invariance(seed, n, shift):
    X = _data(seed, n, 3)
    kern = GaussianKernel(sigma=1.1)
    np.testing.assert_allclose(
        kern(X, X), kern(X + shift, X + shift), rtol=1e-4, atol=1e-5
    )


@SET
@given(seed=st.integers(0, 2**31 - 1), t=st.integers(1, 10))
def test_cg_monotone_residual(seed, t):
    """CG residual norms are (numerically near-)monotone for SPD systems."""
    q = 12
    A0 = jax.random.normal(jax.random.PRNGKey(seed), (q, q))
    A = A0 @ A0.T + q * jnp.eye(q)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (q,))
    res = conjugate_gradient(lambda v: A @ v, b, t=t)
    r = np.asarray(res.residual_norms)
    # energy-norm is strictly monotone; 2-norm can wiggle — allow 10% slack
    assert r[-1] <= r[0] * 1.1
