"""Materialized K_nM cache (``repro.ops.KernelCache``) tests.

The contract under test (see ``repro.ops.gemm`` / ``repro.ops.knm_cache``):

* **Parity** — fp32 device-tier cached sweeps/applies on the jnp backend are
  BIT-IDENTICAL to the recompute path (the GEMM sweep replays the exact
  blocked scan over stored entries); pallas/host tiers agree to <= 1e-4 per
  sweep; bf16 storage agrees to the policy's quantization tolerance.
* **One kernel evaluation per tile** — ``CountingOps.gram_tile_evals`` after
  a cached fit equals ``cache.num_tiles + ceil(M/bs)`` (one materialization
  pass + the K_MM gram), with ``sweeps == 0``: every CG iteration, the RHS
  sweep and the ``estimate_cond`` power-iteration diagnostics consumed
  stored entries.
* **Routing** — ``plan_cache`` tiers by per-shard bytes against the
  ``REPRO_KNM_BUDGET_MB`` / ``REPRO_KNM_HOST_BUDGET_MB`` budgets; forced
  tiers are respected; ``knm_cache="off"`` fits are bit-identical to the
  seed recompute path.
* **Staleness** — a cache pins its exact (X, centers) arrays by identity;
  ``invalidate()``/``swap_model`` make it refuse to serve.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FalkonConfig,
    GaussianKernel,
    cached_knm_apply,
    cached_knm_matvec,
    falkon_fit,
    falkon_fit_minibatch,
    falkon_fit_path,
    falkon_fit_streaming,
    make_knm_cache,
)
from repro.ops import (
    CachePlan,
    CachePlanWarning,
    CountingOps,
    KernelCache,
    data_shards,
    get_ops,
    plan_cache,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _problem(n=1000, d=6, M=128, key=0):
    kx, kf = jax.random.split(jax.random.PRNGKey(key))
    X = jax.random.normal(kx, (n, d))
    y = jnp.sin(X[:, 0]) + 0.1 * jax.random.normal(kf, (n,))
    return X, y, kf


# ---------------------------------------------------------------------------
# plan_cache routing
# ---------------------------------------------------------------------------
def test_plan_cache_tiers_by_budget():
    # 1000 * 128 * 4 bytes = 512000 B = ~0.49 MiB
    p = plan_cache(1000, 128, budget=2**20)
    assert p.tier == "device" and p.cache_bytes == 1000 * 128 * 4
    p = plan_cache(1000, 128, budget=2**18, host_budget=2**20)
    assert p.tier == "host"
    p = plan_cache(1000, 128, budget=2**18, host_budget=2**18)
    assert p.tier == "off"


def test_plan_cache_env_budgets(monkeypatch):
    monkeypatch.setenv("REPRO_KNM_BUDGET_MB", "0.25")     # 256 KiB
    monkeypatch.setenv("REPRO_KNM_HOST_BUDGET_MB", "1")   # 1 MiB
    assert plan_cache(1000, 128).tier == "host"
    monkeypatch.setenv("REPRO_KNM_HOST_BUDGET_MB", "0.25")
    assert plan_cache(1000, 128).tier == "off"
    monkeypatch.setenv("REPRO_KNM_BUDGET_MB", "1")
    assert plan_cache(1000, 128).tier == "device"


def test_plan_cache_charges_per_shard():
    # the same problem that busts a single device fits once row-sharded
    whole = plan_cache(1000, 128, budget=2**18)
    assert whole.tier != "device"
    sharded = plan_cache(1000, 128, budget=2**18, shards=4)
    assert sharded.tier == "device"
    assert sharded.shard_bytes == -(-1000 * 128 * 4 // 4)


def test_plan_cache_forced_tier_and_policy_itemsize():
    p = plan_cache(1000, 128, tier="host", budget=2**30)
    assert p.tier == "host" and "forced" in p.reason
    from repro.ops import resolve_precision
    bf16 = plan_cache(1000, 128, policy=resolve_precision("bf16"))
    fp32 = plan_cache(1000, 128, policy=resolve_precision("fp32"))
    assert bf16.cache_bytes * 2 == fp32.cache_bytes
    assert bf16.storage_dtype == "bfloat16"
    with pytest.raises(ValueError):
        plan_cache(1000, 128, tier="hbm")


def test_cache_refuses_off_plan():
    kern = GaussianKernel(sigma=1.5)
    ops = get_ops("jnp", kern, block_size=256)
    X, _, _ = _problem()
    plan = plan_cache(1000, 128, budget=0, host_budget=0)
    assert plan.tier == "off"
    with pytest.raises(ValueError, match="off"):
        KernelCache(ops, X, X[:128], plan=plan)


# ---------------------------------------------------------------------------
# Parity: cached primitives vs recompute
# ---------------------------------------------------------------------------
def _forced(ops, n, M, tier):
    return plan_cache(n, M, policy=ops.policy, tier=tier)


def test_device_tier_bit_identical_jnp():
    """fp32 jnp device tier: the GEMM sweep replays the recompute scan over
    stored entries — cached == recompute BIT-identically (ragged n)."""
    X, _, _ = _problem(n=1000)
    C = X[:128]
    kern = GaussianKernel(sigma=1.5)
    ops = get_ops("jnp", kern, block_size=256)
    u = jax.random.normal(jax.random.PRNGKey(3), (128,))
    v = jax.random.normal(jax.random.PRNGKey(4), (1000,))
    cache = KernelCache(ops, X, C, plan=_forced(ops, 1000, 128, "device"))
    np.testing.assert_array_equal(
        np.asarray(cache.sweep(u, v)), np.asarray(ops.sweep(X, C, u, v)))
    np.testing.assert_array_equal(
        np.asarray(cache.sweep(u)), np.asarray(ops.sweep(X, C, u)))
    np.testing.assert_array_equal(
        np.asarray(cache.apply(u)), np.asarray(ops.apply(X, C, u)))


@pytest.mark.parametrize("impl,tier", [("pallas", "device"), ("jnp", "host"),
                                       ("pallas", "host")])
def test_cached_sweep_close_other_tiers(impl, tier):
    """Pallas entries / host-tier jitted GEMMs fuse differently than the
    in-core scan: agreement to <= 1e-4 relative, per sweep."""
    X, _, _ = _problem(n=1000)
    C = X[:128]
    kern = GaussianKernel(sigma=1.5)
    ops = get_ops(impl, kern, block_size=256)
    u = jax.random.normal(jax.random.PRNGKey(3), (128,))
    v = jax.random.normal(jax.random.PRNGKey(4), (1000,))
    cache = KernelCache(ops, X, C, plan=_forced(ops, 1000, 128, tier))
    assert cache.tier == tier
    ref = np.asarray(ops.sweep(X, C, u, v))
    got = np.asarray(cache.sweep(u, v))
    rel = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
    assert rel <= 1e-4, rel
    pa = np.asarray(cache.apply(u))
    pr = np.asarray(ops.apply(X, C, u))
    assert np.max(np.abs(pa - pr)) / np.max(np.abs(pr)) <= 1e-4


def test_bf16_storage_halves_footprint_and_stays_close():
    """bf16 policy: tiles are STORED at bfloat16 (half bytes — the cache
    composes with the precision work); sweeps agree to quantization level."""
    X, _, _ = _problem(n=768)
    C = X[:128]
    kern = GaussianKernel(sigma=1.5)
    ops = get_ops("jnp", kern, block_size=256, precision="bf16")
    cache = KernelCache(ops, X, C, plan=_forced(ops, 768, 128, "device"))
    assert cache.K.dtype == jnp.bfloat16
    u = jax.random.normal(jax.random.PRNGKey(3), (128,))
    v = jax.random.normal(jax.random.PRNGKey(4), (768,))
    ref = np.asarray(ops.sweep(X, C, u, v), np.float32)
    got = np.asarray(cache.sweep(u, v), np.float32)
    rel = np.max(np.abs(got - ref)) / np.max(np.abs(ref))
    assert rel <= 5e-3, rel


def test_row_mask_zero_contribution():
    """Masked rows contribute EXACTLY zero — same contract as the recompute
    sweep's internal padding (fixed-shape padded chunks sweep correctly)."""
    X, _, _ = _problem(n=700)
    C = X[:96]
    kern = GaussianKernel(sigma=1.2)
    ops = get_ops("jnp", kern, block_size=256)
    u = jax.random.normal(jax.random.PRNGKey(5), (96,))
    v = jax.random.normal(jax.random.PRNGKey(6), (700,))
    mask = (jnp.arange(700) < 600).astype(jnp.float32)
    cache = KernelCache(ops, X, C, plan=_forced(ops, 700, 96, "device"))
    np.testing.assert_array_equal(
        np.asarray(cache.sweep(u, v, row_mask=mask)),
        np.asarray(ops.sweep(X[:600], C, u, v[:600])))


def test_functional_veneer():
    X, _, _ = _problem(n=512)
    C = X[:64]
    kern = GaussianKernel(sigma=1.5)
    ops = get_ops("jnp", kern, block_size=256)
    cache = make_knm_cache(X, C, kern, block_size=256, tier="device")
    u = jax.random.normal(jax.random.PRNGKey(3), (64,))
    v = jax.random.normal(jax.random.PRNGKey(4), (512,))
    np.testing.assert_array_equal(
        np.asarray(cached_knm_matvec(cache, u, v)),
        np.asarray(ops.sweep(X, C, u, v)))
    np.testing.assert_array_equal(
        np.asarray(cached_knm_apply(cache, u)),
        np.asarray(ops.apply(X, C, u)))


# ---------------------------------------------------------------------------
# Fit-level: bit-identity, counting, lam-path sharing
# ---------------------------------------------------------------------------
def test_cached_fit_bit_identical_fp32():
    X, y, kf = _problem()
    base = dict(num_centers=128, iterations=8, block_size=256, lam=1e-4)
    _, st0 = falkon_fit(kf, X, y, FalkonConfig(**base, knm_cache="off"))
    _, st1 = falkon_fit(kf, X, y, FalkonConfig(**base, knm_cache="device"))
    np.testing.assert_array_equal(np.asarray(st0.alpha), np.asarray(st1.alpha))
    np.testing.assert_array_equal(
        np.asarray(st0.cond_estimate), np.asarray(st1.cond_estimate))


def test_cached_fit_one_eval_per_tile():
    """THE acceptance invariant: a cached fit evaluates each K_nM row tile
    exactly once (plus ceil(M/bs) tiles for the K_MM gram), runs ZERO
    recompute sweeps, and serves CG + RHS as GEMMs."""
    X, y, kf = _problem()
    n, M, bs = 1000, 128, 256
    cfg = FalkonConfig(num_centers=M, iterations=8, block_size=bs, lam=1e-4,
                       knm_cache="device", estimate_cond=False)
    ops = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=bs))
    falkon_fit(kf, X, y, cfg, ops=ops)
    nb, mt = -(-n // bs), -(-M // bs)
    assert ops.sweeps == 0
    assert ops.materializes == 1
    assert ops.gram_tile_evals == nb + mt, (ops.gram_tile_evals, nb, mt)
    # program points: 1 eager RHS + 1 scanned CG matvec trace
    assert ops.gemm_sweeps == 2


def test_cond_estimate_sweeps_are_cached_too():
    """The ~26 width-1 power-iteration diagnostic sweeps route through the
    same cache: tile evals unchanged, 4 extra gemm_sweep program points
    (2 power() calls x (1 scanned trace + 1 eager mv))."""
    X, y, kf = _problem()
    n, M, bs = 1000, 128, 256
    cfg = FalkonConfig(num_centers=M, iterations=8, block_size=bs, lam=1e-4,
                       knm_cache="device", estimate_cond=True)
    ops = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=bs))
    falkon_fit(kf, X, y, cfg, ops=ops)
    assert ops.sweeps == 0
    assert ops.gram_tile_evals == -(-n // bs) + -(-M // bs)
    assert ops.gemm_sweeps == 6


def test_recompute_fit_unaffected_when_off():
    """knm_cache='off' charges zero cache counters — the seed path."""
    X, y, kf = _problem()
    cfg = FalkonConfig(num_centers=128, iterations=4, block_size=256,
                       lam=1e-4, knm_cache="off", estimate_cond=False)
    ops = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=256))
    falkon_fit(kf, X, y, cfg, ops=ops)
    assert ops.materializes == 0 and ops.gemm_sweeps == 0
    assert ops.sweeps == 2     # eager RHS + scanned CG matvec trace


def test_lambda_path_shares_one_cache_build():
    """L lam systems ride ONE materialization — and match the uncached
    path fit bit-identically in fp32."""
    X, y, kf = _problem()
    n, M, bs = 1000, 128, 256
    lams = (1e-3, 1e-4, 1e-5)
    base = dict(num_centers=M, iterations=6, block_size=bs, lam=1e-4)
    r0 = falkon_fit_path(kf, X, y, FalkonConfig(**base, knm_cache="off"), lams)
    cfg = FalkonConfig(**base, knm_cache="device")
    ops = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=bs))
    r1 = falkon_fit_path(kf, X, y, cfg, lams, ops=ops)
    np.testing.assert_array_equal(
        np.asarray(r0.state.alphas), np.asarray(r1.state.alphas))
    assert ops.materializes == 1
    assert ops.sweeps == 0
    assert ops.gram_tile_evals == -(-n // bs) + -(-M // bs)


def test_host_tier_fit_close():
    X, y, kf = _problem(n=900, M=96)
    base = dict(num_centers=96, iterations=6, block_size=256, lam=1e-4)
    est0, _ = falkon_fit(kf, X, y, FalkonConfig(**base, knm_cache="off"))
    esth, _ = falkon_fit(kf, X, y, FalkonConfig(**base, knm_cache="host"))
    p0, ph = np.asarray(est0.predict(X)), np.asarray(esth.predict(X))
    assert np.max(np.abs(ph - p0)) / np.max(np.abs(p0)) <= 1e-3


def test_auto_route_off_warns_and_matches_seed(monkeypatch):
    monkeypatch.setenv("REPRO_KNM_BUDGET_MB", "0.001")
    monkeypatch.setenv("REPRO_KNM_HOST_BUDGET_MB", "0.001")
    X, y, kf = _problem()
    base = dict(num_centers=128, iterations=4, block_size=256, lam=1e-4)
    _, st0 = falkon_fit(kf, X, y, FalkonConfig(**base, knm_cache="off"))
    with pytest.warns(CachePlanWarning) as rec:
        _, sta = falkon_fit(kf, X, y, FalkonConfig(**base, knm_cache="auto"))
    assert rec[0].message.plan.tier == "off"
    np.testing.assert_array_equal(np.asarray(st0.alpha), np.asarray(sta.alpha))


# ---------------------------------------------------------------------------
# Config validation + unsupported-variant refusals
# ---------------------------------------------------------------------------
def test_config_rejects_unknown_mode():
    with pytest.raises(ValueError, match="knm_cache"):
        FalkonConfig(knm_cache="hbm")


def test_streaming_and_minibatch_refuse_cache():
    X, y, kf = _problem(n=512, M=64)
    cfg = FalkonConfig(num_centers=64, iterations=2, block_size=256,
                       lam=1e-4, knm_cache="device")
    with pytest.raises(ValueError, match="mini-batch"):
        falkon_fit_minibatch(kf, X, y, cfg)
    from repro.data.streaming import ArrayChunkSource
    src = ArrayChunkSource(np.asarray(X), np.asarray(y), chunk_rows=256)
    with pytest.raises(ValueError, match="streaming"):
        falkon_fit_streaming(kf, src, cfg)


# ---------------------------------------------------------------------------
# Staleness: estimator + serving tier
# ---------------------------------------------------------------------------
def test_estimator_scoring_cache_and_staleness():
    X, y, kf = _problem()
    cfg = FalkonConfig(num_centers=128, iterations=6, block_size=256, lam=1e-4)
    est, _ = falkon_fit(kf, X, y, cfg)
    Xe = jax.random.normal(jax.random.PRNGKey(9), (300, X.shape[1]))
    cache = est.build_knm_cache(Xe, tier="device")
    direct = np.asarray(est._ops.apply(Xe.astype(est.centers.dtype),
                                       est.centers, est.alpha))
    # explicit cache, implicit (held) cache: both serve bit-identically
    np.testing.assert_array_equal(np.asarray(est.predict(Xe, cache=cache)), direct)
    # held cache only fast-paths the SAME X object it was built over
    held_x = cache.X
    np.testing.assert_array_equal(np.asarray(est.predict(held_x)), direct)
    # a foreign X with an explicit cache is refused, not silently recomputed
    X2 = jax.random.normal(jax.random.PRNGKey(10), (300, X.shape[1]))
    with pytest.raises(ValueError, match="different X"):
        est.predict(X2, cache=cache)
    # invalidation: explicit use refuses; implicit use falls back
    cache.invalidate()
    with pytest.raises(ValueError, match="stale"):
        est.predict(Xe, cache=cache)
    np.testing.assert_array_equal(np.asarray(est.predict(held_x)), direct)


def test_server_swap_model_invalidates_scoring_cache():
    """A cache of K(X_eval, old_centers) MUST NOT score a swapped model:
    swap_model invalidates + detaches it, and the caller's handle refuses."""
    from repro.serve import CoalescingPredictServer

    X, y, kf = _problem()
    cfg = FalkonConfig(num_centers=128, iterations=6, block_size=256, lam=1e-4)
    est, _ = falkon_fit(kf, X, y, cfg)
    Xe = jax.random.normal(jax.random.PRNGKey(9), (200, X.shape[1]))
    srv = CoalescingPredictServer(est, max_batch=128)
    srv.warmup()
    cache = est.build_knm_cache(Xe, tier="device")
    srv.attach_scoring_cache(cache)
    s0 = srv.predict_scoring_set()
    np.testing.assert_array_equal(
        s0, np.asarray(est.predict(Xe.astype(est.centers.dtype))))
    swapped = est.partial_fit(X[:512], y[:512])
    srv.swap_model(swapped)
    with pytest.raises(RuntimeError, match="no scoring cache"):
        srv.predict_scoring_set()
    with pytest.raises(ValueError, match="stale"):
        cache.check_serves(est.centers)
    # a fresh cache over the swapped model re-attaches cleanly
    cache2 = swapped.build_knm_cache(Xe)
    srv.attach_scoring_cache(cache2)
    np.testing.assert_array_equal(
        srv.predict_scoring_set(),
        np.asarray(swapped.predict(Xe.astype(swapped.centers.dtype))))


def test_attach_refuses_foreign_cache():
    from repro.serve import CoalescingPredictServer

    X, y, kf = _problem()
    cfg = FalkonConfig(num_centers=64, iterations=4, block_size=256, lam=1e-4)
    est, _ = falkon_fit(kf, X, y, cfg)
    other, _ = falkon_fit(jax.random.PRNGKey(42), X, y, cfg)
    Xe = jax.random.normal(jax.random.PRNGKey(9), (100, X.shape[1]))
    cache = other.build_knm_cache(Xe)
    srv = CoalescingPredictServer(est, max_batch=64)
    with pytest.raises(ValueError, match="different centers"):
        srv.attach_scoring_cache(cache)


# ---------------------------------------------------------------------------
# Distributed: shard-local caches, one psum per cached sweep
# ---------------------------------------------------------------------------
def _run(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_distributed_cached_fit_parity():
    """Cached fit under a (4,2) mesh: shard-local row-block caches, one
    (M, p) psum per cached sweep, predictions matching the single-device
    cached fit; the host tier is refused under sharding."""
    _run("""
        import warnings
        import jax, jax.numpy as jnp, numpy as np
        import pytest
        from repro.core import FalkonConfig, falkon_fit
        from repro.ops import (
            CountingOps, DistributedOps, KernelCache, get_ops, plan_cache
        )
        assert len(jax.devices()) == 8
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        kx, kf = jax.random.split(jax.random.PRNGKey(0))
        X = jax.random.normal(kx, (1000, 6))
        y = jnp.sin(X[:, 0]) + 0.1 * jax.random.normal(kf, (1000,))
        base = dict(num_centers=128, iterations=6, block_size=64, lam=1e-4,
                    knm_cache="device", estimate_cond=False)
        est1, st1 = falkon_fit(kf, X, y, FalkonConfig(**base))
        cfg = FalkonConfig(**base, mesh=mesh)
        ops = CountingOps(DistributedOps(
            get_ops("jnp", cfg.make_kernel(), block_size=64),
            mesh, ("data",)))
        estd, std = falkon_fit(kf, X, y, cfg, ops=ops)
        rel = float(jnp.max(jnp.abs(std.alpha - st1.alpha))
                    / jnp.max(jnp.abs(st1.alpha)))
        assert rel < 2e-3, rel
        # shard-local tiles: no recompute sweeps, one materialization,
        # one psum per cached sweep program point (RHS + CG trace)
        assert ops.sweeps == 0 and ops.materializes == 1
        dist = ops.ops
        assert dist.psums == 2, dist.psums
        # host tier refuses under sharding
        plan = plan_cache(1000, 128, tier="host")
        try:
            KernelCache(ops, X, est1.centers, plan=plan)
            raise AssertionError("host tier should refuse under sharding")
        except ValueError as e:
            assert "DistributedOps" in str(e)
        print("DIST CACHED FIT OK", rel)
    """)
