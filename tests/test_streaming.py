"""Host-streaming loader + out-of-core FALKON fit.

* ``ArrayChunkSource`` / ``StreamingLoader`` mechanics: chunk shapes, ragged
  last chunk, ordering, re-iterability (the CG loop replays the source once
  per iteration), threaded and synchronous modes, error propagation.
* Reference semantics: ``streaming_sweep`` / ``streaming_apply`` over chunks
  equal the in-core jnp-backend results to <= 1e-4 fp32 — and the same
  identity holds through the pallas backend.
* ``falkon_fit_streaming``: same centers + same data => same predictions as
  the in-core ``falkon_solve`` path, and ``predict_stream`` == ``predict``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FalkonConfig,
    GaussianKernel,
    falkon_fit_streaming,
    falkon_solve,
    make_preconditioner,
    streaming_knm_apply,
    streaming_knm_matvec,
)
from repro.data import (
    ArrayChunkSource,
    JittedOps,
    StreamingLoader,
    streaming_apply,
    streaming_sweep,
    streaming_uniform_centers,
)
from repro.ops import get_ops

TOL = dict(rtol=1e-4, atol=1e-4)


def _problem(n=1000, d=6, M=64, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    X = jax.random.normal(ks[0], (n, d))
    w = jax.random.normal(ks[1], (d,))
    y = jnp.sin(X @ w) + 0.05 * jax.random.normal(ks[2], (n,))
    u = jax.random.normal(ks[3], (M,))
    return np.asarray(X), np.asarray(y), np.asarray(u)


def test_chunk_source_shapes_and_ragged_tail():
    X, y, _ = _problem(n=1000)
    src = ArrayChunkSource(X, y, chunk_rows=300)
    chunks = list(src.chunks())
    assert src.num_chunks == len(chunks) == 4
    assert [c[0].shape[0] for c in chunks] == [300, 300, 300, 100]
    assert all(c[1].shape[0] == c[0].shape[0] for c in chunks)
    np.testing.assert_array_equal(np.concatenate([c[0] for c in chunks]), X)
    # y=None sources stream (chunk, None) pairs
    assert next(iter(ArrayChunkSource(X, chunk_rows=256).chunks()))[1] is None
    with pytest.raises(ValueError, match="chunk_rows"):
        ArrayChunkSource(X, y, chunk_rows=0)
    with pytest.raises(ValueError, match="rows"):
        ArrayChunkSource(X, y[:10])


@pytest.mark.parametrize("prefetch", [0, 2])
def test_loader_orders_and_reiterates(prefetch):
    X, y, _ = _problem(n=700)
    src = ArrayChunkSource(X, y, chunk_rows=256)
    loader = StreamingLoader(src, prefetch=prefetch)
    for _ in range(2):  # re-iterable: two full passes
        got = list(loader)
        assert [int(xc.shape[0]) for xc, _ in got] == [256, 256, 188]
        np.testing.assert_allclose(np.concatenate([np.asarray(xc) for xc, _ in got]), X)


def test_loader_propagates_source_errors():
    class Boom(ArrayChunkSource):
        def chunks(self):
            yield from super().chunks()
            raise RuntimeError("disk on fire")

    X, y, _ = _problem(n=300)
    loader = StreamingLoader(Boom(X, y, chunk_rows=128), prefetch=1)
    with pytest.raises(RuntimeError, match="disk on fire"):
        list(loader)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_streaming_sweep_matches_incore(impl):
    X, y, u = _problem()
    kern = GaussianKernel(sigma=2.0)
    ops = get_ops(impl, kern, block_size=128)
    C = jnp.asarray(X[:64])
    loader = StreamingLoader(ArrayChunkSource(X, y, chunk_rows=300), prefetch=0)
    got = streaming_sweep(ops, loader, C, jnp.asarray(u), use_targets=True)
    ref = ops.sweep(jnp.asarray(X), C, jnp.asarray(u), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)
    # v = 0 (matvec mode)
    got0 = streaming_sweep(ops, loader, C, jnp.asarray(u), use_targets=False)
    ref0 = ops.sweep(jnp.asarray(X), C, jnp.asarray(u), None)
    np.testing.assert_allclose(np.asarray(got0), np.asarray(ref0), **TOL)


def test_streaming_apply_matches_incore():
    X, y, u = _problem()
    kern = GaussianKernel(sigma=2.0)
    ops = get_ops("jnp", kern, block_size=128)
    C = jnp.asarray(X[:64])
    loader = StreamingLoader(ArrayChunkSource(X, y, chunk_rows=260), prefetch=0)
    got = streaming_apply(ops, loader, C, jnp.asarray(u))
    ref = ops.apply(jnp.asarray(X), C, jnp.asarray(u))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


def test_core_matvec_streaming_delegates():
    X, y, u = _problem()
    kern = GaussianKernel(sigma=2.0)
    C = jnp.asarray(X[:64])
    loader = StreamingLoader(ArrayChunkSource(X, y, chunk_rows=300), prefetch=0)
    ops = get_ops("jnp", kern, block_size=2048)
    got = streaming_knm_matvec(loader, C, jnp.asarray(u), kern, use_targets=True)
    ref = ops.sweep(jnp.asarray(X), C, jnp.asarray(u), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)
    got_a = streaming_knm_apply(loader, C, jnp.asarray(u), kern)
    np.testing.assert_allclose(
        np.asarray(got_a),
        np.asarray(ops.apply(jnp.asarray(X), C, jnp.asarray(u))),
        **TOL,
    )


def test_streaming_uniform_centers_exact_rows():
    X, y, _ = _problem(n=500)
    src = ArrayChunkSource(X, y, chunk_rows=128)
    centers, idx = streaming_uniform_centers(jax.random.PRNGKey(3), src, 40)
    assert centers.shape == (40, X.shape[1])
    assert len(np.unique(idx)) == 40  # without replacement
    np.testing.assert_array_equal(centers, X[idx])


def test_streaming_fit_matches_incore_solve():
    """Same centers, same data: the streamed solve must reproduce the
    in-core falkon_solve predictions (CG recurrences differ only in fp32
    summation order)."""
    X, y, _ = _problem(n=1200, M=96)
    n = X.shape[0]
    kern = GaussianKernel(sigma=2.0)
    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", 2.0),),
        lam=1e-3,
        num_centers=96,
        iterations=20,
        block_size=256,
    )
    C = jnp.asarray(X[:96])
    ops = cfg.make_ops(kern)
    pre = make_preconditioner(ops.gram(C, C), cfg.lam, n, D=None)
    st_i = falkon_solve(
        jnp.asarray(X),
        jnp.asarray(y),
        C,
        pre,
        kern,
        cfg.lam,
        cfg.iterations,
        ops=ops,
        estimate_cond=False,
    )

    src = ArrayChunkSource(X, y, chunk_rows=500)
    est_s, st_s = falkon_fit_streaming(jax.random.PRNGKey(1), src, cfg, centers=C)
    pred_i = ops.apply(jnp.asarray(X), C, st_i.alpha)
    pred_s = est_s.predict(jnp.asarray(X))
    rel = float(jnp.linalg.norm(pred_s - pred_i) / jnp.linalg.norm(pred_i))
    assert rel < 1e-3, rel

    # chunked prediction equals in-core prediction on the same estimator
    loader = StreamingLoader(src, prefetch=0)
    np.testing.assert_allclose(
        np.asarray(est_s.predict_stream(loader)), np.asarray(pred_s), **TOL
    )


def test_streaming_fit_rejects_leverage_selection():
    X, y, _ = _problem(n=300)
    src = ArrayChunkSource(X, y, chunk_rows=128)
    cfg = FalkonConfig(num_centers=32, center_selection="leverage")
    with pytest.raises(ValueError, match="uniform"):
        falkon_fit_streaming(jax.random.PRNGKey(0), src, cfg)


# ---------------------------------------------------------------------------
# ragged tail chunk: row-masked padding, one XLA compile per fit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_sweep_row_mask_masks_rows_exactly(impl):
    """The contract tail-padding rests on: masked rows contribute EXACTLY
    zero — the masked padded sweep is bit-identical to sweeping the valid
    prefix alone (with and without the v term)."""
    X, y, u = _problem(n=200, M=32)
    kern = GaussianKernel(sigma=2.0)
    ops = get_ops(impl, kern, block_size=64)
    C = jnp.asarray(X[:32])
    uj = jnp.asarray(u[:32])
    n_valid = 130
    mask = (jnp.arange(200) < n_valid).astype(jnp.float32)
    Xp = jnp.asarray(X).at[n_valid:].set(123.0)  # junk in the pad rows
    yp = jnp.asarray(y) * mask
    ref = ops.sweep(jnp.asarray(X[:n_valid]), C, uj, jnp.asarray(y[:n_valid]))
    got = ops.sweep(Xp, C, uj, yp, row_mask=mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    ref0 = ops.sweep(jnp.asarray(X[:n_valid]), C, uj, None)
    got0 = ops.sweep(Xp, C, uj, None, row_mask=mask)
    np.testing.assert_array_equal(np.asarray(got0), np.asarray(ref0))


def test_sweep_row_mask_sharded_path(monkeypatch):
    """row_mask must survive the planner's fallback to the j-sharded sweep
    (the spilled t rows are zeroed between the two phases)."""
    monkeypatch.setenv("REPRO_VMEM_BUDGET_MB", "0.25")  # force off fused
    X, y, u = _problem(n=200, M=64)
    kern = GaussianKernel(sigma=2.0)
    ops = get_ops("pallas", kern, block_size=64)
    assert ops.plan(200, 64, X.shape[1]).path != "fused"
    C = jnp.asarray(X[:64])
    mask = (jnp.arange(200) < 150).astype(jnp.float32)
    with pytest.warns(Warning):
        ref = ops.sweep(jnp.asarray(X[:150]), C, jnp.asarray(u), jnp.asarray(y[:150]))
        got = ops.sweep(
            jnp.asarray(X), C, jnp.asarray(u), jnp.asarray(y) * mask, row_mask=mask
        )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_streaming_sweep_pads_tail_to_one_shape():
    """Padded-tail streaming equals the legacy ragged-tail sweep bit for
    bit, and the ragged tail no longer costs a second XLA compile: over
    many passes the jitted sweep traces ONCE per (v-present) form."""
    from repro.ops import CountingOps

    X, y, u = _problem(n=1000)
    kern = GaussianKernel(sigma=2.0)
    ops = get_ops("jnp", kern, block_size=128)
    C = jnp.asarray(X[:64])
    loader = StreamingLoader(ArrayChunkSource(X, y, chunk_rows=300), prefetch=0)
    padded = streaming_sweep(ops, loader, C, jnp.asarray(u), use_targets=True)
    legacy = streaming_sweep(
        ops, loader, C, jnp.asarray(u), use_targets=True, pad_ragged=False
    )
    np.testing.assert_array_equal(np.asarray(padded), np.asarray(legacy))

    # CountingOps under the jitted facade counts XLA traces, not calls
    cnt = CountingOps(ops)
    jops = JittedOps(cnt)
    for _ in range(3):  # 3 passes x 4 chunks (300/300/300/100-row tail)
        streaming_sweep(jops, loader, C, jnp.asarray(u), use_targets=False)
    assert cnt.sweeps == 1, (
        f"expected ONE trace for 12 ragged-tail chunk sweeps, got "
        f"{cnt.sweeps} — the tail chunk is missing the compile cache again")


def test_streaming_fit_compiles_sweep_once_per_form():
    """End-to-end single-compile-per-fit: a full streaming fit with a ragged
    tail chunk traces the sweep exactly twice — once for the RHS pass (v =
    targets) and once for the CG matvec form (v = None) — regardless of
    iteration or chunk count. Before the tail-padding fix this was 4 (every
    epoch's short chunk re-missed the cache with a second shape)."""
    from repro.ops import CountingOps

    X, y, _ = _problem(n=1000, M=64)
    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", 2.0),),
        lam=1e-3,
        num_centers=64,
        iterations=12,
        block_size=128,
        estimate_cond=False,
    )
    cnt = CountingOps(cfg.make_ops())
    src = ArrayChunkSource(X, y, chunk_rows=300)  # 300*3 + ragged 100
    est, _ = falkon_fit_streaming(
        jax.random.PRNGKey(1), src, cfg, centers=jnp.asarray(X[:64]), ops=cnt
    )
    assert cnt.sweeps == 2, (
        f"streaming fit traced the sweep {cnt.sweeps} times; the ragged "
        "tail chunk must share the full chunks' compiled program")
    # and the padded-tail fit still predicts like the in-core solve
    pred = est.predict(jnp.asarray(X[:100]))
    assert np.all(np.isfinite(np.asarray(pred)))
