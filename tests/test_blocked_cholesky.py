"""The blocked out-of-core preconditioner path (ISSUE 7 tentpole).

* ``plan_factor`` routing: budget model, block sizing, env override, the
  structured ``FactorPlanWarning``.
* Blocked-vs-in-core factor parity (<= 1e-5 rel) on every registered
  kernel's K_MM, with and without the leverage-score diagonal D, for both
  ``make_preconditioner`` and ``make_preconditioner_path``.
* The Pallas tile engine (interpret mode on CPU) against the jnp tile
  engine and a float64 numpy reference.
* A forced-blocked full ``falkon_fit`` whose alpha matches the in-core fit.
* The O(b * M) device-residency proof: measured peak device bytes (ground
  truth via ``jax.live_arrays()``) stay under ``FactorPlan``'s ceiling,
  under the dense footprint, and scale LINEARLY in M at fixed block.
* The rank-deficient eig path refuses the blocked route loudly.

The M = 32768 acceptance point runs under ``REPRO_XL_TESTS=1`` (about half
an hour of O(M^3) on one CPU core); ``benchmarks/precond_blocked.py`` +
the ``precond_blocked`` gate carry the same invariant in CI at smaller M.
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FalkonConfig, falkon_fit, make_kernel
from repro.core.preconditioner import (make_preconditioner, make_preconditioner_path)
from repro.kernels.blocked_cholesky import (
    FactorStats, blocked_cholesky, blocked_syrk_tt, resolve_tile_impl
)
from repro.ops import (
    FACTOR_PATHS, FactorPlan, FactorPlanWarning, get_ops, plan_factor
)

KERNELS = [
    ("gaussian", dict(sigma=1.3)),
    ("laplacian", dict(sigma=1.1)),
    ("matern32", dict(sigma=1.7)),
    ("linear", dict(scale=1.5)),
    ("polynomial", dict(degree=2, c=0.5, scale=2.0)),
]


def _rel(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30))


def _spd(M, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((M, M)).astype(dtype)
    return A @ A.T / M + np.eye(M, dtype=dtype)


def _kernel_gram(name, params, M=333, d=7, seed=0):
    kern = make_kernel(name, **params)
    C = jax.random.normal(jax.random.PRNGKey(seed), (M, d))
    return get_ops("jnp", kern).gram(C, C)


# ---------------------------------------------------------------------------
# plan_factor
# ---------------------------------------------------------------------------
def test_plan_factor_routing_and_block_sizing():
    small = plan_factor(1024)
    assert small.path == "incore" and small.block is None
    big = plan_factor(32768)           # 4 GB dense fp32 >> 512 MB default
    assert big.path == "blocked"
    assert big.block is not None and big.block % 256 == 0
    assert big.panel_bytes == 2 * big.block * big.M * big.itemsize
    assert big.device_ceiling_bytes == 3 * big.panel_bytes
    assert big.device_ceiling_bytes < big.dense_bytes
    assert big.path in FACTOR_PATHS and "blocked" in big.reason


def test_plan_factor_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_FACTOR_BUDGET_MB", "1")
    assert plan_factor(1024).path == "blocked"
    monkeypatch.setenv("REPRO_FACTOR_BUDGET_MB", "100000")
    assert plan_factor(65536).path == "incore"


def test_plan_factor_x64_itemsize():
    p4 = plan_factor(8192, itemsize=4)
    p8 = plan_factor(8192, itemsize=8)
    assert p8.dense_bytes == 2 * p4.dense_bytes


# ---------------------------------------------------------------------------
# The blocked factorization itself
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,block", [(97, 32), (256, 64), (500, 128)])
def test_blocked_cholesky_matches_reference(M, block):
    K = _spd(M, seed=M)
    ref = np.linalg.cholesky(K.astype(np.float64)).T
    T = blocked_cholesky(K, block)
    assert T.shape == (M, M)
    assert np.allclose(np.tril(T, -1), 0.0), "factor must be upper"
    assert _rel(T, ref) < 1e-5
    TT = blocked_syrk_tt(T, block)
    assert _rel(TT, T @ T.T) < 1e-6


def test_blocked_cholesky_pallas_tile_engine_parity():
    """The Pallas POTRF/TRSM/update kernels (interpret mode off-TPU) agree
    with the BLAS-backed jnp tile engine on ragged multi-tile problems."""
    K = _spd(200, seed=3)
    Tj = blocked_cholesky(K, 64, tile_impl="jnp")
    Tp = blocked_cholesky(K, 64, tile_impl="pallas")
    assert _rel(Tp, Tj) < 1e-5
    assert _rel(Tp, np.linalg.cholesky(K.astype(np.float64)).T) < 1e-5


@pytest.mark.parametrize("M,block", [(300, 256), (500, 192), (260, 256)])
def test_blocked_cholesky_pallas_wide_block_ragged_parity(M, block):
    """Regression: with block > LANE(=128) and M % block != 0, the trailing
    update's factor panels are WIDER than the ragged output tile. The update
    kernel must pad/tile the contraction dimension to the panel width, not
    the output width — getting it wrong silently truncates the contraction
    and corrupts the factor only on the default TPU (pallas) path."""
    K = _spd(M, seed=M + block)
    Tp = blocked_cholesky(K, block, tile_impl="pallas")
    Tj = blocked_cholesky(K, block, tile_impl="jnp")
    assert _rel(Tp, Tj) < 1e-5
    assert _rel(Tp, np.linalg.cholesky(K.astype(np.float64)).T) < 1e-5


def test_blocked_cholesky_pallas_indefinite_yields_nan():
    """An indefinite (under-jittered) input must fail OBSERVABLY on the
    pallas engine — NaNs in the factor, same as the in-core/jnp path —
    not clamp the bad pivot and emit a finite garbage factor."""
    M = 96
    K = _spd(M, seed=11)
    K[M // 2, M // 2] = -100.0  # force a negative pivot mid-factorization
    Tp = blocked_cholesky(K, 32, tile_impl="pallas")
    assert np.isnan(Tp).any(), "indefinite input produced a finite factor"
    Tj = blocked_cholesky(K, 32, tile_impl="jnp")
    assert np.isnan(Tj).any(), "jnp engine should also surface NaNs"


def test_resolve_tile_impl():
    assert resolve_tile_impl("jnp") == "jnp"
    assert resolve_tile_impl("pallas") == "pallas"
    expected = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert resolve_tile_impl("auto") == expected
    with pytest.raises(ValueError, match="tile_impl"):
        resolve_tile_impl("cuda")


def test_blocked_cholesky_float64_input():
    """float64 hosts factor without error; device math matches whatever
    precision the in-core path would run at (x64 on or off)."""
    K = _spd(150, seed=9).astype(np.float64)
    T = blocked_cholesky(K, 64)
    ref = np.asarray(jnp.linalg.cholesky(jnp.asarray(K)).T)
    assert _rel(T, ref) < 1e-5


def test_blocked_cholesky_rejects_bad_inputs():
    with pytest.raises(ValueError, match="square"):
        blocked_cholesky(np.ones((4, 5), np.float32), 2)
    with pytest.raises(ValueError, match="block"):
        blocked_cholesky(np.eye(4, dtype=np.float32), 0)


# ---------------------------------------------------------------------------
# Preconditioner routing + parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kernel_name,params", KERNELS)
@pytest.mark.filterwarnings("ignore::repro.ops.FactorPlanWarning")
def test_blocked_preconditioner_parity_all_kernels(kernel_name, params):
    """Blocked vs in-core T/A parity on every registered kernel's Gram,
    ragged M=333 over 256-wide tiles.

    The jitter keeps the comparison about the FACTORIZATION, not the
    conditioning: linear/polynomial grams in d=7 are rank-deficient
    (cond ~1e7), where ANY two fp32 Cholesky orderings diverge to ~1e-4 in
    the near-null directions — the regime the rank_deficient eig path (or a
    real jitter) exists for."""
    KMM = _kernel_gram(kernel_name, params)
    pin = make_preconditioner(KMM, 1e-3, 1000, factor_plan="incore", jitter=0.1)
    pbl = make_preconditioner(KMM, 1e-3, 1000, factor_plan="blocked", jitter=0.1)
    assert _rel(pbl.T, pin.T) < 1e-5
    assert _rel(pbl.A, pin.A) < 1e-5


@pytest.mark.filterwarnings("ignore::repro.ops.FactorPlanWarning")
def test_blocked_preconditioner_with_leverage_diagonal():
    KMM = _kernel_gram("gaussian", dict(sigma=1.3), M=300)
    D = jnp.asarray(np.random.default_rng(5).uniform(0.5, 1.5, 300).astype(np.float32))
    pin = make_preconditioner(KMM, 1e-3, 1000, D=D, factor_plan="incore")
    pbl = make_preconditioner(KMM, 1e-3, 1000, D=D, factor_plan="blocked")
    assert _rel(pbl.T, pin.T) < 1e-5
    assert _rel(pbl.A, pin.A) < 1e-5


@pytest.mark.filterwarnings("ignore::repro.ops.FactorPlanWarning")
def test_blocked_path_builder_parity():
    KMM = _kernel_gram("gaussian", dict(sigma=1.3), M=300)
    lams = [1e-2, 1e-3, 1e-4]
    pin = make_preconditioner_path(KMM, lams, 1000, factor_plan="incore")
    pbl = make_preconditioner_path(KMM, lams, 1000, factor_plan="blocked")
    assert pbl.A.shape == pin.A.shape == (3, 300, 300)
    assert _rel(pbl.T, pin.T) < 1e-5
    assert _rel(pbl.A, pin.A) < 1e-5


def test_blocked_route_warns_with_plan():
    KMM = _kernel_gram("gaussian", dict(sigma=1.3), M=300)
    with pytest.warns(FactorPlanWarning) as rec:
        make_preconditioner(KMM, 1e-3, 1000, factor_plan="blocked")
    plans = [w.message.plan for w in rec if isinstance(w.message, FactorPlanWarning)]
    assert plans and plans[0].path == "blocked"
    assert isinstance(plans[0], FactorPlan)


def test_auto_plan_routes_blocked_under_tiny_budget(monkeypatch):
    monkeypatch.setenv("REPRO_FACTOR_BUDGET_MB", "0.05")
    KMM = _kernel_gram("gaussian", dict(sigma=1.3), M=300)
    with pytest.warns(FactorPlanWarning):
        pbl = make_preconditioner(KMM, 1e-3, 1000)
    monkeypatch.delenv("REPRO_FACTOR_BUDGET_MB")
    pin = make_preconditioner(KMM, 1e-3, 1000)
    assert _rel(pbl.A, pin.A) < 1e-5


def test_traced_build_falls_back_incore(monkeypatch):
    """Under jit the blocked path cannot leave the device; the plan must
    quietly land in-core and produce the historical result."""
    monkeypatch.setenv("REPRO_FACTOR_BUDGET_MB", "0.01")
    KMM = _kernel_gram("gaussian", dict(sigma=1.3), M=200)
    jitted = jax.jit(lambda K: make_preconditioner(K, 1e-3, 1000).A)
    with warnings.catch_warnings():
        warnings.simplefilter("error", FactorPlanWarning)  # must NOT warn
        A = jitted(KMM)
    monkeypatch.delenv("REPRO_FACTOR_BUDGET_MB")
    ref = make_preconditioner(KMM, 1e-3, 1000).A
    assert _rel(A, ref) < 1e-6


def test_invalid_factor_plan_rejected():
    KMM = _kernel_gram("gaussian", dict(sigma=1.3), M=64)
    with pytest.raises(ValueError, match="factor_plan"):
        make_preconditioner(KMM, 1e-3, 1000, factor_plan="banana")


def test_rank_deficient_refuses_blocked_route():
    """Satellite: the eig fallback must be loudly refused by the blocked
    route (a dense eigendecomposition cannot be tiled by this scheme)."""
    KMM = _kernel_gram("gaussian", dict(sigma=1.3), M=200)
    with pytest.raises(ValueError, match="rank_deficient"):
        make_preconditioner(KMM, 1e-3, 1000, rank_deficient=True, factor_plan="blocked")
    with pytest.raises(ValueError, match="REPRO_FACTOR_BUDGET_MB"):
        make_preconditioner_path(
            KMM, [1e-3], 1000, rank_deficient=True, factor_plan="blocked"
        )
    # in-core eig fallback is untouched
    p = make_preconditioner(KMM, 1e-3, 1000, rank_deficient=True, factor_plan="incore")
    assert p.diag_T


# ---------------------------------------------------------------------------
# Forced-blocked end-to-end fit
# ---------------------------------------------------------------------------
@pytest.mark.filterwarnings("ignore::repro.ops.FactorPlanWarning")
def test_forced_blocked_falkon_fit_alpha_parity(monkeypatch):
    """A full falkon_fit with the preconditioner forced onto the blocked
    path matches the in-core fit's alpha to <= 1e-4 rel (fp32).

    The problem is kept well-conditioned (sigma=1, explicit jitter): with a
    near-singular K_MM the converged FUNCTION is identical (predictions
    agree to ~1e-4 regardless — also asserted) but alpha itself is only
    determined up to near-null directions of K_MM, which is a property of
    Nystrom ridge regression, not of the factor path."""
    n, d, M = 1500, 6, 320
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    X = jax.random.normal(keys[0], (n, d))
    w = jax.random.normal(keys[1], (d,))
    y = X @ w + 0.05 * jax.random.normal(keys[2], (n,))
    config = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", 1.0),),
        num_centers=M,
        lam=1e-3,
        iterations=30,
        jitter=1e-3,
    )
    est_in, _ = falkon_fit(keys[0], X, y, config)
    monkeypatch.setenv("REPRO_FACTOR_BUDGET_MB", "0.2")   # M=320 -> blocked
    est_bl, _ = falkon_fit(keys[0], X, y, config)
    monkeypatch.delenv("REPRO_FACTOR_BUDGET_MB")
    assert _rel(est_bl.alpha, est_in.alpha) < 1e-4
    preds_in = est_in.predict(X[:100])
    preds_bl = est_bl.predict(X[:100])
    assert _rel(preds_bl, preds_in) < 1e-4


# ---------------------------------------------------------------------------
# The O(b * M) device-residency proof
# ---------------------------------------------------------------------------
def _measure_peak(M, block, seed=0):
    """Factor a HOST matrix and return (measured peak device bytes via
    jax.live_arrays — the ground truth — , self-accounted stats peak)."""
    K = _spd(M, seed=seed)
    baseline = sum(a.nbytes for a in jax.live_arrays())
    peak = {"live": 0}

    def on_step(stage, st):
        live = sum(a.nbytes for a in jax.live_arrays()) - baseline
        peak["live"] = max(peak["live"], live)

    stats = FactorStats()
    T = blocked_cholesky(K, block, stats=stats, on_step=on_step)
    assert _rel(T, np.linalg.cholesky(K.astype(np.float64)).T) < 1e-5
    assert stats.current_device_bytes == 0, "device buffers leaked"
    return peak["live"], stats.peak_device_bytes


def test_device_peak_is_o_block_m_not_m_squared():
    """The acceptance-seam memory claim, measured: peak device-resident
    bytes stay under the plan's O(b * M) ceiling and UNDER the dense M^2
    footprint, and grow linearly (not quadratically) in M at fixed block."""
    block = 128
    peaks = {}
    for M in (1024, 2048):
        plan = plan_factor(M, block=block, factor_budget=1)  # force blocked
        assert plan.path == "blocked" and plan.block == block
        live, accounted = _measure_peak(M, block, seed=M)
        assert live <= plan.device_ceiling_bytes, (
            f"M={M}: measured {live}B above the O(b*M) ceiling "
            f"{plan.device_ceiling_bytes}B")
        assert live < plan.dense_bytes, (
            f"M={M}: measured {live}B not below dense {plan.dense_bytes}B"
        )
        assert accounted <= plan.device_ceiling_bytes
        peaks[M] = live
    # doubling M at fixed block must not 4x the peak: linear-with-slack
    assert peaks[2048] <= 3.0 * peaks[1024], (f"peak grew superlinearly: {peaks}")


@pytest.mark.skipif(not os.environ.get("REPRO_XL_TESTS"),
                    reason="M=32768 acceptance point: ~30 min of O(M^3) on "
                           "one CPU core; set REPRO_XL_TESTS=1 to run")
def test_blocked_parity_m32768_xl():
    M = 32768
    plan = plan_factor(M)
    assert plan.path == "blocked"
    rng = np.random.default_rng(0)
    A = rng.standard_normal((M, 64)).astype(np.float32)
    K = (A @ A.T) / 64 + np.eye(M, dtype=np.float32)
    stats = FactorStats()
    T = blocked_cholesky(K, plan.block, stats=stats)
    Tref = np.asarray(jnp.linalg.cholesky(jnp.asarray(K)).T)
    assert _rel(T, Tref) < 1e-5
    assert stats.peak_device_bytes <= plan.device_ceiling_bytes
