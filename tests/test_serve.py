"""Batch-coalescing predict server (repro.serve) + launch/serve --falkon.

* Coalescing policy: bucket ladder construction, bucket selection, dispatch
  planning (in-order packing, splitting, zero-size requests).
* Pad/scatter parity: bucketed predictions == direct ``est.predict`` —
  BIT-IDENTICAL in fp32 on the jnp backend (pad rows are dropped, never
  mixed into valid rows; centers/alpha enter the jitted apply as arguments,
  not foldable constants), tolerance-checked on the pallas backend.
* Zero retraces: the server's trace counter (incremented at jit trace time)
  must not move after warmup, for any ragged request mix.
* Multi-model tier: a FalkonPathResult served through ONE stacked apply per
  bucket matches each per-lam estimator's own predictions.
* ``launch/serve.py --falkon`` CLI smoke (coalesced, per-request, streaming
  fit) — previously had zero coverage.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FalkonConfig, falkon_fit, falkon_fit_path
from repro.serve import (
    CoalescingPredictServer, bucket_ladder, pick_bucket, plan_dispatches
)


# ---------------------------------------------------------------------------
# pure coalescing policy
# ---------------------------------------------------------------------------
def test_bucket_ladder_powers_of_two():
    assert bucket_ladder(256) == (8, 16, 32, 64, 128, 256)
    assert bucket_ladder(64, min_bucket=4) == (4, 8, 16, 32, 64)
    # non-pow2 ends round UP
    assert bucket_ladder(100, min_bucket=6) == (8, 16, 32, 64, 128)
    assert bucket_ladder(1, min_bucket=1) == (1,)
    # min above max: one rung covering both
    assert bucket_ladder(4, min_bucket=32) == (32,)
    with pytest.raises(ValueError, match="max_batch"):
        bucket_ladder(0)
    with pytest.raises(ValueError, match="min_bucket"):
        bucket_ladder(8, min_bucket=0)


def test_pick_bucket_smallest_fitting_rung():
    ladder = bucket_ladder(64)
    assert pick_bucket(1, ladder) == 8
    assert pick_bucket(8, ladder) == 8
    assert pick_bucket(9, ladder) == 16
    assert pick_bucket(64, ladder) == 64
    with pytest.raises(ValueError, match="exceed"):
        pick_bucket(65, ladder)
    with pytest.raises(ValueError, match="rows"):
        pick_bucket(0, ladder)


def test_plan_dispatches_packs_in_order_and_splits():
    ladder = bucket_ladder(32)
    plan = plan_dispatches([10, 10, 20, 70, 3], ladder)
    # every request row lands exactly once, in order
    seen = {}
    for di, disp in enumerate(plan):
        assert disp.bucket == pick_bucket(disp.rows, ladder)
        assert disp.rows <= ladder[-1]
        filled = 0
        for s in disp.segments:
            assert s.buf_offset == filled  # densely packed, no holes
            filled += s.rows
            seen.setdefault(s.request, []).append((di, s.req_offset, s.rows))
        assert filled == disp.rows
    assert set(seen) == {0, 1, 2, 3, 4}
    for req, size in enumerate([10, 10, 20, 70, 3]):
        covered = sorted(seen[req], key=lambda t: t[1])
        assert sum(r for _, _, r in covered) == size
        off = 0
        for _, req_off, r in covered:  # contiguous, in-order coverage
            assert req_off == off
            off += r
    # request 3 (70 rows > 32-row cap) was split across >= 3 dispatches
    assert len(seen[3]) >= 3
    # zero-size requests vanish from the plan
    assert plan_dispatches([0, 0], ladder) == ()
    with pytest.raises(ValueError, match="negative"):
        plan_dispatches([-1], ladder)


def test_plan_dispatches_fills_to_capacity():
    ladder = bucket_ladder(64)
    plan = plan_dispatches([40, 40, 40], ladder)
    # greedy fill: 64, 56 — not three 40-row dispatches
    assert [d.rows for d in plan] == [64, 56]
    assert [d.bucket for d in plan] == [64, 64]
    assert plan[0].pad_rows == 0 and plan[1].pad_rows == 8


# ---------------------------------------------------------------------------
# server over a fitted estimator
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def fitted():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    X = jax.random.normal(ks[0], (1500, 6))
    w = jax.random.normal(ks[1], (6,))
    y = jnp.sin(X @ w) + 0.05 * jax.random.normal(ks[2], (1500,))
    cfg = FalkonConfig(
        kernel_params=(("sigma", 2.0),),
        lam=1e-4,
        num_centers=96,
        iterations=10,
        block_size=128,
        estimate_cond=False,
    )
    est, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
    return est, cfg, X, y


def _ragged_requests(d, sizes, seed=7):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(sizes))
    return [
        np.asarray(jax.random.normal(keys[i], (int(s), d))) for i, s in enumerate(sizes)
    ]


def test_bucketed_predictions_bit_identical_fp32(fitted):
    """Pad/scatter parity — the acceptance criterion: every coalesced
    prediction equals the one-shot ``est.predict`` BIT FOR BIT (fp32, jnp
    backend), across co-packing, padding and request splitting."""
    est, _, _, _ = fitted
    server = CoalescingPredictServer(est, max_batch=32)
    server.warmup()
    reqs = _ragged_requests(6, [1, 5, 32, 31, 17, 80, 2, 9])  # 80 splits
    outs = server.predict_many(reqs)
    for r, o in zip(reqs, outs):
        direct = np.asarray(est.predict(jnp.asarray(r)))
        np.testing.assert_array_equal(o, direct)


def test_bucketed_predictions_pallas_backend(fitted):
    est, cfg, _, _ = fitted
    est_p = dataclasses.replace(est, ops_impl="pallas")
    server = CoalescingPredictServer(est_p, max_batch=16)
    outs = server.predict_many(_ragged_requests(6, [3, 16, 11]))
    for r, o in zip(_ragged_requests(6, [3, 16, 11]), outs):
        direct = np.asarray(est_p.predict(jnp.asarray(r)))
        np.testing.assert_allclose(o, direct, rtol=1e-5, atol=1e-5)


def test_zero_retraces_after_warmup(fitted):
    est, _, _, _ = fitted
    server = CoalescingPredictServer(est, max_batch=64, min_bucket=8)
    compile_s = server.warmup()
    assert set(compile_s) == set(server.ladder) == {8, 16, 32, 64}
    assert server.trace_count == len(server.ladder)  # one trace per rung
    rng = np.random.default_rng(0)
    for _ in range(3):  # several flushes of fresh ragged mixes
        sizes = rng.integers(1, 150, size=23)  # incl. > max_batch splits
        server.predict_many(_ragged_requests(6, sizes, seed=int(sizes[0])))
    assert server.retraces_since_warmup() == 0
    assert server.stats.requests == 69


def test_lazy_warmup_and_submit_flush_roundtrip(fitted):
    est, _, _, _ = fitted
    server = CoalescingPredictServer(est, max_batch=16)
    assert server.flush() == []         # nothing queued
    t0 = server.submit(np.zeros((3, 6), np.float32))
    t1 = server.submit(np.zeros((5, 6), np.float32))
    assert (t0, t1) == (0, 1)
    outs = server.flush()               # warmup ran lazily
    assert [o.shape for o in outs] == [(3,), (5,)]
    assert server.retraces_since_warmup() == 0
    with pytest.raises(ValueError, match="rows"):
        server.submit(np.zeros((3, 7), np.float32))  # wrong feature dim


def test_zero_row_request(fitted):
    est, _, _, _ = fitted
    server = CoalescingPredictServer(est, max_batch=16)
    outs = server.predict_many(
        [np.zeros((0, 6), np.float32), np.ones((4, 6), np.float32)]
    )
    assert outs[0].shape == (0,)
    assert outs[1].shape == (4,)


def test_multioutput_estimator_parity():
    """(M, p) coefficients -> (rows, p) predictions through the buckets."""
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    X = jax.random.normal(ks[0], (600, 5))
    Y = jnp.stack([jnp.sin(X[:, 0]), jnp.cos(X[:, 1])], axis=1)
    cfg = FalkonConfig(
        kernel_params=(("sigma", 1.5),),
        lam=1e-4,
        num_centers=64,
        iterations=8,
        block_size=128,
        estimate_cond=False,
    )
    est, _ = falkon_fit(ks[1], X, Y, cfg)
    server = CoalescingPredictServer(est, max_batch=32)
    reqs = _ragged_requests(5, [7, 40, 3])
    outs = server.predict_many(reqs)
    for r, o in zip(reqs, outs):
        assert o.shape == (r.shape[0], 2)
        np.testing.assert_array_equal(o, np.asarray(est.predict(jnp.asarray(r))))


def test_stacked_path_serving_parity(fitted):
    """The multi-model tier: all L lam-estimators through ONE stacked apply
    per bucket must match each estimator served alone."""
    est, cfg, X, y = fitted
    lams = (1e-5, 1e-4, 1e-3)
    path = falkon_fit_path(jax.random.PRNGKey(1), X, y, cfg, lams)
    server = CoalescingPredictServer(path, max_batch=32)
    server.warmup()
    reqs = _ragged_requests(6, [9, 33, 4])
    outs = server.predict_many(reqs)
    assert server.retraces_since_warmup() == 0
    for r, o in zip(reqs, outs):
        assert o.shape == (r.shape[0], len(lams))
        for i in range(len(lams)):
            direct = np.asarray(path.estimators[i].predict(jnp.asarray(r)))
            np.testing.assert_allclose(o[:, i], direct, rtol=1e-5, atol=1e-5)


def test_estimator_ops_cached(fitted):
    """Bugfix regression: predict must not rebuild the backend per call."""
    est, _, _, _ = fitted
    assert est._ops is est._ops                     # cached_property
    assert est._jitted_ops.ops is est._ops          # stream path shares it
    # a pytree round-trip (fresh instance) gets its own lazily-built cache
    leaves, treedef = jax.tree_util.tree_flatten(est)
    est2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert "_ops" not in est2.__dict__
    np.testing.assert_array_equal(
        np.asarray(est2.predict(jnp.zeros((2, 6)))),
        np.asarray(est.predict(jnp.zeros((2, 6)))),
    )


def test_server_rejects_unknown_model():
    with pytest.raises(TypeError, match="FalkonEstimator"):
        CoalescingPredictServer(object())


# ---------------------------------------------------------------------------
# CLI smoke: launch/serve.py --falkon modes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("extra", [
    [],                          # coalesced (default)
    ["--per-request"],           # single-stream baseline loop
    ["--stream-chunk", "512"],   # out-of-core fit, coalesced serving
])
def test_serve_main_falkon_smoke(monkeypatch, capsys, extra):
    from repro.launch import serve as serve_mod
    argv = [
        "serve",
        "--falkon",
        "--n",
        "512",
        "--d",
        "5",
        "--centers",
        "48",
        "--batch",
        "16",
        "--requests",
        "6",
    ] + extra
    monkeypatch.setattr("sys.argv", argv)
    serve_mod.main()
    out = capsys.readouterr().out
    assert "falkon[jnp/fp32]: fit n=512" in out
    if "--per-request" in extra:
        assert "per-request:" in out and "rows/s" in out
    else:
        assert "coalesced:" in out and "retraces after warmup: 0" in out
