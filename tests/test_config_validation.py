"""FalkonConfig fails fast: unknown knobs error at CONFIG time, naming the
options — not deep inside ``get_ops`` at solve time — and the deprecated
``matvec_impl`` alias warns."""
import pytest

from repro.core import FalkonConfig
from repro.core.falkon import CENTER_SELECTIONS
from repro.ops import PRECISIONS, PrecisionPolicy, available_ops


def test_unknown_ops_impl_fails_eagerly_naming_options():
    with pytest.raises(ValueError, match="unknown ops_impl 'cuda'"):
        FalkonConfig(ops_impl="cuda")
    with pytest.raises(ValueError) as e:
        FalkonConfig(ops_impl="cuda")
    for name in available_ops():
        assert name in str(e.value)


def test_unknown_precision_fails_eagerly_naming_options():
    with pytest.raises(ValueError, match="unknown precision"):
        FalkonConfig(precision="fp8")
    with pytest.raises(ValueError) as e:
        FalkonConfig(precision="fp8")
    for name in PRECISIONS:
        assert name in str(e.value)


def test_unknown_center_selection_fails_eagerly_naming_options():
    with pytest.raises(ValueError, match="unknown center_selection"):
        FalkonConfig(center_selection="kmeans")
    with pytest.raises(ValueError) as e:
        FalkonConfig(center_selection="kmeans")
    for name in CENTER_SELECTIONS:
        assert name in str(e.value)


def test_valid_configs_still_construct():
    FalkonConfig()  # defaults
    FalkonConfig(ops_impl="pallas", precision="bf16", center_selection="leverage")
    # a custom PrecisionPolicy instance passes validation too
    FalkonConfig(precision=PrecisionPolicy(name="custom", storage="bfloat16"))


def test_matvec_impl_alias_warns_deprecation():
    with pytest.warns(DeprecationWarning, match="matvec_impl is a deprecated"):
        cfg = FalkonConfig(matvec_impl="pallas")
    assert cfg.impl == "pallas"  # still honored, just loudly


def test_matvec_impl_alias_is_validated_too():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="unknown ops_impl"):
            FalkonConfig(matvec_impl="cuda")


def test_falkon_solve_matvec_impl_warns():
    import jax
    import jax.numpy as jnp
    from conftest import synthetic_regression
    from repro.core import falkon_solve, make_preconditioner, uniform_centers
    from repro.core.kernels import make_kernel

    X, y = synthetic_regression(jax.random.PRNGKey(0), 64)
    kern = make_kernel("gaussian", sigma=1.5)
    sel = uniform_centers(jax.random.PRNGKey(1), X, 16)
    pre = make_preconditioner(kern(sel.centers, sel.centers), 1e-3, 64)
    with pytest.warns(DeprecationWarning, match="matvec_impl"):
        st = falkon_solve(
            X,
            y,
            sel.centers,
            pre,
            kern,
            1e-3,
            2,
            block_size=64,
            matvec_impl="jnp",
            estimate_cond=False,
        )
    assert bool(jnp.all(jnp.isfinite(st.alpha)))
