"""Paper Table 2 — regression/multiclass accuracy+time parity.

Synthetic analogues of MillionSongs (n scaled, d=90, MSE/relative error),
YELP (linear kernel, RMSE) and TIMIT (multiclass c-err), at the paper's
hyperparameter regimes. The claim reproduced: FALKON reaches the accuracy of
the exact Nystrom estimator (and of exact KRR where computable) in a handful
of CG iterations, at a fraction of the direct-solve time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import FalkonConfig, falkon_fit, nystrom_direct
from repro.data.synthetic import PAPER_TASKS, make_kernel_dataset

from .common import c_err, emit, mse, relative_error, rmse, timed


def _split(X, y, frac=0.8):
    n = int(X.shape[0] * frac)
    return X[:n], y[:n], X[n:], y[n:]


def run(fast: bool = True):
    rows = []
    scale = 0.25 if fast else 1.0

    # --- MillionSongs analogue (gaussian, regression) ---
    task = PAPER_TASKS["millionsongs"]
    n = int(task.n * scale)
    X, y = make_kernel_dataset(jax.random.PRNGKey(0), task, n=n)
    Xtr, ytr, Xte, yte = _split(X, y)
    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", task.sigma),),
        lam=task.lam,
        num_centers=task.num_centers,
        iterations=20,
    )
    (est, st), t_f = timed(lambda: falkon_fit(jax.random.PRNGKey(1), Xtr, ytr, cfg))
    ny, t_ny = timed(
        lambda: nystrom_direct(Xtr, ytr, est.centers, cfg.make_kernel(), cfg.lam)
    )
    rows.append(dict(name="table2/millionsongs",
                     us_per_call=round(t_f * 1e6),
                     falkon_mse=round(mse(est.predict(Xte), yte), 4),
                     nystrom_mse=round(mse(ny.predict(Xte), yte), 4),
                     falkon_rel=round(relative_error(est.predict(Xte), yte), 4),
                     falkon_s=round(t_f, 2), nystrom_direct_s=round(t_ny, 2),
                     cond_W=round(float(st.cond_estimate), 1)))

    # --- YELP analogue (linear kernel) ---
    task = PAPER_TASKS["yelp"]
    n = int(task.n * scale)
    X, y = make_kernel_dataset(jax.random.PRNGKey(2), task, n=n)
    # sparse-ish binary features like 3-gram indicators
    X = (X > 1.0).astype(jnp.float32)
    Xtr, ytr, Xte, yte = _split(X, y)
    cfg = FalkonConfig(
        kernel="linear",
        kernel_params=(("scale", 8.0),),
        lam=task.lam,
        num_centers=task.num_centers,
        iterations=20,
    )
    (est, _), t_f = timed(lambda: falkon_fit(jax.random.PRNGKey(3), Xtr, ytr, cfg))
    rows.append(dict(name="table2/yelp", us_per_call=round(t_f * 1e6),
                     falkon_rmse=round(rmse(est.predict(Xte), yte), 4),
                     baseline_rmse=round(rmse(jnp.zeros_like(yte) +
                                              jnp.mean(ytr), yte), 4),
                     falkon_s=round(t_f, 2)))

    # --- TIMIT analogue (multiclass, one-vs-all CG over (M, p) rhs) ---
    task = PAPER_TASKS["timit"]
    n = int(task.n * scale)
    X, labels = make_kernel_dataset(jax.random.PRNGKey(4), task, n=n)
    Y = jax.nn.one_hot(labels, task.n_classes)
    Xtr, Ytr, Xte, Yte = _split(X, Y)
    ltr, lte = jnp.argmax(Ytr, -1), jnp.argmax(Yte, -1)
    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", task.sigma),),
        lam=1e-6,
        num_centers=task.num_centers,
        iterations=20,
    )
    (est, _), t_f = timed(lambda: falkon_fit(jax.random.PRNGKey(5), Xtr, Ytr, cfg))
    ny, _ = timed(
        lambda: nystrom_direct(Xtr, Ytr, est.centers, cfg.make_kernel(), cfg.lam)
    )
    rows.append(dict(name="table2/timit", us_per_call=round(t_f * 1e6),
                     falkon_cerr=round(c_err(est.predict(Xte), lte), 4),
                     nystrom_cerr=round(c_err(ny.predict(Xte), lte), 4),
                     chance=round(1 - 1 / task.n_classes, 3),
                     falkon_s=round(t_f, 2)))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
