"""lam-path solver benchmark: one shared-sweep path fit vs L sequential fits.

Measures the tentpole claim end to end — model selection over an L-point
regularization grid should cost ~one fit, not L — and writes
``BENCH_path.json`` (path override: env ``BENCH_PATH_JSON``), gated in CI by
``benchmarks/check_regression.py``:

* ``speedup_vs_sequential`` — wall-clock of L sequential ``falkon_fit``
  calls over one ``falkon_fit_path`` call, measured in the same run on the
  same machine (machine-neutral ratio, like the fused-sweep gate). The gate
  floor is 2x at L=8; the data-sweep model predicts ~L minus the shared
  O(M^3)/selection overheads.
* ``sweeps_seq`` / ``sweeps_path`` — ``CountingOps`` sweep counts for both
  arms. Their ratio must equal L EXACTLY (the deterministic, machine-
  independent regression signal: if it drops, the path solver stopped
  sharing the data pass).

Runs on the jnp reference backend: the sharing win is backend-agnostic
(the sweep is the dominant cost on every backend) and interpret-mode Pallas
wall-clock on CPU CI runners would measure the emulator, not the algorithm.

    PYTHONPATH=src python -m benchmarks.lambda_path [--quick | --full]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.core import FalkonConfig, falkon_fit, falkon_fit_path
from repro.ops import CountingOps, get_ops

from .check_regression import _geomean
from .common import emit, timed_best, write_payload

#: L, the grid size the acceptance criterion names.
L = 8
LAMS = tuple(float(10.0**e) for e in np.linspace(-4.0, -1.0, L))

#: (n, M, d, t) benchmark points — in-core, planner keeps the jnp row sweep.
FAST_POINTS = [(4096, 256, 16, 10)]
FULL_POINTS = FAST_POINTS + [(8192, 512, 32, 10)]

SPEEDUP_FLOOR = 2.0   # the CI gate's absolute acceptance at L=8


def _problem(n, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(ks[0], (n, d))
    w = jax.random.normal(ks[1], (d,))
    y = jax.numpy.sin(X @ w) + 0.05 * jax.random.normal(ks[2], (n,))
    return X, y


def _config(M, t):
    return FalkonConfig(
        kernel_params=(("sigma", 1.0),),
        num_centers=M,
        iterations=t,
        block_size=1024,
        jitter=1e-5,
        ops_impl="jnp",
        estimate_cond=False,
    )


def _count_sweeps(key, X, y, cfg):
    """CountingOps sweep counts for the path fit and the L sequential fits
    (counted once, untimed — the counts are deterministic)."""
    kern = cfg.make_kernel()
    path_ops = CountingOps(get_ops("jnp", kern, block_size=cfg.block_size))
    falkon_fit_path(key, X, y, cfg, LAMS, ops=path_ops)
    seq_ops = CountingOps(get_ops("jnp", kern, block_size=cfg.block_size))
    for lam in LAMS:
        falkon_fit(key, X, y, dataclasses.replace(cfg, lam=lam), ops=seq_ops)
    return path_ops.sweeps, seq_ops.sweeps


def run(points, repeat=3):
    records = []
    key = jax.random.PRNGKey(1)
    for n, M, d, t in points:
        X, y = _problem(n, d)
        cfg = _config(M, t)

        def fit_path():
            return falkon_fit_path(key, X, y, cfg, LAMS).state.alphas

        def fit_sequential():
            return [falkon_fit(key, X, y,
                               dataclasses.replace(cfg, lam=lam))[0].alpha
                    for lam in LAMS]

        _, sec_path = timed_best(fit_path, repeat=repeat)
        _, sec_seq = timed_best(fit_sequential, repeat=repeat)
        sweeps_path, sweeps_seq = _count_sweeps(key, X, y, cfg)
        rec = dict(
            n=n,
            M=M,
            d=d,
            iterations=t,
            L=L,
            impl=cfg.ops_impl,
            time_path_s=sec_path,
            time_seq_s=sec_seq,
            speedup_vs_sequential=sec_seq / sec_path,
            sweeps_path=sweeps_path,
            sweeps_seq=sweeps_seq,
        )
        records.append(rec)
        print(f"n={n} M={M} d={d} t={t}: path {sec_path * 1e3:.1f}ms, "
              f"{L}-sequential {sec_seq * 1e3:.1f}ms -> "
              f"{rec['speedup_vs_sequential']:.2f}x "
              f"(sweeps {sweeps_path} vs {sweeps_seq})")
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI points, fewer repeats")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    points = FULL_POINTS if args.full else FAST_POINTS
    repeat = 2 if args.quick else 3

    records = run(points, repeat=repeat)
    summary = dict(
        L=L,
        lams=list(LAMS),
        speedup_geomean=_geomean([r["speedup_vs_sequential"] for r in records]),
        sweep_ratio=records[0]["sweeps_seq"] / records[0]["sweeps_path"],
        speedup_floor=SPEEDUP_FLOOR,
    )
    payload = {
        "benchmark": "lambda_path",
        "records": records,
        "summary": summary,
    }
    out = write_payload(payload, "BENCH_PATH_JSON", "BENCH_path.json")
    print(f"wrote {out}: speedup geomean "
          f"{summary['speedup_geomean']:.2f}x over {len(records)} points, "
          f"sweep ratio {summary['sweep_ratio']:.0f} (= L)")

    rows = [dict(name=f"path_fit_n{r['n']}_M{r['M']}",
                 us_per_call=f"{r['time_path_s'] * 1e6:.0f}",
                 speedup=f"{r['speedup_vs_sequential']:.2f}",
                 sweeps=f"{r['sweeps_path']}v{r['sweeps_seq']}")
            for r in records]
    emit(rows)


if __name__ == "__main__":
    main()
