"""bf16 end-to-end precision policy: throughput, footprint, achieved error.

Three measurements, written to ``BENCH_precision.json`` (path override: env
``BENCH_PRECISION_JSON``) and gated in CI by ``benchmarks/check_regression.py``:

1. **Achieved error vs an fp64 oracle** — the bf16 policy's sweep on every
   registered kernel across the fused, two-pass, j-sharded and streaming
   paths, reported as relative error against a dense float64 evaluation
   (plus the fp32 policy as a sanity row). The documented ceiling is 1e-2
   (storage quantization at eps_bf16 ~ 3.9e-3 dominates; compensated fp32
   accumulation keeps the reduction term at O(eps_fp32)).

2. **Throughput ratio** — the same jitted ``KernelOps.sweep`` the fit runs,
   timed under both policies. On CPU/interpret hosts this ratio hovers near
   1.0 (the bf16 win is an HBM/MXU effect real accelerators see), which is
   why the CI gate accepts EITHER the throughput floor or the planner-model
   footprint headroom.

3. **Planner-model footprint** — ``plan_sweep`` under both policies at
   out-of-core shapes: VMEM scratch/io split, the chosen path, and the
   storage-dtype HBM working set (``SweepPlan.hbm_bytes``), whose
   fp32/bf16 ratio is the headroom number (-> 2x as n-sized terms dominate).

    PYTHONPATH=src python -m benchmarks.precision_sweep [--quick | --full]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import enable_x64
from repro.core import make_kernel, spec_of
from repro.data import ArrayChunkSource, StreamingLoader, streaming_sweep
from repro.kernels.kernel_matvec import (fused_sweep_pallas, sharded_sweep_pallas)
from repro.ops import get_ops

from .check_regression import _geomean  # the gate's own aggregation
from .common import emit, timed_best, write_payload

ERROR_BOUND = {"fp32": 1e-4, "bf16": 1e-2}

KERNELS = [
    ("gaussian", dict(sigma=1.3)),
    ("laplacian", dict(sigma=1.1)),
    ("matern32", dict(sigma=1.7)),
    ("linear", dict(scale=1.5)),
    ("polynomial", dict(degree=2, c=0.5, scale=2.0)),
]

ERR_SHAPE = (512, 160, 13)          # ragged: exercises padding/masking too
FAST_TIME_POINTS = [(4096, 512, 32), (8192, 1024, 32)]
FULL_TIME_POINTS = FAST_TIME_POINTS + [(32768, 2048, 64)]
PLAN_POINTS = [(65536, 1024, 32), (262144, 2048, 32), (262144, 8192, 64)]


def _data(n, M, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (
        jax.random.normal(ks[0], (n, d)),
        jax.random.normal(ks[1], (M, d)),
        jax.random.normal(ks[2], (M,)),
        jax.random.normal(ks[3], (n,)),
    )


def _oracle(kern, X, C, u, v):
    with enable_x64(True):
        X64 = jnp.asarray(np.asarray(X), jnp.float64)
        C64 = jnp.asarray(np.asarray(C), jnp.float64)
        K = kern(X64, C64)
        t = K @ jnp.asarray(np.asarray(u), jnp.float64)
        t = t + jnp.asarray(np.asarray(v), jnp.float64)
        return np.asarray(K.T @ t, dtype=np.float64)


def _rel(got, oracle):
    got = np.asarray(got, dtype=np.float64)
    return float(np.linalg.norm(got - oracle) / np.linalg.norm(oracle))


def _error_record(kernel_name: str, params: dict) -> dict:
    n, M, d = ERR_SHAPE
    kern = make_kernel(kernel_name, **params)
    seed = [k for k, _ in KERNELS].index(kernel_name) + 17
    X, C, u, v = _data(n, M, d, seed)
    oracle = _oracle(kern, X, C, u, v)
    bf = jnp.bfloat16
    Xb, Cb, vb = X.astype(bf), C.astype(bf), v.astype(bf)
    kw = dict(spec=spec_of(kern), block_m=64, compensated=True, interpret=True)
    co = jnp.float32  # coefficient dtype (policy override): u in / w out

    err = {
        "err_fp32": _rel(
            get_ops("jnp", kern, block_size=128).sweep(X, C, u, v), oracle),
        "err_fused": _rel(
            fused_sweep_pallas(Xb, Cb, u.astype(co), vb, block_n=64, **kw),
            oracle),
        "err_two_pass": _rel(
            sharded_sweep_pallas(Xb, Cb, u.astype(co), vb, shard_m=M,
                                 t_dtype=bf, out_dtype=co, **kw), oracle),
        "err_j_sharded": _rel(
            sharded_sweep_pallas(Xb, Cb, u.astype(co), vb, shard_m=64,
                                 t_dtype=bf, out_dtype=co, **kw), oracle),
    }
    source = ArrayChunkSource(np.asarray(X), np.asarray(v), chunk_rows=128)
    loader = StreamingLoader(source, prefetch=0, dtype=bf)
    jops = get_ops("jnp", kern, block_size=128, precision="bf16")
    err["err_stream"] = _rel(
        streaming_sweep(jops, loader, C, u, use_targets=True), oracle
    )
    bf16_errs = [v_ for k, v_ in err.items() if k != "err_fp32"]
    return dict(
        kernel=kernel_name,
        n=n,
        M=M,
        d=d,
        **{k: round(v_, 8) for k, v_ in err.items()},
        max_rel_err_bf16=round(max(bf16_errs), 8),
    )


def _throughput_record(n: int, M: int, d: int) -> dict:
    kern = make_kernel("gaussian", sigma=2.0)
    X, C, u, v = _data(n, M, d, seed=n + M)
    out = dict(n=n, M=M, d=d, backend=jax.default_backend())
    times = {}
    for prec in ("fp32", "bf16"):
        ops = get_ops("jnp", kern, block_size=2048, precision=prec)
        sweep = jax.jit(ops.sweep)
        _, t = timed_best(sweep, X, C, u, v, repeat=5)
        times[prec] = t
        out[f"us_{prec}"] = round(t * 1e6, 1)
        out[f"rows_per_s_{prec}"] = round(n / t, 1)
    out["speedup_bf16"] = round(times["fp32"] / times["bf16"], 3)
    return out


def _plan_record(n: int, M: int, d: int) -> dict:
    kern = make_kernel("gaussian", sigma=2.0)
    rec = dict(n=n, M=M, d=d)
    hbm = {}
    for prec in ("fp32", "bf16"):
        plan = get_ops("pallas", kern, block_size=2048, precision=prec).plan(n, M, d, 1)
        hbm[prec] = plan.hbm_bytes
        rec[prec] = dict(
            path=plan.path,
            shard_m=plan.shard_m,
            scratch_bytes=plan.scratch_bytes,
            io_bytes=plan.io_bytes,
            total_bytes=plan.total_bytes,
            hbm_bytes=plan.hbm_bytes,
            input_dtype=plan.input_dtype,
            vector_dtype=plan.vector_dtype,
            coeffs_dtype=plan.coeffs_dtype,
            compensated=plan.compensated,
        )
    rec["hbm_headroom"] = round(hbm["fp32"] / hbm["bf16"], 3)
    return rec


def run(fast: bool = True):
    errors = [_error_record(name, params) for name, params in KERNELS]
    points = FAST_TIME_POINTS if fast else FULL_TIME_POINTS
    throughput = [_throughput_record(*pt) for pt in points]
    plans = [_plan_record(*pt) for pt in PLAN_POINTS]

    summary = dict(
        speedup_geomean=round(_geomean([r["speedup_bf16"] for r in throughput]), 3),
        hbm_headroom_geomean=round(_geomean([p["hbm_headroom"] for p in plans]), 3),
        max_rel_err=max(r["max_rel_err_bf16"] for r in errors),
        error_bound=ERROR_BOUND["bf16"],
        kernels=len(errors),
    )
    payload = {
        "benchmark": "precision_sweep",
        "records": errors,
        "throughput": throughput,
        "planner": plans,
        "summary": summary,
    }
    out = write_payload(payload, "BENCH_PRECISION_JSON", "BENCH_precision.json")

    rows = []
    for r in errors:
        rows.append(dict(name=f"precision_err/{r['kernel']}", us_per_call="",
                         **{k: v for k, v in r.items() if k != "kernel"}))
    for r in throughput:
        rows.append(dict(name=f"precision_sweep/n{r['n']}_M{r['M']}_d{r['d']}",
                         us_per_call=r["us_bf16"],
                         **{k: v for k, v in r.items()
                            if k not in ("n", "M", "d", "us_bf16")}))
    for p in plans:
        rows.append(dict(name=f"precision_plan/n{p['n']}_M{p['M']}",
                         us_per_call="", hbm_headroom=p["hbm_headroom"],
                         path_fp32=p["fp32"]["path"],
                         path_bf16=p["bf16"]["path"]))
    rows.append(dict(name="precision_summary", us_per_call="", **summary))
    emit(rows)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fast points only (the default; kept explicit for "
                         "the CI bench-regression job)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and args.full:
        raise SystemExit("--quick and --full are mutually exclusive")
    run(fast=not args.full)
