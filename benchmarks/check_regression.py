"""Bench-regression gate (CI: the bench-regression job).

Dispatches on the candidate's ``benchmark`` field:

* ``sweep_fusion`` — fused-sweep gate against the checked-in
  ``BENCH_sweep.json`` baseline (details below).
* ``precision_sweep`` — bf16-policy gate against ``BENCH_precision.json``:
  the achieved error vs the fp64 oracle must stay under the documented
  ceiling (baseline ``summary.error_bound``, default 1e-2), and the policy
  must keep its win — EITHER bf16 sweep-throughput geomean >= 1.3x fp32 OR
  planner-model HBM-footprint headroom geomean >= 1.8x (interpret-mode CPU
  hosts cannot see the MXU/HBM throughput win, the footprint model can) —
  with neither geomean regressing more than ``--max-regression-pct`` below
  its baseline value.
* ``lambda_path`` — shared-sweep path-solver gate against
  ``BENCH_path.json``: path-fit throughput must stay >= 2x the L-sequential
  baseline at L=8 (same-run ratio, machine-neutral; geomean across points,
  noise-robust) and must not regress more than ``--max-regression-pct``
  below the checked-in geomean; per record the CountingOps sweep counts
  must satisfy ``sweeps_seq == L * sweeps_path`` EXACTLY — the
  deterministic signal that the path solve still shares every data pass.
* ``distributed_sweep`` — mesh-sharded backend gate against
  ``BENCH_distributed.json``: per record ``psums_per_sweep`` must be 1 and
  ``comm_floats`` must equal M*p EXACTLY (the one-(M,p)-psum-per-sweep
  design invariant), the distributed-vs-single-device sweep parity must
  stay under the baseline's reassociation ceiling, and the CountingOps fit
  section must show identical sweep/gram trace counts distributed vs
  single-device with ``psums == sweeps``. Deliberately NO wall-clock or
  speedup gate — the CI harness simulates devices on shared cores.
* ``precond_blocked`` — blocked-preconditioner gate against
  ``BENCH_precond.json``: per record the blocked-vs-in-core factor
  ``parity_rel`` must stay under the baseline's ``summary.parity_ceiling``
  (default 1e-5 — the acceptance seam), ``peak_device_bytes`` must stay
  under the plan's O(b * M) ``device_ceiling_bytes``, and — wherever the
  dense footprint exceeds that ceiling — under ``dense_bytes`` too (the
  M^2 -> b * M residency claim itself). Deliberately NO wall-clock gate,
  same rationale as ``distributed_sweep``: every gated signal is exact
  arithmetic or a measured byte count.
* ``minibatch_fit`` — delayed-projection gate against
  ``BENCH_minibatch.json``: per record the minibatch-vs-full-CG val MSE
  ratio must stay under the baseline ceiling (default 1.15), the
  sweep-equivalents ratio under the budget (default 0.5 — quality parity at
  at most HALF the exact fit's data movement), and the CountingOps sweep
  count must equal ``power_iters + steps`` EXACTLY (one chunk-sized sweep
  per stochastic step). All machine-neutral; no wall clock.
* ``streaming_sweep`` — host-streaming gate against ``BENCH_streaming.json``
  (runs on the nightly full leg): per record the stream-vs-incore
  throughput ratio must stay within ``--max-regression-pct`` of the
  baseline (both sides measured in the same run, machine-neutral), the
  streamed device working set must stay strictly below the in-core one,
  and ``num_chunks`` must match the baseline exactly.
* ``knm_cache`` — materialized-K_nM-cache gate against
  ``BENCH_knm_cache.json``: per record the CountingOps cached fit must
  charge exactly one kernel evaluation per K_nM row tile
  (``fit_tile_evals == fit_tile_evals_expected``, zero recompute sweeps,
  one materialization), the ``estimate_cond`` power-iteration sweeps must
  ride the cache (cond-on == cond-off + 4 gemm_sweep program points, tile
  evals unchanged), cached-vs-recompute sweep parity must stay <= 1e-4,
  and the ``plan_cache`` routing table must match its expected tiers
  exactly. Wall clock: the same-run cached-vs-recompute CG-phase sweep
  ratio geomean must stay >= 1.5x (absolute floor) and within
  ``--max-regression-pct`` of the checked-in baseline geomean.
* ``serve_coalesce`` — coalescing-server gate against ``BENCH_serve.json``:
  coalesced serving must stay >= 2x the per-request baseline's rows/s on a
  ragged trace (same-run ratio; absolute floor ONLY — deliberately no
  ``--max-regression-pct`` band, because the cold baseline is dominated by
  XLA compile time and compile-vs-compute speed is not comparable across
  machines); per record ``retraces_after_warmup`` must be 0 EXACTLY — the
  deterministic signal that the bucket ladder still covers the traffic
  with the warmup-compiled shapes.

For ``sweep_fusion``, two gates per matching (n, M, d, block_m, block_n)
record:

* ``tile_evals_fused`` must equal the baseline exactly — more Gram-tile
  evaluations per sweep means the single-pass fusion property broke, the
  one regression that is deterministic and machine-independent.
* the **geometric mean** of ``speedup_vs_two_pass`` over all matched points
  (fused wall-clock normalized by the two-pass composition *measured in the
  same run on the same machine*) must not drop more than
  ``--max-regression-pct`` (default 20%). Raw microseconds are deliberately
  NOT gated — CI runners and interpret-mode emulation make absolute
  wall-clock incomparable across machines — and single points are not
  gated either: even best-of-5 per-point ratios swing ~15% on shared
  runners, while the cross-point geomean is stable to a few percent.

Override knobs (documented for CI):

* ``--max-regression-pct N`` or env ``BENCH_MAX_REGRESSION_PCT`` — widen or
  tighten the throughput band (e.g. a deliberate trade-off PR sets 35).
* env ``BENCH_SKIP_REGRESSION=1`` — skip the gate entirely (exit 0); for
  emergencies, the PR description should say why.

CI runs ONE invocation per job: ``--all`` globs every ``BENCH_*.json``
under ``--candidate-dir`` (the benchmark steps' artifact directory), gates
each against the checked-in baseline of the same filename, prints a
per-gate pass/fail markdown table (appended to ``$GITHUB_STEP_SUMMARY``
when set) and fails if any gate is red. Single pairs still work:

    PYTHONPATH=src python -m benchmarks.sweep_fusion --quick  # new run
    python benchmarks/check_regression.py \
        --baseline BENCH_sweep.json --candidate BENCH_sweep.json
    python benchmarks/check_regression.py --all \
        --candidate-dir bench-artifacts                       # the CI step
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

KEY = ("n", "M", "d", "block_m", "block_n")


def _index(records):
    return {tuple(r[k] for k in KEY): r for r in records}


def _geomean(values):
    prod = 1.0
    for v in values:
        prod *= v
    return prod ** (1.0 / len(values))


#: Absolute acceptance floors for the precision gate (either arm passes).
PRECISION_SPEEDUP_FLOOR = 1.3
PRECISION_HEADROOM_FLOOR = 1.8

#: Absolute acceptance floor for the lambda-path gate (at L=8).
PATH_SPEEDUP_FLOOR = 2.0

#: Absolute acceptance floor for the serving gate (ragged trace).
SERVE_SPEEDUP_FLOOR = 2.0

#: Absolute acceptance floors for the K_nM-cache gate.
KNM_CACHE_SPEEDUP_FLOOR = 1.5
KNM_CACHE_PARITY_CEILING = 1e-4


def compare_knm_cache(baseline: dict, candidate: dict, max_pct: float) -> list[str]:
    """Gate BENCH_knm_cache.json: one eval per tile + parity + 1.5x floor.

    Exact, machine-neutral invariants per record: a cached fit must charge
    ``fit_tile_evals == fit_tile_evals_expected`` kernel evaluations
    (one per K_nM row tile + the K_MM gram tiles) with ZERO recompute
    sweeps; the ``estimate_cond`` diagnostics must ride the cache
    (cond-on == cond-off + 4 gemm_sweep program points, tile evals
    unchanged); cached-vs-recompute sweep parity must stay under the 1e-4
    ceiling. The routing table must match expectations exactly. The
    wall-clock signal is the same-run cached-vs-recompute sweep ratio:
    geomean >= 1.5x absolute, and within ``--max-regression-pct`` of the
    checked-in baseline geomean.
    """
    failures = []
    for r in candidate.get("records", []):
        key = (r.get("n"), r.get("M"), r.get("d"))
        if r["fit_sweeps"] != 0 or r["fit_materializes"] != 1:
            failures.append(
                f"{key}: cached fit ran {r['fit_sweeps']} recompute sweeps / "
                f"{r['fit_materializes']} materializations (want 0 / 1) — "
                "the fit stopped consuming stored entries")
        if r["fit_tile_evals"] != r["fit_tile_evals_expected"]:
            failures.append(
                f"{key}: fit_tile_evals {r['fit_tile_evals']} != expected "
                f"{r['fit_tile_evals_expected']} — the one-kernel-eval-per-"
                "tile invariant broke")
        if r["fit_tile_evals_cond_on"] != r["fit_tile_evals_expected"]:
            failures.append(
                f"{key}: estimate_cond added kernel evaluations "
                f"({r['fit_tile_evals_cond_on']} != "
                f"{r['fit_tile_evals_expected']}) — the power-iteration "
                "diagnostics stopped riding the cache")
        if r["fit_gemm_sweeps_cond_on"] != r["fit_gemm_sweeps_cond_off"] + 4:
            failures.append(
                f"{key}: gemm_sweep program points cond-on "
                f"{r['fit_gemm_sweeps_cond_on']} != cond-off "
                f"{r['fit_gemm_sweeps_cond_off']} + 4")
        if r["parity_rel"] > KNM_CACHE_PARITY_CEILING:
            failures.append(
                f"{key}: cached-vs-recompute parity {r['parity_rel']:.2e} > "
                f"ceiling {KNM_CACHE_PARITY_CEILING}")
    for r in candidate.get("routing", []):
        if r["got_tier"] != r["expected_tier"]:
            failures.append(
                f"routing {r['scenario']}: plan_cache chose "
                f"{r['got_tier']!r}, expected {r['expected_tier']!r}")

    speedups = [r["speedup_cached"] for r in candidate.get("records", [])]
    if not speedups:
        return failures + ["candidate has no knm_cache records"]
    got = _geomean(speedups)
    print(f"cached-vs-recompute sweep speedup geomean over {len(speedups)} "
          f"points: {got:.3f} (floor {KNM_CACHE_SPEEDUP_FLOOR})")
    if got < KNM_CACHE_SPEEDUP_FLOOR:
        failures.append(
            f"speedup_cached geomean {got:.3f} < absolute floor "
            f"{KNM_CACHE_SPEEDUP_FLOOR} — the GEMM-serving win is gone")
    base = baseline.get("summary", {}).get("speedup_geomean")
    if base is not None:
        floor = float(base) * (1.0 - max_pct / 100.0)
        if got < floor:
            failures.append(
                f"speedup_cached geomean {got:.3f} < baseline "
                f"{float(base):.3f} - {max_pct:.0f}%")
    return failures


def compare_serve(baseline: dict, candidate: dict, max_pct: float) -> list[str]:
    """Gate BENCH_serve.json: zero retraces + the 2x throughput floor."""
    failures = []
    for r in candidate.get("records", []):
        key = (r.get("n"), r.get("M"), r.get("max_batch"))
        if r["retraces_after_warmup"] != 0:
            failures.append(
                f"{key}: {r['retraces_after_warmup']} XLA retraces after "
                "warmup — the bucket ladder stopped covering the ragged "
                "trace with warmup-compiled shapes")

    speedups = [r["speedup_vs_per_request"] for r in candidate.get("records", [])]
    if not speedups:
        return failures + ["candidate has no serve_coalesce records"]
    got = _geomean(speedups)
    print(f"coalesced-vs-per-request speedup geomean over {len(speedups)} "
          f"points: {got:.3f} (floor {SERVE_SPEEDUP_FLOOR})")
    if got < SERVE_SPEEDUP_FLOOR:
        failures.append(
            f"speedup_vs_per_request geomean {got:.3f} < absolute floor "
            f"{SERVE_SPEEDUP_FLOOR} — the coalescing win is gone")
    # No baseline-relative band here, unlike the other gates: the cold
    # per-request baseline is dominated by XLA compile time (one retrace per
    # distinct request size), and compile-vs-compute speed varies far more
    # across machines than the kernel ratios the other gates track. The
    # absolute floor plus the exact zero-retrace invariant are the stable
    # signals.
    return failures


def compare_lambda_path(baseline: dict, candidate: dict, max_pct: float) -> list[str]:
    """Gate BENCH_path.json: exact sweep sharing + the 2x throughput floor."""
    failures = []
    for r in candidate.get("records", []):
        key = (r.get("n"), r.get("M"), r.get("d"))
        if r["sweeps_seq"] != r["L"] * r["sweeps_path"]:
            failures.append(
                f"{key}: sweeps_seq {r['sweeps_seq']} != L={r['L']} * "
                f"sweeps_path {r['sweeps_path']} — the path solve stopped "
                "sharing the data sweep")

    speedups = [r["speedup_vs_sequential"] for r in candidate.get("records", [])]
    if not speedups:
        return failures + ["candidate has no lambda_path records"]
    got = _geomean(speedups)
    L = candidate.get("summary", {}).get("L", "?")
    print(f"path-fit speedup vs {L}-sequential geomean over "
          f"{len(speedups)} points: {got:.3f} (floor {PATH_SPEEDUP_FLOOR})")
    if got < PATH_SPEEDUP_FLOOR:
        failures.append(
            f"speedup_vs_sequential geomean {got:.3f} < absolute floor "
            f"{PATH_SPEEDUP_FLOOR} — the one-sweep-serves-all-lams win "
            "is gone")
    base = baseline.get("summary", {}).get("speedup_geomean")
    if base is not None:
        floor = float(base) * (1.0 - max_pct / 100.0)
        if got < floor:
            failures.append(
                f"speedup_vs_sequential geomean {got:.3f} < baseline "
                f"{float(base):.3f} - {max_pct:.0f}%")
    return failures


def compare_distributed(baseline: dict, candidate: dict, max_pct: float) -> list[str]:
    """Gate BENCH_distributed.json: exact comm invariants + parity ceiling.

    Deliberately NO wall-clock or speedup gate: the benchmark's simulated
    host devices share physical cores, so distributed wall clock measures
    scheduler contention, not the backend. The machine-independent signals
    are the comm counters (one (M, p) psum per sweep, M*p floats) and the
    sweep/gram count parity of the distributed fit.
    """
    failures = []
    ceiling = float(baseline.get("summary", {}).get("parity_ceiling", 1e-4))
    for r in candidate.get("records", []) + candidate.get("parity", []):
        key = (r.get("impl", "jnp"), r.get("n"), r.get("M"), r.get("devices"))
        if r["psums_per_sweep"] != 1:
            failures.append(
                f"{key}: {r['psums_per_sweep']} psums per sweep != 1 — the "
                "sweep stopped being single-collective")
        if r["comm_floats"] != r["comm_floats_expected"]:
            failures.append(
                f"{key}: comm_floats {r['comm_floats']} != M*p = "
                f"{r['comm_floats_expected']} — extra data on the wire")
        if r["parity_rel"] > ceiling:
            failures.append(
                f"{key}: distributed-vs-single parity {r['parity_rel']:.2e}"
                f" > ceiling {ceiling:.0e} — beyond psum reassociation")
    if not candidate.get("records"):
        failures.append("candidate has no distributed_sweep records")

    c = candidate.get("fit_counting")
    if c is None:
        failures.append("candidate has no fit_counting section")
    else:
        if c["sweeps_dist"] != c["sweeps_single"]:
            failures.append(
                f"fit traces {c['sweeps_dist']} sweeps distributed vs "
                f"{c['sweeps_single']} single-device — hidden re-sweeps")
        if c["grams_dist"] != c["grams_single"]:
            failures.append(
                f"fit traces {c['grams_dist']} grams distributed vs "
                f"{c['grams_single']} single-device")
        if c["psums"] != c["sweeps_dist"]:
            failures.append(
                f"fit psums {c['psums']} != sweeps {c['sweeps_dist']} — "
                "a non-sweep collective appeared")
        if c["fit_parity_rel"] > 100 * ceiling:
            failures.append(
                f"fit parity {c['fit_parity_rel']:.2e} > "
                f"{100 * ceiling:.0e} (CG amplifies the sweep ceiling; "
                "100x is the documented band)")
    if not failures:
        print(f"distributed invariants hold on "
              f"{len(candidate.get('records', []))} scaling + "
              f"{len(candidate.get('parity', []))} parity points "
              f"(ceiling {ceiling:.0e})")
    return failures


def compare_precond(baseline: dict, candidate: dict, max_pct: float) -> list[str]:
    """Gate BENCH_precond.json: exact parity + device-residency ceilings.

    Candidate-record invariants only (a --quick CI run and the checked-in
    full baseline cover different M's by design; the ceiling comes from the
    baseline summary, the measurements from the candidate). No wall clock.
    """
    failures = []
    ceiling = float(baseline.get("summary", {}).get("parity_ceiling", 1e-5))
    records = candidate.get("records", [])
    if not records:
        return ["candidate has no precond_blocked records"]
    for r in records:
        key = (r.get("M"), r.get("block"))
        if r["parity_rel"] > ceiling:
            failures.append(
                f"{key}: blocked-vs-in-core factor parity "
                f"{r['parity_rel']:.2e} > ceiling {ceiling:.0e} — the "
                "out-of-core factorization stopped matching the dense one")
        if r["peak_device_bytes"] > r["device_ceiling_bytes"]:
            failures.append(
                f"{key}: peak device bytes {r['peak_device_bytes']} > "
                f"O(b*M) ceiling {r['device_ceiling_bytes']} — the blocked "
                "path is keeping more than its two-panel working set "
                "device-resident")
        if (r["dense_bytes"] > r["device_ceiling_bytes"]
                and r["peak_device_bytes"] >= r["dense_bytes"]):
            failures.append(
                f"{key}: peak device bytes {r['peak_device_bytes']} >= "
                f"dense {r['dense_bytes']} — no residency win over in-core")
    if not failures:
        worst = max(r["parity_rel"] for r in records)
        print(f"precond invariants hold on {len(records)} points "
              f"(worst parity {worst:.2e}, ceiling {ceiling:.0e})")
    return failures


def compare_precision(baseline: dict, candidate: dict, max_pct: float) -> list[str]:
    """Gate BENCH_precision.json: error ceiling + (throughput | footprint)."""
    failures = []
    cs = candidate.get("summary", {})
    bs = baseline.get("summary", {})
    bound = float(bs.get("error_bound", 0.01))

    err = cs.get("max_rel_err")
    if err is None:
        return ["candidate has no summary.max_rel_err"]
    print(f"bf16 max error vs fp64 oracle over {cs.get('kernels')} kernels: "
          f"{err:.2e} (ceiling {bound:.0e})")
    if err > bound:
        failures.append(
            f"max_rel_err {err:.3e} > ceiling {bound:.0e} — bf16 numerics "
            "regressed past the documented error model")

    speed = float(cs.get("speedup_geomean", 0.0))
    head = float(cs.get("hbm_headroom_geomean", 0.0))
    print(f"bf16 speedup geomean {speed:.3f} (floor "
          f"{PRECISION_SPEEDUP_FLOOR}), hbm headroom geomean {head:.3f} "
          f"(floor {PRECISION_HEADROOM_FLOOR})")
    if speed < PRECISION_SPEEDUP_FLOOR and head < PRECISION_HEADROOM_FLOOR:
        failures.append(
            f"neither acceptance arm holds: speedup geomean {speed:.3f} < "
            f"{PRECISION_SPEEDUP_FLOOR} AND hbm headroom geomean {head:.3f} "
            f"< {PRECISION_HEADROOM_FLOOR}")

    # Relative regression mirrors the either/or acceptance: the throughput
    # arm is wall-clock noise on shared runners, the footprint arm is pure
    # arithmetic — only failing BOTH below baseline-minus-pct is a real
    # regression of the policy's win.
    scale = 1.0 - max_pct / 100.0
    regressed = []
    for key, got in (("speedup_geomean", speed), ("hbm_headroom_geomean", head)):
        base = bs.get(key)
        if base is not None and got < float(base) * scale:
            regressed.append(
                f"{key} {got:.3f} < baseline {float(base):.3f} - " f"{max_pct:.0f}%"
            )
    if len(regressed) == 2:
        failures.extend(regressed)
    return failures


def compare_minibatch(baseline: dict, candidate: dict, max_pct: float) -> list[str]:
    """Gate BENCH_minibatch.json: quality parity at half the data movement.

    Three candidate-record invariants, all machine-neutral (same-run MSE
    ratio, deterministic row counts, exact sweep counts — no wall clock):
    ``mse_ratio`` under the baseline's ceiling (the stochastic solver still
    reaches full-CG quality), ``equiv_ratio`` under the baseline's budget
    (and it still gets there in at most half the full fit's data passes),
    and ``counted_sweeps == expected_sweeps`` EXACTLY (one chunk-sized
    sweep per stochastic step plus the pilot's power iterations — the
    CountingOps-pinned cost model).
    """
    failures = []
    bs = baseline.get("summary", {})
    ceiling = float(bs.get("mse_ratio_ceiling", 1.15))
    budget = float(bs.get("equiv_budget", 0.5))
    records = candidate.get("records", [])
    if not records:
        return ["candidate has no minibatch_fit records"]
    for r in records:
        key = (r.get("n"), r.get("M"), r.get("chunk_rows"))
        if r["mse_ratio"] > ceiling:
            failures.append(
                f"{key}: minibatch-vs-full-CG mse ratio {r['mse_ratio']:.3f}"
                f" > ceiling {ceiling} — the delayed-projection solve "
                "stopped reaching exact-solve quality")
        if r["equiv_ratio"] > budget:
            failures.append(
                f"{key}: sweep-equivalents ratio {r['equiv_ratio']:.3f} > "
                f"budget {budget} — quality now costs more than half the "
                "full fit's data movement")
        if r["counted_sweeps"] != r["expected_sweeps"]:
            failures.append(
                f"{key}: counted sweeps {r['counted_sweeps']} != expected "
                f"{r['expected_sweeps']} — a stochastic step stopped "
                "costing exactly one chunk-sized sweep")
    if not failures:
        worst = max(r["mse_ratio"] for r in records)
        print(f"minibatch invariants hold on {len(records)} points "
              f"(worst mse ratio {worst:.3f}, ceiling {ceiling}; "
              f"budget {budget})")
    return failures


def compare_streaming(baseline: dict, candidate: dict, max_pct: float) -> list[str]:
    """Gate for ``streaming_sweep.py`` payloads.

    Machine-neutral invariants: the streamed sweep must stay within
    ``max_pct`` of the baseline's stream-vs-incore throughput ratio (both
    sides of the ratio are measured on the same machine), keep its device
    working set strictly below the in-core one, and walk the exact same
    chunk count.
    """
    key = ("n", "M", "chunk_rows", "prefetch")
    base = {tuple(r[k] for k in key): r for r in baseline["records"]}
    cand = {tuple(r[k] for k in key): r for r in candidate["records"]}
    failures = []
    ratios = []
    for k, b in base.items():
        c = cand.get(k)
        if c is None:
            failures.append(f"{k}: baseline point missing from candidate")
            continue
        floor = b["stream_vs_incore_ratio"] * (1.0 - max_pct / 100.0)
        ratios.append((k, c["stream_vs_incore_ratio"], floor))
        if c["stream_vs_incore_ratio"] < floor:
            failures.append(
                f"{k}: stream/incore throughput ratio "
                f"{c['stream_vs_incore_ratio']:.3f} < floor {floor:.3f}"
            )
        if c["device_workingset_bytes_stream"] >= c["device_workingset_bytes_incore"]:
            failures.append(
                f"{k}: streaming working set "
                f"{c['device_workingset_bytes_stream']} is not below in-core "
                f"{c['device_workingset_bytes_incore']}"
            )
        if c["num_chunks"] != b["num_chunks"]:
            failures.append(
                f"{k}: num_chunks {c['num_chunks']} != baseline {b['num_chunks']}"
            )
    if not ratios and not failures:
        failures.append("no baseline points matched the candidate run")
    if not failures:
        worst = min(r for _, r, _ in ratios)
        print(
            f"streaming invariants hold on {len(ratios)} points "
            f"(worst stream/incore ratio {worst:.3f})"
        )
    return failures


def compare(baseline: dict, candidate: dict, max_pct: float) -> list[str]:
    """Return a list of human-readable failures (empty == gate passes)."""
    base = _index(baseline["records"])
    cand = _index(candidate["records"])
    failures = []
    base_speedups, cand_speedups = [], []
    for key, b in base.items():
        c = cand.get(key)
        if c is None:
            failures.append(f"{key}: baseline point missing from candidate")
            continue
        base_speedups.append(b["speedup_vs_two_pass"])
        cand_speedups.append(c["speedup_vs_two_pass"])
        if c["tile_evals_fused"] != b["tile_evals_fused"]:
            failures.append(
                f"{key}: tile_evals_fused {c['tile_evals_fused']} != "
                f"baseline {b['tile_evals_fused']} — single-pass fusion "
                "property regressed"
            )
    if not base_speedups:
        failures.append("no baseline points matched the candidate run")
        return failures
    got = _geomean(cand_speedups)
    floor = _geomean(base_speedups) * (1.0 - max_pct / 100.0)
    print(
        f"speedup_vs_two_pass geomean over {len(cand_speedups)} points: "
        f"{got:.3f} (floor {floor:.3f})"
    )
    if got < floor:
        failures.append(
            f"speedup_vs_two_pass geomean {got:.3f} < {floor:.3f} "
            f"(baseline {_geomean(base_speedups):.3f} - {max_pct:.0f}%)"
        )
    return failures


GATES = {
    "knm_cache": compare_knm_cache,
    "precision_sweep": compare_precision,
    "lambda_path": compare_lambda_path,
    "serve_coalesce": compare_serve,
    "distributed_sweep": compare_distributed,
    "precond_blocked": compare_precond,
    "minibatch_fit": compare_minibatch,
    "streaming_sweep": compare_streaming,
}


def run_pair(
    baseline_path: str, candidate_path: str, max_pct: float
) -> tuple[str, list[str]]:
    """Run one gate; returns (benchmark kind, failure lines)."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    with open(candidate_path) as f:
        candidate = json.load(f)
    kind = candidate.get("benchmark", "sweep_fusion")
    if baseline.get("benchmark", kind) != kind:
        return kind, [
            f"baseline benchmark {baseline.get('benchmark')!r} != "
            f"candidate {kind!r}"
        ]
    gate = GATES.get(kind, compare)
    return kind, gate(baseline, candidate, max_pct)


def _step_summary(rows: list[tuple[str, str, str, str]]) -> None:
    """Append the per-gate markdown table to ``$GITHUB_STEP_SUMMARY``
    (printed to stdout too, so local runs see the same table)."""
    lines = [
        "## Bench-regression gates",
        "",
        "| gate | benchmark | result | detail |",
        "|---|---|---|---|",
    ]
    lines += [f"| {f} | {k} | {res} | {det} |" for f, k, res, det in rows]
    table = "\n".join(lines)
    print(table)
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if path:
        with open(path, "a") as f:
            f.write(table + "\n")


def run_all(candidate_dir: str, baseline_dir: str, max_pct: float) -> int:
    """Discover and gate every ``BENCH_*.json`` pair — the ONE CI step.

    Candidates are whatever the benchmark steps dropped in
    ``candidate_dir``; each is gated against the checked-in baseline of the
    same filename in ``baseline_dir``. A baseline with no candidate is
    reported (surfacing a benchmark that silently stopped running) but not
    failed — jobs deliberately run subsets (the distributed benchmark lives
    in its own job). Emits the per-gate pass/fail markdown table to
    ``$GITHUB_STEP_SUMMARY`` and returns nonzero if any gate failed.
    """
    names = sorted(os.path.basename(p) for p in
                   glob.glob(os.path.join(candidate_dir, "BENCH_*.json")))
    if not names:
        print(f"no BENCH_*.json candidates under {candidate_dir}")
        return 1
    rows, bad = [], 0
    for name in names:
        baseline_path = os.path.join(baseline_dir, name)
        candidate_path = os.path.join(candidate_dir, name)
        if not os.path.exists(baseline_path):
            rows.append((name, "?", "❌ fail", "no checked-in baseline of this name"))
            bad += 1
            continue
        print(f"--- {name}")
        kind, failures = run_pair(baseline_path, candidate_path, max_pct)
        if failures:
            for line in failures:
                print(f"  {line}")
            rows.append(
                (name, kind, "❌ fail", f"{len(failures)} failure(s): {failures[0]}")
            )
            bad += 1
        else:
            rows.append((name, kind, "✅ pass", ""))
    for name in sorted(os.path.basename(p) for p in
                       glob.glob(os.path.join(baseline_dir,
                                              "BENCH_*.json"))):
        if name not in names:
            rows.append((name, "?", "⬜ no candidate",
                         "baseline present but this job ran no candidate"))
    _step_summary(rows)
    if bad:
        print(f"bench-regression gate FAILED: {bad}/{len(names)} gates red "
              "(override: --max-regression-pct / BENCH_MAX_REGRESSION_PCT, "
              "or BENCH_SKIP_REGRESSION=1 with a justification in the PR)")
        return 1
    print(f"bench-regression gate passed: {len(names)} gates green")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_sweep.json")
    ap.add_argument(
        "--candidate",
        help="json written by a fresh benchmark run "
        "(BENCH_SWEEP_JSON=... python -m benchmarks.sweep_fusion --quick)",
    )
    ap.add_argument(
        "--all", action="store_true",
        help="gate every BENCH_*.json under --candidate-dir against the "
        "checked-in baseline of the same name; one markdown summary table",
    )
    ap.add_argument("--candidate-dir", default="bench-artifacts")
    ap.add_argument("--baseline-dir", default=".")
    ap.add_argument(
        "--max-regression-pct",
        type=float,
        default=float(os.environ.get("BENCH_MAX_REGRESSION_PCT", 20.0)),
    )
    args = ap.parse_args(argv)

    if os.environ.get("BENCH_SKIP_REGRESSION") == "1":
        print("BENCH_SKIP_REGRESSION=1 — bench-regression gate skipped")
        return 0

    if args.all:
        return run_all(args.candidate_dir, args.baseline_dir, args.max_regression_pct)
    if not args.candidate:
        ap.error("--candidate is required (or use --all)")

    kind, failures = run_pair(args.baseline, args.candidate, args.max_regression_pct)
    if failures:
        print(f"bench-regression gate FAILED ({kind}):")
        for line in failures:
            print(f"  {line}")
        print(
            "(override: --max-regression-pct / BENCH_MAX_REGRESSION_PCT, "
            "or BENCH_SKIP_REGRESSION=1 with a justification in the PR)"
        )
        return 1
    print(
        f"bench-regression gate passed ({kind}) within "
        f"{args.max_regression_pct:.0f}% tolerance"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
