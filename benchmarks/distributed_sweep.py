"""Multi-device data-parallel FALKON: scaling + comm-invariant benchmark.

Runs the mesh-sharded ``DistributedOps`` backend over 1/2/4/8 simulated
host devices (``--xla_force_host_platform_device_count``, set below before
jax imports) and writes ``BENCH_distributed.json`` with three sections:

1. **Scaling records** — the ``K_nM^T (K_nM u + v)`` sweep timed per device
   count, with rows/s and the same-run ratio vs the 1-device mesh. On a CI
   host the simulated devices SHARE physical cores, so wall-clock speedup
   is not expected and deliberately not gated; the numbers document the
   harness and become meaningful on real multi-chip hardware.

2. **Comm invariants** (the gated signals, all machine-independent):
   ``psums_per_sweep`` must be exactly 1 — the backend's whole design is
   that the (M, p) partial is the ONLY collective per sweep — and
   ``comm_floats`` must be exactly M*p. ``parity_rel`` (distributed vs
   single-device sweep on identical inputs) must stay under the psum-
   reassociation ceiling: fp32 summed in a different order, not an
   approximation. Checked for jnp AND pallas inner backends.

3. **Fit counting** — a ``CountingOps`` wrapped by ``DistributedOps``
   through a full ``falkon_fit``: the distributed fit must trace exactly
   the sweep/gram counts of the single-device fit (no hidden per-shard
   re-sweeps), and the psum count must equal the sweep count.

Gated by ``benchmarks/check_regression.py --baseline BENCH_distributed.json``
(the ``distributed_sweep`` gate): exact invariants + the parity ceiling,
never wall clock.

    PYTHONPATH=src python -m benchmarks.distributed_sweep [--full]
"""
from __future__ import annotations

import os

# Must precede any jax import in this process: device count is fixed at
# backend init. Respect an existing override (e.g. CI exporting 8 already).
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import FalkonConfig, GaussianKernel, falkon_fit
from repro.ops import CountingOps, DistributedOps, get_ops

from .common import emit, timed_best, write_payload

FAST_POINTS = [(16384, 512, 32)]
FULL_POINTS = FAST_POINTS + [(65536, 1024, 32)]

#: distributed-vs-single-device sweep parity ceiling. The only difference
#: is fp32 summation order (per-shard partials psum'd vs one global scan),
#: measured ~1e-7; 1e-4 leaves two orders of headroom without letting a
#: real numeric break through.
PARITY_CEILING = 1e-4

DEVICE_COUNTS = (1, 2, 4, 8)


def _mesh(k: int) -> Mesh:
    return Mesh(np.asarray(jax.devices()[:k]), ("data",))


def _scaling_point(n: int, M: int, d: int) -> list[dict]:
    rng = np.random.default_rng(n + M + d)
    X = jnp.asarray(rng.standard_normal((n, d), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((n,), dtype=np.float32))
    u = jnp.asarray(rng.standard_normal((M,), dtype=np.float32))
    C = X[:M]

    inner = get_ops("jnp", GaussianKernel(sigma=2.0), block_size=4096)
    ref, t_single = timed_best(
        jax.jit(lambda X, C, u, v: inner.sweep(X, C, u, v)), X, C, u, v, repeat=5
    )

    records = []
    t_one = None
    for k in DEVICE_COUNTS:
        dist = DistributedOps(inner, _mesh(k), ("data",))
        fn = jax.jit(lambda X, C, u, v: dist.sweep(X, C, u, v))
        out, t = timed_best(fn, X, C, u, v, repeat=5)
        if t_one is None:
            t_one = t
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        records.append(dict(
            n=n, M=M, d=d, devices=k,
            backend=jax.default_backend(),
            us_per_sweep=round(t * 1e6, 1),
            rows_per_s=round(n / t, 1),
            speedup_vs_1dev=round(t_one / t, 3),
            # the gated invariants: jit traces the sweep ONCE, so the
            # counters read exactly the per-traced-sweep comm cost
            psums_per_sweep=dist.psums,
            comm_floats=dist.psum_floats,
            comm_floats_expected=M * 1,
            parity_rel=rel,
            n_local=-(-n // k),
        ))
    return records


def _parity_point(impl: str, n: int, M: int, d: int) -> dict:
    rng = np.random.default_rng(7)
    X = jnp.asarray(rng.standard_normal((n, d), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((n,), dtype=np.float32))
    u = jnp.asarray(rng.standard_normal((M,), dtype=np.float32))
    C = X[:M]
    inner = get_ops(impl, GaussianKernel(sigma=2.0), block_size=1024)
    dist = DistributedOps(inner, _mesh(8), ("data",))
    ref = inner.sweep(X, C, u, v)
    got = dist.sweep(X, C, u, v)
    return dict(
        impl=impl,
        n=n,
        M=M,
        d=d,
        devices=8,
        parity_rel=float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref)),
        psums_per_sweep=dist.psums,
        comm_floats=dist.psum_floats,
        comm_floats_expected=M,
        plan_local=dataclasses.asdict(dist.plan(n, M, d, 1)),
    )


def _fit_counting(n: int, M: int, d: int) -> dict:
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    X = jax.random.normal(k1, (n, d))
    y = jnp.sin(X @ jax.random.normal(k2, (d,)))
    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", 2.0),),
        lam=1e-4,
        num_centers=M,
        iterations=10,
        block_size=1024,
    )
    count_1 = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=1024))
    falkon_fit(jax.random.PRNGKey(1), X, y, cfg, ops=count_1)
    count_8 = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=1024))
    dist = DistributedOps(count_8, _mesh(8), ("data",))
    cfg_8 = dataclasses.replace(cfg, mesh=dist.mesh)
    est_8, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg_8, ops=dist)
    est_1, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
    p1, p8 = est_1.predict(X), est_8.predict(X)
    return dict(
        n=n,
        M=M,
        d=d,
        devices=8,
        iterations=cfg.iterations,
        sweeps_single=count_1.sweeps,
        sweeps_dist=count_8.sweeps,
        grams_single=count_1.grams,
        grams_dist=count_8.grams,
        psums=dist.psums,
        fit_parity_rel=float(jnp.linalg.norm(p8 - p1) / jnp.linalg.norm(p1)),
    )


def run(fast: bool = True):
    points = FAST_POINTS if fast else FULL_POINTS
    scaling = [r for pt in points for r in _scaling_point(*pt)]
    parity = [
        _parity_point("jnp", 8192, 256, 16), _parity_point("pallas", 2048, 128, 16)
    ]
    counting = _fit_counting(4096, 256, 8)

    payload = {
        "benchmark": "distributed_sweep",
        "records": scaling,
        "parity": parity,
        "fit_counting": counting,
        "summary": {
            "parity_ceiling": PARITY_CEILING,
            "devices": list(DEVICE_COUNTS),
            "comm_model": "one (M, p) psum per sweep = M*p floats per "
                          "CG iteration, independent of n and devices",
        },
    }
    out = write_payload(payload, "BENCH_DISTRIBUTED_JSON", "BENCH_distributed.json")

    rows = []
    for r in scaling:
        rows.append(dict(
            name=f"distributed_sweep/n{r['n']}_M{r['M']}_dev{r['devices']}",
            us_per_call=r["us_per_sweep"],
            rows_per_s=r["rows_per_s"],
            speedup_vs_1dev=r["speedup_vs_1dev"],
            psums_per_sweep=r["psums_per_sweep"],
            comm_floats=r["comm_floats"],
            parity_rel=f"{r['parity_rel']:.2e}",
        ))
    for r in parity:
        rows.append(dict(
            name=f"distributed_parity/{r['impl']}",
            us_per_call="",
            parity_rel=f"{r['parity_rel']:.2e}",
            psums_per_sweep=r["psums_per_sweep"],
            plan_path=r["plan_local"]["path"],
        ))
    c = counting
    rows.append(dict(
        name="distributed_fit/counting",
        us_per_call="",
        sweeps=f"{c['sweeps_dist']}/{c['sweeps_single']}",
        grams=f"{c['grams_dist']}/{c['grams_single']}",
        psums=c["psums"],
        fit_parity_rel=f"{c['fit_parity_rel']:.2e}",
    ))
    emit(rows)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(fast=not ap.parse_args().full)
