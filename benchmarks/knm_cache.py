"""Materialized-K_nM-cache benchmark: cached GEMM sweeps vs recompute.

Measures the tentpole claim end to end — once the kernel entries are
evaluated and stored, the CG phase runs on GEMMs and stops paying the
pairwise-distance + exp() kernel math every iteration — and writes
``BENCH_knm_cache.json`` (path override: env ``BENCH_KNM_CACHE_JSON``),
gated in CI by ``benchmarks/check_regression.py``:

* ``speedup_cached`` — wall-clock of the recompute CG-phase sweep
  ``K_nM^T (K_nM u)`` over the cached GEMM sweep from stored entries, both
  jitted and measured in the same run on the same machine (machine-neutral
  ratio). Gate floor: 1.5x geomean on the Gaussian kernel.
* ``parity_rel`` — cached vs recompute sweep agreement, must stay <= 1e-4
  (fp32 device tier is bit-identical pre-jit; the ceiling absorbs XLA
  fusion reassociation).
* exact tile-eval counts — a ``CountingOps`` cached fit must charge ONE
  kernel evaluation per K_nM row tile (``fit_tile_evals ==
  fit_tile_evals_expected``, i.e. ceil(n/bs) + ceil(M/bs) for the K_MM
  gram) with ``fit_sweeps == 0``; the ``estimate_cond`` power-iteration
  diagnostics must ride the cache too (``fit_gemm_sweeps_cond_on ==
  fit_gemm_sweeps_cond_off + 4`` program points, no extra tile evals).
* the ``routing`` table — ``plan_cache`` tier decisions for a grid of
  (bytes, budget, shards, forced) scenarios must match expectations
  EXACTLY (the budget-routing contract is configuration, not chance).

Runs on the jnp reference backend: the cached-vs-recompute ratio is
backend-agnostic (both arms share the backend) and interpret-mode Pallas
on CPU CI would measure the emulator, not the algorithm.

    PYTHONPATH=src python -m benchmarks.knm_cache [--quick | --full]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FalkonConfig, falkon_fit
from repro.ops import CountingOps, KernelCache, get_ops, plan_cache, resolve_precision

from .check_regression import _geomean
from .common import emit, timed_best, write_payload

#: (n, M, d) sweep-throughput points. M spans the paper's sqrt(n) regime.
FAST_POINTS = [(8192, 512, 16), (8192, 2048, 16)]
FULL_POINTS = FAST_POINTS + [(65536, 512, 16), (65536, 2048, 16)]

#: CG width and fit iterations for the counting section.
FIT_ITERS = 8
BLOCK_SIZE = 2048

SPEEDUP_FLOOR = 1.5     # CI gate: cached CG-phase sweep vs recompute
PARITY_CEILING = 1e-4   # CI gate: cached vs recompute sweep agreement


def _problem(n, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(ks[0], (n, d))
    w = jax.random.normal(ks[1], (d,))
    y = jax.numpy.sin(X @ w) + 0.05 * jax.random.normal(ks[2], (n,))
    return X, y


def _fit_counts(X, y, M, *, estimate_cond):
    """CountingOps counters for one cached fit (deterministic, untimed)."""
    cfg = FalkonConfig(
        num_centers=M, iterations=FIT_ITERS, block_size=BLOCK_SIZE,
        jitter=1e-5, lam=1e-4, knm_cache="device",
        estimate_cond=estimate_cond,
    )
    ops = CountingOps(get_ops("jnp", cfg.make_kernel(), block_size=BLOCK_SIZE))
    falkon_fit(jax.random.PRNGKey(1), X, y, cfg, ops=ops)
    return ops


def run(points, repeat=3):
    records = []
    for n, M, d in points:
        X, y = _problem(n, d)
        kern = FalkonConfig().make_kernel()
        ops = get_ops("jnp", kern, block_size=BLOCK_SIZE)
        C = X[:M]
        u = jax.random.normal(jax.random.PRNGKey(2), (M,))
        plan = plan_cache(n, M, policy=ops.policy, tier="device")
        cache = KernelCache(ops, X, C, plan=plan)

        # Both arms jitted; K enters as a jit ARGUMENT (a closure constant
        # would invite constant-folding into a different program than the
        # fit runs). The mask is whatever the cache itself would fold in —
        # None at these aligned sizes (the no-mask fast path the fit takes).
        recompute = jax.jit(lambda uu: ops.sweep(X, C, uu))
        mask = cache._mask(None)
        cached = jax.jit(lambda K, uu: ops.gemm_sweep(K, uu, None, mask))

        ref, sec_recompute = timed_best(recompute, u, repeat=repeat)
        got, sec_cached = timed_best(cached, cache.K, u, repeat=repeat)
        parity = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))

        ops_off = _fit_counts(X, y, M, estimate_cond=False)
        ops_on = _fit_counts(X, y, M, estimate_cond=True)
        nb, mt = -(-n // BLOCK_SIZE), -(-M // BLOCK_SIZE)
        bf16_bytes = plan_cache(n, M, policy=resolve_precision("bf16")).cache_bytes

        rec = dict(
            n=n,
            M=M,
            d=d,
            impl="jnp",
            tier=cache.tier,
            block_size=BLOCK_SIZE,
            time_recompute_s=sec_recompute,
            time_cached_s=sec_cached,
            speedup_cached=sec_recompute / sec_cached,
            parity_rel=parity,
            cache_bytes=plan.cache_bytes,
            cache_bytes_bf16=bf16_bytes,
            fit_sweeps=ops_off.sweeps,
            fit_materializes=ops_off.materializes,
            fit_tile_evals=ops_off.gram_tile_evals,
            fit_tile_evals_expected=nb + mt,
            fit_gemm_sweeps_cond_off=ops_off.gemm_sweeps,
            fit_gemm_sweeps_cond_on=ops_on.gemm_sweeps,
            fit_tile_evals_cond_on=ops_on.gram_tile_evals,
        )
        records.append(rec)
        print(f"n={n} M={M} d={d}: recompute {sec_recompute * 1e3:.2f}ms, "
              f"cached {sec_cached * 1e3:.2f}ms -> "
              f"{rec['speedup_cached']:.2f}x (parity {parity:.2e}, "
              f"tile evals {rec['fit_tile_evals']}/"
              f"{rec['fit_tile_evals_expected']})")
    return records


def routing_table():
    """plan_cache tier decisions for explicit-budget scenarios — gated as
    exact expected == got rows (budgets in bytes, not env, so the table is
    deterministic on any machine)."""
    MiB = 2**20
    fp32 = resolve_precision("fp32")
    bf16 = resolve_precision("bf16")
    # (label, kwargs, expected tier); 8192 x 2048 fp32 = 64 MiB
    scenarios = [
        ("fits_device", dict(budget=128 * MiB), "device"),
        ("spills_host", dict(budget=32 * MiB, host_budget=128 * MiB), "host"),
        ("busts_both", dict(budget=32 * MiB, host_budget=32 * MiB), "off"),
        ("sharded_fits", dict(budget=32 * MiB, shards=4), "device"),
        ("bf16_halves", dict(budget=48 * MiB, policy=bf16), "device"),
        ("forced_host", dict(budget=1024 * MiB, tier="host"), "host"),
        ("forced_off", dict(budget=1024 * MiB, tier="off"), "off"),
    ]
    rows = []
    for label, kw, want in scenarios:
        kw.setdefault("policy", fp32)
        p = plan_cache(8192, 2048, **kw)
        rows.append(dict(
            scenario=label,
            n=8192,
            M=2048,
            shards=p.shards,
            itemsize=p.itemsize,
            shard_bytes=p.shard_bytes,
            budget_bytes=p.budget_bytes,
            host_budget_bytes=p.host_budget_bytes,
            expected_tier=want,
            got_tier=p.tier,
            reason=p.reason,
        ))
        print(f"routing {label}: expected {want}, got {p.tier} ({p.reason})")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI points, fewer repeats")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    points = FULL_POINTS if args.full else FAST_POINTS
    repeat = 2 if args.quick else 3

    records = run(points, repeat=repeat)
    routing = routing_table()
    summary = dict(
        speedup_geomean=_geomean([r["speedup_cached"] for r in records]),
        parity_ceiling=PARITY_CEILING,
        speedup_floor=SPEEDUP_FLOOR,
        block_size=BLOCK_SIZE,
        fit_iterations=FIT_ITERS,
    )
    payload = {
        "benchmark": "knm_cache",
        "records": records,
        "routing": routing,
        "summary": summary,
    }
    out = write_payload(payload, "BENCH_KNM_CACHE_JSON", "BENCH_knm_cache.json")
    print(f"wrote {out}: cached-sweep speedup geomean "
          f"{summary['speedup_geomean']:.2f}x over {len(records)} points")

    rows = [dict(name=f"knm_cache_n{r['n']}_M{r['M']}",
                 us_per_call=f"{r['time_cached_s'] * 1e6:.0f}",
                 speedup=f"{r['speedup_cached']:.2f}",
                 parity=f"{r['parity_rel']:.1e}")
            for r in records]
    emit(rows)


if __name__ == "__main__":
    main()
