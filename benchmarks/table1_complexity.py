"""Paper Table 1 — computational complexity for optimal generalization.

Empirical check of the scaling rows (CPU, scaled-down): at the paper's
hyperparameters (lam = n^-1/2, M = c sqrt(n), t = O(log n)), FALKON's wall
time should scale ~ n^1.5 while exact KRR scales ~ n^3 (direct) / n^2
(gradient), with all methods reaching comparable test error. We fit the
empirical exponent over a geometric n-sweep.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import (
    FalkonConfig, falkon_fit, krr_direct, krr_gradient, nystrom_direct
)
from repro.data.synthetic import KernelTask, make_kernel_dataset

from .common import emit, mse, timed


def _fit_exponent(ns, ts):
    return float(np.polyfit(np.log(ns), np.log(ts), 1)[0])


def run(fast: bool = True):
    ns = [2000, 8000, 24000] if fast else [4000, 16000, 64000]
    task = KernelTask(
        "scaling", n=max(ns), d=10, task="regression", sigma=3.0, lam=0.0, num_centers=0
    )
    key = jax.random.PRNGKey(0)
    Xa, ya = make_kernel_dataset(key, task)
    Xte, yte = make_kernel_dataset(jax.random.PRNGKey(9), task, n=1000)

    # whole-fit jit: wall times measure the algorithm, not python dispatch
    jit_fit = jax.jit(falkon_fit, static_argnames=("config",))

    rows = []
    times = {m: [] for m in ("falkon", "nystrom_direct", "krr_direct", "krr_gradient")}
    opcounts = {
        m: [] for m in ("falkon", "nystrom_direct", "krr_direct", "krr_gradient")
    }
    for n in ns:
        X, y = Xa[:n], ya[:n]
        lam = 1.0 / np.sqrt(n)
        M = int(3 * np.sqrt(n))
        t_iter = max(8, int(np.log(n)) + 5)

        cfg = FalkonConfig(
            kernel="gaussian",
            kernel_params=(("sigma", 3.0),),
            lam=lam,
            num_centers=M,
            iterations=t_iter,
            block_size=2048,
        )
        (est, _), dt = timed(lambda: jit_fit(jax.random.PRNGKey(1), X, y, config=cfg))
        times["falkon"].append(dt)
        # kernel-evaluation counts (the paper's accounting unit):
        opcounts["falkon"].append(n * M * (t_iter + 2) + M**3 / 3)
        opcounts["nystrom_direct"].append(n * M * 2 + n * M**2 + M**3 / 3)
        opcounts["krr_direct"].append(n**3 / 3 + n**2)
        opcounts["krr_gradient"].append(n**2 * int(np.sqrt(n)))
        err_f = mse(est.predict(Xte), yte)

        kern = cfg.make_kernel()
        C = est.centers
        (ny), dt = timed(lambda: nystrom_direct(X, y, C, kern, lam))
        times["nystrom_direct"].append(dt)
        err_ny = mse(ny.predict(Xte), yte)

        if n <= 8000:       # exact KRR beyond this is impractical on CPU
            (kr), dt = timed(lambda: krr_direct(X, y, kern, lam))
            times["krr_direct"].append(dt)
            err_kr = mse(kr.predict(Xte), yte)
            (kg), dt = timed(lambda: krr_gradient(X, y, kern, lam, t=int(np.sqrt(n))))
            times["krr_gradient"].append(dt)
            err_kg = mse(kg.predict(Xte), yte)
        else:
            err_kr = err_kg = float("nan")

        rows.append(dict(name=f"table1/n{n}",
                         us_per_call=round(times["falkon"][-1] * 1e6),
                         falkon_s=round(times["falkon"][-1], 3),
                         nystrom_s=round(times["nystrom_direct"][-1], 3),
                         krr_s=round(times["krr_direct"][-1], 3)
                         if n <= 8000 else "n/a",
                         krr_grad_s=round(times["krr_gradient"][-1], 3)
                         if n <= 8000 else "n/a",
                         mse_falkon=round(err_f, 4), mse_nystrom=round(err_ny, 4),
                         mse_krr=round(err_kr, 4), mse_krr_grad=round(err_kg, 4)))

    paper_exp = {
        "falkon": 1.5, "nystrom_direct": 2.0, "krr_direct": 3.0, "krr_gradient": 2.5
    }
    for m, ts in times.items():
        nsub = ns[:len(ts)]
        rows.append(dict(
            name=f"table1/exponent_{m}", us_per_call="",
            wall_exponent=round(_fit_exponent(nsub, ts), 2),
            opcount_exponent=round(_fit_exponent(
                ns[:len(opcounts[m])], opcounts[m]), 2),
            paper_exponent=paper_exp[m]))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
