"""Theory-validation benchmarks (paper Thm 1/2/3 behaviour).

* cond(B^T H B) vs M              — Thm 2: bounded by ~17 once M ≳ c/λ·log.
* gap-to-Nystrom vs t             — Thm 1: e^{-t/2}-type exponential decay.
* excess risk vs n at λ=n^{-1/2}  — Thm 3: slope ≈ -1/2 on a log-log fit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import enable_x64
from repro.core import (
    FalkonConfig,
    falkon_fit,
    falkon_solve,
    make_preconditioner,
    nystrom_direct,
    uniform_centers,
)
from repro.data.synthetic import KernelTask, make_kernel_dataset

from .common import emit, timed


def run(fast: bool = True):
    rows = []
    task = KernelTask(
        "conv",
        n=6000,
        d=8,
        task="regression",
        sigma=3.0,
        lam=0.0,
        num_centers=0,
        noise=0.05,
    )
    X, y = make_kernel_dataset(jax.random.PRNGKey(0), task, n=6000)

    # --- cond(W) vs M (Thm 2) ---
    lam = 1e-4
    conds = {}
    for M in (25, 100, 400):
        cfg = FalkonConfig(
            kernel="gaussian",
            kernel_params=(("sigma", 3.0),),
            lam=lam,
            num_centers=M,
            iterations=3,
        )
        (_, st), _ = timed(lambda: falkon_fit(jax.random.PRNGKey(1), X, y, cfg))
        conds[M] = round(float(st.cond_estimate), 2)
    rows.append(dict(name="convergence/cond_vs_M", us_per_call="",
                     **{f"M{m}": c for m, c in conds.items()},
                     thm2_threshold=17.0))

    # --- exponential decay in t (Thm 1) ---
    # fp64: the "exact Nystrom" REFERENCE needs it (the fp32 direct solve is
    # the unstable one — that is the paper's own point about conditioning)
    kern = FalkonConfig(
        kernel="gaussian", kernel_params=(("sigma", 3.0),)
    ).make_kernel()
    with enable_x64(True):
        X64 = X.astype(jnp.float64)
        y64 = y.astype(jnp.float64)
        sel = uniform_centers(jax.random.PRNGKey(2), X64, 300)
        KMM = kern(sel.centers, sel.centers)
        pre = make_preconditioner(KMM, lam, X64.shape[0])
        ny = nystrom_direct(X64, y64, sel.centers, kern, lam, jitter=0.0)
        probe = X64[:1500]
        p_ny = ny.predict(probe)
        gaps = {}
        for t in (1, 3, 5, 10, 20):
            st = falkon_solve(X64, y64, sel.centers, pre, kern, lam, t)
            from repro.core import knm_apply
            p_f = knm_apply(probe, sel.centers, st.alpha, kern)
            g = float(
                jnp.linalg.norm(p_f - p_ny) / jnp.maximum(jnp.linalg.norm(p_ny), 1e-12)
            )
            gaps[t] = max(g, 1e-12)
    # fitted rate: log gap ~ -nu t; Thm 1/2 predict nu >= 1/2
    ts = np.array(sorted(gaps))
    gs = np.array([max(gaps[t], 1e-14) for t in ts])
    nu = -float(np.polyfit(ts, np.log(gs), 1)[0])
    rows.append(dict(name="convergence/decay_in_t", us_per_call="",
                     **{f"t{t}": f"{g:.2e}" for t, g in gaps.items()},
                     fitted_nu=round(nu, 2), thm_nu=0.5))

    # --- n^{-1/2} learning rate (Thm 3) ---
    # f* IN the RKHS of the kernel used (f* = sum_j a_j K(., z_j)) — the
    # source condition r=1/2 of Thm 3 holds exactly, so the minimax rate is
    # the right yardstick. Train/test share f*; test targets are noiseless.
    ns = [500, 1000, 2000, 4000] if fast else [1000, 2000, 4000, 8000, 16000]
    kernf = FalkonConfig(
        kernel="gaussian", kernel_params=(("sigma", 3.0),)
    ).make_kernel()
    kz, ka, kx, kxe, knz = jax.random.split(jax.random.PRNGKey(77), 5)
    d = 8
    z = jax.random.normal(kz, (32, d))
    a = jax.random.normal(ka, (32,)) / jnp.sqrt(32.0)
    Xall = jax.random.normal(kx, (max(ns), d))
    clean_tr = kernf(Xall, z) @ a
    yall = clean_tr + 0.3 * jax.random.normal(knz, (max(ns),))
    Xte = jax.random.normal(kxe, (2000, d))
    yte_clean = kernf(Xte, z) @ a
    errs = []
    for n in ns:
        Xn, yn = Xall[:n], yall[:n]
        cfg = FalkonConfig(
            kernel="gaussian",
            kernel_params=(("sigma", 3.0),),
            lam=float(1 / np.sqrt(n)),
            num_centers=int(4 * np.sqrt(n)),
            iterations=max(8, int(np.log(n)) + 5),
        )
        (est, _), _ = timed(lambda: falkon_fit(jax.random.PRNGKey(3), Xn, yn, cfg))
        errs.append(float(jnp.mean((est.predict(Xte) - yte_clean) ** 2)))
    slope = float(np.polyfit(np.log(ns), np.log(errs), 1)[0])
    rows.append(dict(name="convergence/rate_in_n", us_per_call="",
                     **{f"n{n}": f"{e:.2e}" for n, e in zip(ns, errs)},
                     fitted_slope=round(slope, 2), thm3_slope=-0.5))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
