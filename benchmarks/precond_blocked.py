"""Blocked vs in-core preconditioner factorization benchmark.

For each M, factors the same host-resident SPD matrix twice — in-core
``jnp.linalg.cholesky`` and the tiled right-looking blocked path
(``repro.kernels.blocked_cholesky``) — and writes ``BENCH_precond.json``
with, per point:

* ``parity_rel`` — blocked-vs-in-core factor relative error (gated:
  <= ``summary.parity_ceiling`` = 1e-5, the ISSUE 7 acceptance seam).
* ``peak_device_bytes`` — the blocked path's self-accounted peak device
  residency (gated: <= ``device_ceiling_bytes`` = the ``FactorPlan``'s
  3 * 2 * block * M * itemsize O(b * M) bound, and < ``dense_bytes``
  whenever dense exceeds the ceiling — the M^2 -> b * M claim itself).
* wall-clock for both paths — recorded for the curious, deliberately NOT
  gated (same rationale as ``distributed_sweep``: CI runners and
  interpret/CPU hosts make absolute time incomparable; every gated signal
  here is exact arithmetic or a measured byte count).

``--quick`` runs M in {1024, 2048, 4096} (CI-sized, ~10 s); the full run
(checked-in baseline) adds {16384, 32768} — the acceptance ceiling, about
half an hour of O(M^3) on one CPU core.

    PYTHONPATH=src python -m benchmarks.precond_blocked --quick
    python benchmarks/check_regression.py \
        --baseline BENCH_precond.json --candidate BENCH_precond.json
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.kernels.blocked_cholesky import FactorStats, blocked_cholesky
from repro.ops import plan_factor

from .common import emit, write_payload

QUICK_MS = (1024, 2048, 4096)
FULL_MS = (4096, 16384, 32768)

#: blocked-vs-in-core factor parity ceiling — the acceptance invariant.
PARITY_CEILING = 1e-5

#: fixed panel width across points so peak_device_bytes is comparable
#: between M's (the plan would otherwise shrink the block as M grows).
BLOCK = 512


def _spd(M: int, seed: int = 0) -> np.ndarray:
    """Synthetic well-conditioned SPD host matrix: low-rank + identity.

    rank-64 keeps generation O(M^2 * 64) — negligible next to the O(M^3)
    factorizations being timed — and cond ~ M/64, far from the fp32 cliff,
    so ``parity_rel`` measures the factorization, not the conditioning.
    """
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((M, 64)).astype(np.float32)
    return (A @ A.T) / 64.0 + np.eye(M, dtype=np.float32)


def _point(M: int) -> dict:
    plan = plan_factor(M, block=BLOCK, factor_budget=1)   # force blocked
    assert plan.path == "blocked" and plan.block == BLOCK
    K = _spd(M, seed=M)

    t0 = time.perf_counter()
    T_incore = np.asarray(jnp.linalg.cholesky(jnp.asarray(K)).T)
    t_incore = time.perf_counter() - t0

    stats = FactorStats()
    t0 = time.perf_counter()
    T_blocked = blocked_cholesky(K, plan.block, stats=stats)
    t_blocked = time.perf_counter() - t0

    num = np.linalg.norm((T_blocked - T_incore).astype(np.float64))
    den = np.linalg.norm(T_incore.astype(np.float64))
    parity = float(num / den)
    autoplan = plan_factor(M)     # what the default budget would choose
    return dict(
        M=M,
        block=plan.block,
        parity_rel=parity,
        peak_device_bytes=stats.peak_device_bytes,
        device_ceiling_bytes=plan.device_ceiling_bytes,
        dense_bytes=plan.dense_bytes,
        bytes_transferred=stats.bytes_transferred,
        panels=stats.panels,
        default_path=autoplan.path,
        t_incore_s=round(t_incore, 3),
        t_blocked_s=round(t_blocked, 3),
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized points (M <= 4096)")
    args = ap.parse_args(argv)
    Ms = QUICK_MS if args.quick else FULL_MS

    records = [_point(M) for M in Ms]
    payload = {
        "benchmark": "precond_blocked",
        "records": records,
        "summary": {
            "parity_ceiling": PARITY_CEILING,
            "block": BLOCK,
            "max_parity_rel": max(r["parity_rel"] for r in records),
            "quick": bool(args.quick),
        },
    }
    out = write_payload(payload, "BENCH_PRECOND_JSON", "BENCH_precond.json")
    print(f"wrote {out}")

    emit([dict(name=f"precond_blocked_M{r['M']}",
               us_per_call=int(r["t_blocked_s"] * 1e6),
               parity_rel=f"{r['parity_rel']:.2e}",
               peak_device_mb=round(r["peak_device_bytes"] / 2**20, 2),
               dense_mb=round(r["dense_bytes"] / 2**20, 2))
          for r in records])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
