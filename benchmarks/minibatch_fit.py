"""Mini-batch FALKON benchmark: time-to-full-CG-quality in sweep-equivalents.

Measures the delayed-projection tentpole end to end and writes
``BENCH_minibatch.json`` (path override: env ``BENCH_MINIBATCH_JSON``),
gated in CI by ``benchmarks/check_regression.py``:

* ``mse_ratio`` — minibatch val MSE over full-CG val MSE on the same
  held-out set, same centers, same preconditioner construction. The gate
  ceiling comes from the baseline summary (default 1.15): the stochastic
  solver must land within a few percent of the exact solve.
* ``equiv_ratio`` — rows swept by the minibatch fit (pads + step-size pilot
  included — the honest count) over the full fit's ``(iterations + 1) * n``.
  Gated at <= 0.5: quality parity must come at no more than HALF the data
  movement of exact CG, the whole point of trading projections for sweeps.
* ``counted_sweeps`` vs ``expected_sweeps`` — a `CountingOps`-instrumented
  run of the streaming driver with ``jit_update=False`` (eager: the counter
  sees every call, not one trace). Must match EXACTLY: per stochastic step
  ONE chunk-sized sweep, plus exactly ``power_iters`` pilot sweeps for the
  step size — the deterministic cost-model invariant. If it moves, a step
  started paying hidden extra passes.

Both arms are deterministic given the seeds; no wall clock is measured or
gated (CI runners make absolute time incomparable — the sweep-equivalents
ratio IS the machine-neutral time proxy, because both arms move the same
rows/second through the same backend).

    PYTHONPATH=src python -m benchmarks.minibatch_fit [--quick]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import (
    FalkonConfig,
    MinibatchConfig,
    falkon_fit,
    falkon_fit_minibatch,
    make_preconditioner,
    minibatch_solve_stream,
)
from repro.data import ArrayChunkSource, StreamingLoader
from repro.ops import CountingOps, get_ops

from .common import emit, mse, write_payload

#: (n, M, d) benchmark points. One point in --quick (CI), two in full runs.
FAST_POINTS = [(8192, 512, 6)]
FULL_POINTS = [(8192, 512, 6), (16384, 512, 6)]

#: Shared problem constants: lam in the statistically sensible regime
#: (~1/n), where FALKON's preconditioned operator is well conditioned and
#: both solvers converge — the comparison the README step-cost model makes.
LAM = 1e-4
SIGMA = 2.0
CG_ITERATIONS = 20
N_VAL = 2048

#: The minibatch operating point: genuinely delayed projections (4 chunk
#: sweeps per projection), 8 reshuffled epochs, heavy-ball defaults.
MB = MinibatchConfig(chunk_rows=512, project_every=4, epochs=8)

#: Gate constants (mirrored into the baseline summary).
MSE_RATIO_CEILING = 1.15
EQUIV_BUDGET = 0.5


def _problem(n, d, seed=0):
    """A learnable synthetic regression task (val MSE << var(y), so the
    mse_ratio gate measures convergence, not noise-floor coincidence)."""
    kx, ky, kf = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(kx, (n, d))
    w = jax.random.normal(kf, (d,))
    w = 1.2 * w / jnp.linalg.norm(w)

    def f(Z):
        return jnp.sin(Z @ w) + 0.5 * jnp.cos(0.6 * Z[:, 0] * Z[:, 1])

    y = f(X) + 0.05 * jax.random.normal(ky, (n,))
    Xv = jax.random.normal(jax.random.PRNGKey(seed + 9), (N_VAL, d))
    return X, y, Xv, f(Xv)


def _count_invariant(M=256, d=6, chunk=512, num_chunks=4, seed=3):
    """CountingOps proof: one chunk sweep per step, power_iters pilot sweeps.

    Runs the streaming driver eagerly (``jit_update=False``) over a tiny
    in-memory source so the counter increments per CALL; returns the
    counted and expected sweep totals (exact-match gated).
    """
    n = chunk * num_chunks
    X, y, _, _ = _problem(n, d, seed=seed)
    cfg = FalkonConfig(
        kernel_params=(("sigma", SIGMA),),
        lam=LAM,
        num_centers=M,
        ops_impl="jnp",
        estimate_cond=False,
    )
    kern = cfg.make_kernel()
    ops = CountingOps(get_ops("jnp", kern, block_size=cfg.block_size))
    centers = X[:M]
    precond = make_preconditioner(ops.gram(centers, centers), LAM, n)
    mb = MinibatchConfig(
        chunk_rows=chunk,
        project_every=2,
        epochs=2,
        power_iters=4,
        shuffle=False,
    )
    loader = StreamingLoader(
        ArrayChunkSource(jnp.asarray(X), jnp.asarray(y), chunk_rows=chunk),
        prefetch=0,
    )
    before = ops.sweeps
    result = minibatch_solve_stream(
        loader, centers, precond, LAM, mb, ops=ops, jit_update=False
    )
    counted = ops.sweeps - before
    expected = mb.power_iters + mb.epochs * num_chunks
    assert int(result.state.step) == mb.epochs * num_chunks
    return counted, expected


def run(points):
    records = []
    for n, M, d in points:
        X, y, Xv, yv = _problem(n, d)
        cfg = FalkonConfig(
            kernel_params=(("sigma", SIGMA),),
            lam=LAM,
            num_centers=M,
            iterations=CG_ITERATIONS,
            ops_impl="jnp",
            estimate_cond=False,
        )
        est_full, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
        mse_full = mse(est_full.predict(Xv), yv)

        est_mb, result = falkon_fit_minibatch(
            jax.random.PRNGKey(1), X, y, cfg, MB, centers=est_full.centers
        )
        mse_mb = mse(est_mb.predict(Xv), yv)

        # the full fit's data movement: one sweep per CG iteration plus the
        # K^T y pass that builds the right-hand side.
        full_rows = (CG_ITERATIONS + 1) * n
        counted, expected = _count_invariant()
        rec = dict(
            n=n,
            M=M,
            d=d,
            chunk_rows=MB.chunk_rows,
            project_every=MB.project_every,
            epochs=MB.epochs,
            mse_full=mse_full,
            mse_minibatch=mse_mb,
            mse_ratio=mse_mb / mse_full,
            rows_swept=result.rows_swept,
            full_rows=float(full_rows),
            equiv_ratio=result.rows_swept / full_rows,
            step_size=float(result.step_size),
            projections=int(result.state.projections),
            counted_sweeps=counted,
            expected_sweeps=expected,
        )
        records.append(rec)
        print(
            f"n={n} M={M}: minibatch mse {mse_mb:.5f} vs full-CG "
            f"{mse_full:.5f} -> ratio {rec['mse_ratio']:.3f} "
            f"(ceiling {MSE_RATIO_CEILING}) at "
            f"{result.rows_swept / n:.2f} sweep-equivalents vs "
            f"{CG_ITERATIONS + 1} -> {rec['equiv_ratio']:.3f}x budget "
            f"(<= {EQUIV_BUDGET}); counted sweeps {counted} == "
            f"expected {expected}"
        )
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI mode: n=8192 point only")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    points = FAST_POINTS if args.quick and not args.full else FULL_POINTS

    records = run(points)
    summary = dict(
        mse_ratio_ceiling=MSE_RATIO_CEILING,
        equiv_budget=EQUIV_BUDGET,
        worst_mse_ratio=max(r["mse_ratio"] for r in records),
        worst_equiv_ratio=max(r["equiv_ratio"] for r in records),
    )
    payload = {
        "benchmark": "minibatch_fit",
        "records": records,
        "summary": summary,
    }
    out = write_payload(payload, "BENCH_MINIBATCH_JSON", "BENCH_minibatch.json")
    print(
        f"wrote {out}: worst mse ratio {summary['worst_mse_ratio']:.3f} "
        f"(ceiling {MSE_RATIO_CEILING}), worst equiv ratio "
        f"{summary['worst_equiv_ratio']:.3f} (budget {EQUIV_BUDGET}) over "
        f"{len(records)} points"
    )

    emit(
        [
            dict(
                name=f"minibatch_n{r['n']}",
                mse_ratio=f"{r['mse_ratio']:.3f}",
                equiv_ratio=f"{r['equiv_ratio']:.3f}",
                sweeps=f"{r['counted_sweeps']}",
            )
            for r in records
        ]
    )


if __name__ == "__main__":
    main()
