"""Benchmark harness — one module per paper table + theory/roofline reports.

Prints ``name,us_per_call,derived`` CSV per row.
Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--only tableX]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--full",
        action="store_true",
        help="paper-scale n (slower); default is CPU-fast",
    )
    ap.add_argument("--only", default=None, help="substring filter on module name")
    args = ap.parse_args()

    from .import (
        convergence,
        roofline_report,
        sweep_fusion,
        table1_complexity,
        table2_regression,
        table3_classification,
    )
    mods = [
        ("table1_complexity", table1_complexity),
        ("table2_regression", table2_regression),
        ("table3_classification", table3_classification),
        ("convergence", convergence),
        ("sweep_fusion", sweep_fusion),
        ("roofline_report", roofline_report),
    ]
    print("name,us_per_call,derived")
    for name, mod in mods:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---", file=sys.stderr)
        mod.run(fast=not args.full)


if __name__ == "__main__":
    main()
