"""Shared benchmark utilities."""

from __future__ import annotations

import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def timed(fn, *args, repeat: int = 1, **kw):
    """Run fn once for compile, then time ``repeat`` runs; returns
    (result, seconds_per_call)."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeat


def timed_best(fn, *args, repeat: int = 5, **kw):
    """Like ``timed`` but returns the BEST (minimum) per-call time of
    ``repeat`` individually-timed runs. The minimum is the noise-robust
    estimator on shared/loaded machines (load spikes only ever add time),
    which is what gated benchmarks should report."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def mse(pred, y):
    return float(jnp.mean((pred - y) ** 2))


def rmse(pred, y):
    return float(jnp.sqrt(jnp.mean((pred - y) ** 2)))


def relative_error(pred, y):
    return float(jnp.mean(jnp.abs(pred - y) / jnp.maximum(jnp.abs(y), 1e-9)))


def c_err(pred_logits, labels):
    if pred_logits.ndim == 1:       # binary with +-1 labels
        return float(jnp.mean(jnp.sign(pred_logits) != jnp.sign(labels)))
    return float(jnp.mean(jnp.argmax(pred_logits, -1) != labels))


def auc(scores, labels) -> float:
    """Rank-based AUC; labels in {-1, +1} or {0, 1}."""
    s = np.asarray(scores).ravel()
    y = np.asarray(labels).ravel() > 0
    order = np.argsort(s)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(s) + 1)
    n_pos = y.sum()
    n_neg = len(y) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[y].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


def _non_finite_paths(node, path=""):
    """Yield json-paths of every NaN/inf number in a payload tree."""
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _non_finite_paths(v, f"{path}.{k}" if path else str(k))
    elif isinstance(node, (list, tuple)):
        for i, v in enumerate(node):
            yield from _non_finite_paths(v, f"{path}[{i}]")
    elif isinstance(node, float) and not math.isfinite(node):
        yield f"{path}={node}"


def _config_key(record: dict):
    """The configuration identity of a record: its int/str/bool fields
    (lists of those tuple-ized), skipping floats — measurements vary run to
    run, configuration must not. Two records sharing this key measured the
    same point twice."""
    items = []
    for k in sorted(record):
        v = record[k]
        if isinstance(v, bool) or isinstance(v, (int, str)):
            items.append((k, v))
        elif isinstance(v, (list, tuple)) and all(
            isinstance(x, (bool, int, str)) for x in v
        ):
            items.append((k, tuple(v)))
    return tuple(items)


def check_payload(payload: dict) -> list[str]:
    """Problems that make a BENCH_*.json worthless to gate (empty == good).

    Three failure classes the regression gates cannot be trusted to catch
    on their own: an EMPTY record list (every per-record invariant loop
    vacuously passes), NON-FINITE metrics (NaN poisons geomeans and every
    ``>`` comparison silently evaluates False, i.e. "pass"), and DUPLICATE
    (benchmark, config-key) records (a benchmark loop that appended the
    same point twice double-weights it in every geomean, and key-indexed
    gates silently keep only the last). Benchmarks must fail loudly at
    write time instead of handing CI a green lie.
    """
    problems = []
    if not payload.get("benchmark"):
        problems.append("payload has no 'benchmark' field")
    if not payload.get("records"):
        problems.append("payload has no records — nothing for the gate to check")
    seen: dict = {}
    for i, r in enumerate(payload.get("records") or []):
        if not isinstance(r, dict):
            continue
        key = (payload.get("benchmark"), _config_key(r))
        if key in seen:
            problems.append(
                f"records[{i}] duplicates records[{seen[key]}] "
                f"(same config key {key[1]})")
        else:
            seen[key] = i
    problems.extend(f"non-finite metric at {p}" for p in _non_finite_paths(payload))
    return problems


def write_payload(payload: dict, env_var: str, default_path: str) -> str:
    """Validate and write a benchmark payload; die loudly on junk metrics.

    The single exit door every gated benchmark writes through: path comes
    from ``env_var`` (the CI artifact override) falling back to
    ``default_path`` (the checked-in baseline name), and a payload that
    fails :func:`check_payload` terminates the process with a nonzero exit
    so the CI step goes red BEFORE a vacuous gate can go green.
    """
    problems = check_payload(payload)
    if problems:
        print(f"REFUSING to write {default_path}:", file=sys.stderr)
        for line in problems:
            print(f"  {line}", file=sys.stderr)
        raise SystemExit(1)
    out = os.environ.get(env_var, default_path)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    return out


def emit(rows: list[dict]):
    """Print ``name,us_per_call,derived`` CSV rows (benchmarks/run.py
    contract)."""
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
