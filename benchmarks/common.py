"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def timed(fn, *args, repeat: int = 1, **kw):
    """Run fn once for compile, then time ``repeat`` runs; returns
    (result, seconds_per_call)."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeat


def timed_best(fn, *args, repeat: int = 5, **kw):
    """Like ``timed`` but returns the BEST (minimum) per-call time of
    ``repeat`` individually-timed runs. The minimum is the noise-robust
    estimator on shared/loaded machines (load spikes only ever add time),
    which is what gated benchmarks should report."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def mse(pred, y):
    return float(jnp.mean((pred - y) ** 2))


def rmse(pred, y):
    return float(jnp.sqrt(jnp.mean((pred - y) ** 2)))


def relative_error(pred, y):
    return float(jnp.mean(jnp.abs(pred - y) / jnp.maximum(jnp.abs(y), 1e-9)))


def c_err(pred_logits, labels):
    if pred_logits.ndim == 1:       # binary with +-1 labels
        return float(jnp.mean(jnp.sign(pred_logits) != jnp.sign(labels)))
    return float(jnp.mean(jnp.argmax(pred_logits, -1) != labels))


def auc(scores, labels) -> float:
    """Rank-based AUC; labels in {-1, +1} or {0, 1}."""
    s = np.asarray(scores).ravel()
    y = np.asarray(labels).ravel() > 0
    order = np.argsort(s)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(s) + 1)
    n_pos = y.sum()
    n_neg = len(y) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[y].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


def emit(rows: list[dict]):
    """Print ``name,us_per_call,derived`` CSV rows (benchmarks/run.py
    contract)."""
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
