"""Paper Table 3 — classification (SUSY / HIGGS AUC, IMAGENET c-err).

Synthetic analogues at the paper's hyperparameter regimes. Claims reproduced:
FALKON reaches the exact-Nystrom AUC in ~20 iterations; the multiclass
(IMAGENET-features-like) problem solves all one-vs-all systems in a single
multi-rhs CG.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import FalkonConfig, falkon_fit, nystrom_direct
from repro.data.synthetic import PAPER_TASKS, make_kernel_dataset

from .common import auc, c_err, emit, timed


def _split(X, y, frac=0.8):
    n = int(X.shape[0] * frac)
    return X[:n], y[:n], X[n:], y[n:]


def run(fast: bool = True):
    rows = []
    scale = 0.25 if fast else 1.0

    for key_i, tname in ((0, "susy"), (2, "higgs")):
        task = PAPER_TASKS[tname]
        n = int(task.n * scale)
        X, y = make_kernel_dataset(jax.random.PRNGKey(key_i), task, n=n)
        Xtr, ytr, Xte, yte = _split(X, y)
        cfg = FalkonConfig(
            kernel="gaussian",
            kernel_params=(("sigma", task.sigma),),
            lam=task.lam,
            num_centers=task.num_centers,
            iterations=20,
        )
        (est, _), t_f = timed(
            lambda: falkon_fit(jax.random.PRNGKey(key_i + 1), Xtr, ytr, cfg)
        )
        ny, _ = timed(
            lambda: nystrom_direct(Xtr, ytr, est.centers, cfg.make_kernel(), cfg.lam)
        )
        sc_f, sc_n = est.predict(Xte), ny.predict(Xte)
        rows.append(dict(name=f"table3/{tname}", us_per_call=round(t_f * 1e6),
                         falkon_auc=round(auc(sc_f, yte), 4),
                         nystrom_auc=round(auc(sc_n, yte), 4),
                         falkon_cerr=round(c_err(sc_f, yte), 4),
                         falkon_s=round(t_f, 2)))

    # IMAGENET analogue: kernel head over frozen deep features (the paper's
    # own setup: FALKON on Inception-V4 penultimate activations).
    task = PAPER_TASKS["imagenet"]
    n = int(task.n * scale)
    X, labels = make_kernel_dataset(jax.random.PRNGKey(6), task, n=n)
    Y = jax.nn.one_hot(labels, task.n_classes)
    Xtr, Ytr, Xte, Yte = _split(X, Y)
    lte = jnp.argmax(Yte, -1)
    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", task.sigma),),
        lam=1e-8,
        num_centers=task.num_centers,
        iterations=20,
    )
    (est, _), t_f = timed(lambda: falkon_fit(jax.random.PRNGKey(7), Xtr, Ytr, cfg))
    rows.append(dict(name="table3/imagenet", us_per_call=round(t_f * 1e6),
                     falkon_cerr=round(c_err(est.predict(Xte), lte), 4),
                     chance=round(1 - 1 / task.n_classes, 3),
                     falkon_s=round(t_f, 2)))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
