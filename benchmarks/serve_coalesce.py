"""Serving benchmark: batch-coalescing server vs the per-request loop.

Measures the serving tentpole end to end on a ragged request trace (sizes
uniform in 1..max_batch, pre-generated OUTSIDE every timer) and writes
``BENCH_serve.json`` (path override: env ``BENCH_SERVE_JSON``), gated in CI
by ``benchmarks/check_regression.py``:

* ``speedup_vs_per_request`` — coalesced rows/s over the single-stream
  baseline's rows/s, measured in the same run on the same machine
  (machine-neutral ratio, like the other gates). The baseline is the old
  ``serve --falkon`` protocol: one jitted ``est.predict`` dispatch per
  request, which retraces on every DISTINCT batch size in the trace — the
  production cost profile the server removes. The gate floor is 2x.
  ``speedup_vs_per_request_warm`` is also recorded (baseline re-run with
  every shape already compiled — isolating the dispatch-coalescing win from
  the retrace win) but not gated: it depends on per-call dispatch overhead,
  which varies wildly across hosts.
* ``retraces_after_warmup`` — the server's trace counter after serving the
  whole ragged trace; must be 0 EXACTLY (deterministic, machine-independent:
  if it moves, the bucket ladder stopped covering the traffic).
* p50/p99 latency per arm — per-request: each dispatch timed individually;
  coalesced: the trace arrives in flush windows and every request in a
  window is charged the whole window's wall time (the honest number — a
  coalesced request waits for its batch).

Runs on the jnp reference backend: the coalescing win is batching policy,
not kernel speed, and interpret-mode Pallas wall-clock on CPU CI runners
would measure the emulator.

    PYTHONPATH=src python -m benchmarks.serve_coalesce [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FalkonConfig, falkon_fit
from repro.serve import CoalescingPredictServer

from .check_regression import _geomean
from .common import emit, write_payload

#: (n, M, d, n_requests, max_batch) benchmark points.
FAST_POINTS = [(4096, 256, 16, 150, 128)]
FULL_POINTS = FAST_POINTS + [(4096, 256, 16, 150, 32)]

SPEEDUP_FLOOR = 2.0     # the CI gate's absolute acceptance
FLUSH_WINDOW = 16       # requests per coalesced flush (latency attribution)


def _fit(n, M, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(ks[0], (n, d))
    w = jax.random.normal(ks[1], (d,))
    y = jnp.sin(X @ w) + 0.05 * jax.random.normal(ks[2], (n,))
    cfg = FalkonConfig(
        kernel_params=(("sigma", 2.0),),
        lam=1e-4,
        num_centers=M,
        iterations=10,
        block_size=256,
        ops_impl="jnp",
        estimate_cond=False,
    )
    est, _ = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
    jax.block_until_ready(est.alpha)
    return est


def _trace(n_requests, max_batch, d, seed=0):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, max_batch + 1, size=n_requests)
    return [rng.standard_normal((int(s), d)).astype(np.float32) for s in sizes]


def _run_per_request(est, trace, d, *, warm_shapes):
    """The single-stream baseline; returns (seconds, [per-request seconds]).

    A FRESH ``jax.jit`` wrapper per call keeps its compile cache empty, so
    each invocation measures the protocol from cold — except the shapes in
    ``warm_shapes``, compiled before the timer (the old loop warmed exactly
    one shape; the warm variant passes all of them).
    """
    step = jax.jit(est.predict)
    for s in sorted(warm_shapes):
        jax.block_until_ready(step(jnp.zeros((s, d), jnp.float32)))
    lat = []
    t0 = time.perf_counter()
    for xb in trace:
        t1 = time.perf_counter()
        jax.block_until_ready(step(jnp.asarray(xb)))
        lat.append(time.perf_counter() - t1)
    return time.perf_counter() - t0, lat


def _run_coalesced(est, trace, max_batch):
    """The server arm; returns (seconds, [per-request seconds], server).

    The trace arrives in ``FLUSH_WINDOW``-request windows; every request in
    a window is charged the window's whole flush time.
    """
    server = CoalescingPredictServer(est, max_batch=max_batch)
    server.warmup()
    lat = []
    t0 = time.perf_counter()
    for w0 in range(0, len(trace), FLUSH_WINDOW):
        window = trace[w0 : w0 + FLUSH_WINDOW]
        t1 = time.perf_counter()
        for xb in window:
            server.submit(xb)
        server.flush()
        lat.extend([time.perf_counter() - t1] * len(window))
    return time.perf_counter() - t0, lat, server


def _pct(lat, q):
    return float(np.percentile(np.asarray(lat), q) * 1e3)


def run(points, *, max_requests=None):
    records = []
    for n, M, d, n_requests, max_batch in points:
        if max_requests is not None:
            n_requests = min(n_requests, max_requests)
        est = _fit(n, M, d)
        trace = _trace(n_requests, max_batch, d)
        rows = sum(b.shape[0] for b in trace)

        sec_cold, lat_req = _run_per_request(est, trace, d, warm_shapes={max_batch})
        warm = {b.shape[0] for b in trace}
        sec_warm, _ = _run_per_request(est, trace, d, warm_shapes=warm)
        sec_co, lat_co, server = _run_coalesced(est, trace, max_batch)

        rec = dict(
            n=n,
            M=M,
            d=d,
            n_requests=n_requests,
            max_batch=max_batch,
            rows=rows,
            impl="jnp",
            ladder=list(server.ladder),
            rows_per_s_coalesced=rows / sec_co,
            rows_per_s_per_request=rows / sec_cold,
            rows_per_s_per_request_warm=rows / sec_warm,
            speedup_vs_per_request=sec_cold / sec_co,
            speedup_vs_per_request_warm=sec_warm / sec_co,
            p50_ms_coalesced=_pct(lat_co, 50),
            p99_ms_coalesced=_pct(lat_co, 99),
            p50_ms_per_request=_pct(lat_req, 50),
            p99_ms_per_request=_pct(lat_req, 99),
            dispatches=server.stats.dispatches,
            pad_fraction=server.stats.pad_fraction,
            retraces_after_warmup=server.retraces_since_warmup(),
        )
        records.append(rec)
        print(f"n={n} M={M} max_batch={max_batch}: coalesced "
              f"{rec['rows_per_s_coalesced']:.0f} rows/s vs per-request "
              f"{rec['rows_per_s_per_request']:.0f} (warm "
              f"{rec['rows_per_s_per_request_warm']:.0f}) -> "
              f"{rec['speedup_vs_per_request']:.1f}x (warm "
              f"{rec['speedup_vs_per_request_warm']:.1f}x); p99 "
              f"{rec['p99_ms_coalesced']:.1f}ms vs "
              f"{rec['p99_ms_per_request']:.1f}ms; retraces "
              f"{rec['retraces_after_warmup']}")
    return records


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--quick",
        action="store_true",
        help="CI mode: fast point set, trace capped at 100 " "requests",
    )
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    points = FULL_POINTS if args.full else FAST_POINTS

    records = run(points, max_requests=100 if args.quick else None)
    summary = dict(
        speedup_geomean=_geomean([r["speedup_vs_per_request"]
                                  for r in records]),
        speedup_warm_geomean=_geomean([r["speedup_vs_per_request_warm"]
                                       for r in records]),
        retraces_after_warmup=sum(r["retraces_after_warmup"]
                                  for r in records),
        speedup_floor=SPEEDUP_FLOOR,
    )
    payload = {"benchmark": "serve_coalesce", "records": records, "summary": summary}
    out = write_payload(payload, "BENCH_SERVE_JSON", "BENCH_serve.json")
    print(f"wrote {out}: coalesced speedup geomean "
          f"{summary['speedup_geomean']:.1f}x (warm-baseline "
          f"{summary['speedup_warm_geomean']:.1f}x) over {len(records)} "
          f"points, {summary['retraces_after_warmup']} retraces after warmup")

    emit([dict(name=f"serve_b{r['max_batch']}",
               us_per_call=f"{1e6 / r['rows_per_s_coalesced']:.1f}",
               speedup=f"{r['speedup_vs_per_request']:.1f}",
               p99_ms=f"{r['p99_ms_coalesced']:.1f}")
          for r in records])


if __name__ == "__main__":
    main()
