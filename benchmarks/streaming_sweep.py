"""Out-of-core FALKON: streaming-loader throughput + sweep-path planning.

Two measurements, written to ``BENCH_streaming.json``:

1. **Streaming vs in-core throughput** — the same ``K_nM^T (K_nM u + y)``
   sweep run (a) in-core on device-resident X and (b) through the
   double-buffered host->device ``StreamingLoader`` in ``chunk_rows`` chunks.
   Reported as rows/s plus ``stream_vs_incore_ratio`` — the acceptance
   number (the streaming path should sustain >= 0.7 of in-core throughput at
   the largest in-core-feasible size). Both paths run the jnp backend with
   the per-chunk sweep jitted, so the ratio isolates streaming overhead
   (transfer + host loop), not backend differences. Peak memory is reported
   two ways: the analytic device working set per path (the hardware-portable
   number — on CPU "device" and host are the same arena) and the process
   ``ru_maxrss`` high-water mark.

2. **Planner routing** — ``KernelOps.plan()`` decisions of the pallas
   backend across the M axis, recording where fused hands off to two-pass
   and j-sharded and the VMEM budget numbers behind each decision.

    PYTHONPATH=src python -m benchmarks.streaming_sweep [--full]
"""
from __future__ import annotations

import argparse
import dataclasses
import resource

import jax
import numpy as np

from repro.core import GaussianKernel
from repro.data import ArrayChunkSource, StreamingLoader, streaming_sweep
from repro.data.streaming import JittedOps
from repro.ops import get_ops

from .common import emit, timed_best, write_payload

FAST_POINTS = [(16384, 512, 32), (32768, 1024, 32)]
FULL_POINTS = FAST_POINTS + [(131072, 2048, 32), (262144, 2048, 64)]

CHUNK_ROWS = 8192
# On CPU the "transfer" shares cores with compute, so the overlap thread
# only contends — stream inline there; double-buffer on real accelerators.
PREFETCH = 0 if jax.default_backend() == "cpu" else 2

PLAN_POINTS = [
    (8192, 1024, 32),
    (8192, 8192, 32),
    (8192, 32768, 32),
    (8192, 131072, 32),
]


def _throughput_point(n: int, M: int, d: int) -> dict:
    rng = np.random.default_rng(n + M + d)
    X = rng.standard_normal((n, d), dtype=np.float32)
    y = rng.standard_normal((n,), dtype=np.float32)
    u = rng.standard_normal((M,), dtype=np.float32)
    C = X[:M].copy()

    # JittedOps is the facade falkon_solve_streaming itself runs, so the
    # streaming side of the ratio measures the real fit path; the in-core
    # side uses the same jitted sweep for symmetry.
    ops = JittedOps(get_ops("jnp", GaussianKernel(sigma=2.0), block_size=CHUNK_ROWS))
    Xd, yd, Cd, ud = map(jax.device_put, (X, y, C, u))
    _, t_incore = timed_best(ops.sweep, Xd, Cd, ud, yd, repeat=5)

    source = ArrayChunkSource(X, y, chunk_rows=CHUNK_ROWS)
    loader = StreamingLoader(source, prefetch=PREFETCH)
    _, t_stream = timed_best(
        lambda: streaming_sweep(ops, loader, Cd, ud, use_targets=True),
        repeat=5,
    )

    itemsize = 4
    incore_ws = (n * d + n + M * d + M) * itemsize
    stream_ws = ((PREFETCH + 1) * CHUNK_ROWS * (d + 1) + M * d + M) * itemsize
    return dict(
        n=n,
        M=M,
        d=d,
        chunk_rows=CHUNK_ROWS,
        prefetch=PREFETCH,
        num_chunks=source.num_chunks,
        backend=jax.default_backend(),
        us_incore=round(t_incore * 1e6, 1),
        us_stream=round(t_stream * 1e6, 1),
        rows_per_s_incore=round(n / t_incore, 1),
        rows_per_s_stream=round(n / t_stream, 1),
        stream_vs_incore_ratio=round(t_incore / t_stream, 3),
        device_workingset_bytes_incore=incore_ws,
        device_workingset_bytes_stream=stream_ws,
        ru_maxrss_kb=resource.getrusage(resource.RUSAGE_SELF).ru_maxrss,
    )


def _plan_point(n: int, M: int, d: int) -> dict:
    ops = get_ops("pallas", GaussianKernel(sigma=2.0), block_size=2048)
    plan = dataclasses.asdict(ops.plan(n, M, d, 1))
    plan["total_bytes"] = plan["scratch_bytes"] + plan["io_bytes"]
    return plan


def run(fast: bool = True):
    points = FAST_POINTS if fast else FULL_POINTS
    records = [_throughput_point(*pt) for pt in points]
    plans = [_plan_point(*pt) for pt in PLAN_POINTS]

    payload = {
        "benchmark": "streaming_sweep",
        "records": records,
        "sweep_plans": plans,
    }
    out = write_payload(payload, "BENCH_STREAMING_JSON", "BENCH_streaming.json")

    rows = []
    for r in records:
        rest = {k: v for k, v in r.items() if k not in ("n", "M", "d", "us_stream")}
        name = f"streaming_sweep/n{r['n']}_M{r['M']}_d{r['d']}"
        rows.append(dict(name=name, us_per_call=r["us_stream"], **rest))
    for p in plans:
        row = dict(
            name=f"sweep_plan/M{p['M']}",
            us_per_call="",
            path=p["path"],
            shard_m=p["shard_m"],
            total_bytes=p["total_bytes"],
        )
        rows.append(row)
    emit(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(fast=not ap.parse_args().full)
