"""Roofline table from the dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json and prints one row per (arch x shape x mesh):
three roofline terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio,
bytes/device and fits-HBM. Markdown table written to artifacts/roofline.md
(EXPERIMENTS.md SS Roofline embeds it).
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def to_markdown(cells: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | useful flops | GB/dev | fits 16GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for c in cells:
        if c.get("status") != "ok":
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | " f"ERROR | | | | | | |"
            )
            continue
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.3f} | {c['bytes_per_device_gb']} "
            f"| {'Y' if c['fits_hbm'] else 'N'} |")
    return hdr + "\n".join(lines) + "\n"


def run(fast: bool = True):
    cells = load_cells()
    rows = []
    for c in cells:
        if c.get("status") != "ok":
            rows.append(dict(name=f"roofline/{c['arch']}/{c['shape']}/"
                             f"{c['mesh']}", us_per_call="", status="ERROR"))
            continue
        r = c["roofline"]
        dom_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        rows.append(dict(
            name=f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            us_per_call=round(dom_us, 1),
            bottleneck=r["bottleneck"],
            compute_s=f"{r['compute_s']:.3e}",
            memory_s=f"{r['memory_s']:.3e}",
            collective_s=f"{r['collective_s']:.3e}",
            useful_flops_ratio=round(r["useful_flops_ratio"], 3),
            gb_per_dev=c["bytes_per_device_gb"],
            fits=c["fits_hbm"]))
    if cells:
        out = os.path.join(os.path.dirname(__file__), "..", "artifacts", "roofline.md")
        with open(out, "w") as f:
            f.write(to_markdown(cells))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
