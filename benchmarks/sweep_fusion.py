"""Fused single-pass sweep vs. legacy two-matmul sweep vs. jnp reference.

The FALKON hot loop is ``w = K_nM^T (K_nM u + v)`` once per CG iteration.
This benchmark times three implementations at several (n, M, d) points:

* ``fused``    — one Pallas pass, each Gram tile evaluated once
                 (``repro.ops`` "pallas" backend / ``fused_sweep_pallas``).
* ``two_pass`` — the pre-refactor composition of two kernel matmuls, each
                 Gram tile evaluated twice (``two_pass_knm_matvec``).
* ``jnp``      — the blocked lax.scan reference backend.

Besides wall-clock it records the analytically known Gram-tile evaluation
counts (the fused kernel's int32 counter is cross-checked), since on non-TPU
hosts the Pallas kernels run in interpret mode and wall-clock is
Python-emulation noise — tile evals and HBM bytes are the hardware-portable
metric. Results go to stdout as CSV rows (benchmarks/run.py contract) and to
``BENCH_sweep.json`` (path override: env ``BENCH_SWEEP_JSON``), which
``benchmarks/check_regression.py`` gates CI against.

    PYTHONPATH=src python -m benchmarks.sweep_fusion [--quick | --full]
"""
from __future__ import annotations

import argparse

import jax

from repro.core import GaussianKernel, spec_of
from repro.kernels.kernel_matvec import fused_sweep_pallas, sweep_tile_grid
from repro.kernels.ops import two_pass_knm_matvec
from repro.ops import get_ops

from .common import emit, timed_best, write_payload

FAST_POINTS = [(2048, 256, 16), (2048, 512, 32), (4096, 512, 16)]
FULL_POINTS = [(65536, 1024, 32), (131072, 2048, 64), (262144, 4096, 32)]


def _tile_counts(n: int, M: int, block_m: int, block_n: int) -> tuple[int, int]:
    nbi, nbj = sweep_tile_grid(n, M, block_m, block_n)
    return nbi * nbj, 2 * nbi * nbj  # fused vs two-pass evaluations per sweep


def run(fast: bool = True):
    points = FAST_POINTS if fast else FULL_POINTS
    interpret = jax.default_backend() != "tpu"
    kern = GaussianKernel(sigma=2.0)
    block_m, block_n = 256, 512
    rows, records = [], []

    for (n, M, d) in points:
        ks = jax.random.split(jax.random.PRNGKey(n + M + d), 4)
        X = jax.random.normal(ks[0], (n, d))
        C = jax.random.normal(ks[1], (M, d))
        u = jax.random.normal(ks[2], (M,))
        v = jax.random.normal(ks[3], (n,))

        fused = jax.jit(lambda X, C, u, v: fused_sweep_pallas(
            X, C, u, v, spec=spec_of(kern), block_m=block_m, block_n=block_n,
            interpret=interpret))
        two = jax.jit(
            lambda X, C, u, v: two_pass_knm_matvec(X, C, u, v, kern, block_size=block_m)
        )
        jops = get_ops("jnp", kern, block_size=2048)
        jref = jax.jit(lambda X, C, u, v: jops.sweep(X, C, u, v))

        # best-of-5: the CI bench gate reads speedup_vs_two_pass off these
        # numbers, and on shared runners mean timings of interpret-mode
        # Pallas swing >20% run-to-run; the minimum filters load spikes.
        _, t_fused = timed_best(fused, X, C, u, v, repeat=5)
        _, t_two = timed_best(two, X, C, u, v, repeat=5)
        _, t_jnp = timed_best(jref, X, C, u, v, repeat=5)

        # counter cross-check: the kernel reports one eval per tile
        _, cnt = fused_sweep_pallas(
            X,
            C,
            u,
            v,
            spec=spec_of(kern),
            block_m=block_m,
            block_n=block_n,
            interpret=interpret,
            return_tile_count=True,
        )
        evals_fused, evals_two = _tile_counts(n, M, block_m, block_n)
        assert int(cnt) == evals_fused, (int(cnt), evals_fused)

        rec = dict(
            n=n,
            M=M,
            d=d,
            block_m=block_m,
            block_n=block_n,
            backend=jax.default_backend(),
            interpret=interpret,
            us_fused=round(t_fused * 1e6, 1),
            us_two_pass=round(t_two * 1e6, 1),
            us_jnp=round(t_jnp * 1e6, 1),
            speedup_vs_two_pass=round(t_two / t_fused, 3),
            tile_evals_fused=evals_fused,
            tile_evals_two_pass=evals_two,
        )
        records.append(rec)
        rows.append(dict(name=f"sweep_fusion/n{n}_M{M}_d{d}",
                         us_per_call=rec["us_fused"],
                         **{k: v for k, v in rec.items()
                            if k not in ("n", "M", "d", "us_fused")}))

    write_payload(
        {"benchmark": "sweep_fusion", "records": records},
        "BENCH_SWEEP_JSON",
        "BENCH_sweep.json",
    )
    emit(rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fast points only (the default; kept explicit for "
                         "the CI bench-regression job)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and args.full:
        raise SystemExit("--quick and --full are mutually exclusive")
    run(fast=not args.full)
