"""Checkpointing: per-leaf files, async writer, restore-with-resharding.

Format: a directory per step containing
  MANIFEST.json      — tree structure, shapes, dtypes, step metadata
  <leaf-id>.npy.zst  — zstd-compressed ndarray per pytree leaf

Restore accepts a *different* mesh/sharding than the save used (elastic
scaling): leaves are loaded on host and device_put with the new shardings.
Writes go through a tmp-dir + atomic rename so a preemption mid-write never
corrupts the latest checkpoint; an optional background thread makes the save
async (fault tolerance without stalling the step loop).

``zstandard`` is an optional dependency: when missing, leaves are written
uncompressed as ``.npy.raw`` (the manifest records the codec per checkpoint,
so mixed environments interoperate — reading a zstd checkpoint without the
module is the only unsupported combination and raises a clear error).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _zstd():
    """Lazy optional import: the zstandard module, or None if unavailable."""
    try:
        import zstandard
        return zstandard
    except ImportError:
        return None


def _flatten(tree) -> tuple[dict[str, Any], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i:05d}": l for i, l in enumerate(leaves)}, treedef


def save_checkpoint(
    path: str, tree, step: int, *, blocking: bool = True, extra: dict | None = None
) -> threading.Thread | None:
    """Save ``tree`` under ``path`` (dir). Atomic via tmp + rename."""
    named, treedef = _flatten(tree)
    # pull to host before returning control (device buffers may be donated)
    host = {k: np.asarray(jax.device_get(v)) for k, v in named.items()}
    structure = jax.tree.map(lambda _: 0, tree)

    def _write():
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        zstd = _zstd()
        codec = "zstd" if zstd is not None else "raw"
        ext = ".npy.zst" if zstd is not None else ".npy.raw"
        cctx = zstd.ZstdCompressor(level=3) if zstd is not None else None
        manifest = {
            "step": int(step), "extra": extra or {}, "codec": codec, "leaves": {}
        }
        for k, arr in host.items():
            raw = arr.tobytes()
            with open(os.path.join(tmp, k + ext), "wb") as f:
                f.write(cctx.compress(raw) if cctx is not None else raw)
            manifest["leaves"][k] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def load_checkpoint(path: str, like_tree, shardings=None) -> tuple[Any, int]:
    """Restore into the structure of ``like_tree`` (shapes must match),
    placing each leaf with ``shardings`` (matching tree of NamedSharding /
    None). Works across mesh shapes — elastic restore."""
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like_tree)
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    codec = manifest.get("codec", "zstd")   # pre-codec manifests were zstd
    dctx = None
    if codec == "zstd":
        zstd = _zstd()
        if zstd is None:
            raise RuntimeError(
                f"checkpoint {path} is zstd-compressed but the optional "
                "'zstandard' module is not installed")
        dctx = zstd.ZstdDecompressor()
    ext = ".npy.zst" if codec == "zstd" else ".npy.raw"
    out = []
    for i, like in enumerate(leaves_like):
        k = f"leaf_{i:05d}"
        meta = manifest["leaves"][k]
        with open(os.path.join(path, k + ext), "rb") as f:
            raw = f.read()
            if dctx is not None:
                raw = dctx.decompress(raw,
                                      max_output_size=int(
                                          np.prod(meta["shape"]) *
                                          np.dtype(meta["dtype"]).itemsize) or 1)
        arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
        exp_shape = tuple(getattr(like, "shape", ()) or ())
        if tuple(arr.shape) != exp_shape:
            raise ValueError(
                f"shape mismatch for {k}: ckpt {arr.shape} vs " f"model {exp_shape}"
            )
        sh = shard_leaves[i]
        out.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["step"]


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[-1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")
