from .pipeline import ShardedLoader
from .synthetic import (PAPER_TASKS, KernelTask, TokenStreamConfig,
                        make_kernel_dataset, token_stream)
