from .pipeline import ShardedLoader
from .streaming import (
    ArrayChunkSource,
    ChunkSource,
    JittedOps,
    ShardedChunkSource,
    ShuffledChunkSource,
    StreamingLoader,
    shard_chunk_sources,
    streaming_apply,
    streaming_sweep,
    streaming_uniform_centers,
)
from .synthetic import (
    PAPER_TASKS, KernelTask, TokenStreamConfig, make_kernel_dataset, token_stream
)
