"""Synthetic datasets.

Two families:
* kernel-regression/classification generators sized to the paper's datasets
  (MillionSongs / YELP / TIMIT / SUSY / HIGGS / IMAGENET analogues) — used by
  the Table 1/2/3 benchmarks. Ground-truth functions are RKHS-style (random
  Fourier mixtures) so kernel methods are well-specified and excess risk is
  measurable.
* an LM token stream for the training examples (mixture-of-ngrams language so
  loss decreases meaningfully within a few hundred steps).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class KernelTask:
    name: str
    n: int
    d: int
    task: str            # "regression" | "binary" | "multiclass"
    n_classes: int = 1
    noise: float = 0.1
    # paper-matched hyperparameters (Sect. 5)
    sigma: float = 5.0
    lam: float = 1e-6
    num_centers: int = 1024


# Scaled-down analogues of the paper's experiments (CPU-runnable sizes; the
# (n, d) ratios and hyperparameter regimes follow Sect. 5).
PAPER_TASKS = {
    "millionsongs": KernelTask("millionsongs", n=40_000, d=90,
                               task="regression", sigma=6.0, lam=1e-6,
                               num_centers=1_000),
    "yelp":         KernelTask("yelp", n=30_000, d=512, task="regression",
                               sigma=0.0, lam=1e-6, num_centers=1_000),
    "timit":        KernelTask("timit", n=20_000, d=120, task="multiclass",
                               n_classes=10, sigma=15.0, lam=1e-9,
                               num_centers=1_500),
    "susy":         KernelTask("susy", n=50_000, d=18, task="binary",
                               sigma=4.0, lam=1e-6, num_centers=1_000),
    "higgs":        KernelTask("higgs", n=40_000, d=28, task="binary",
                               sigma=5.0, lam=1e-8, num_centers=1_500),
    "imagenet":     KernelTask("imagenet", n=15_000, d=256, task="multiclass",
                               n_classes=20, sigma=19.0, lam=1e-9,
                               num_centers=1_500),
}


def make_kernel_dataset(
    key: Array,
    task: KernelTask,
    n: int | None = None,
    fn_key: Array | None = None,
    return_clean: bool = False,
):
    """X ~ N(0, I_d); f* = random Fourier feature mixture (RKHS member for the
    Gaussian kernel => the source condition of Thm 3 holds).

    ``fn_key`` fixes the ground-truth function independently of the sample
    (excess-risk studies need train/test from the SAME f*); ``return_clean``
    additionally returns noiseless targets."""
    n = n or task.n
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    if fn_key is not None:
        k2, k4 = jax.random.split(fn_key)
    X = jax.random.normal(k1, (n, task.d))
    n_feat = 64
    sigma = task.sigma if task.sigma > 0 else float(np.sqrt(task.d))
    W = jax.random.normal(k2, (task.d, n_feat)) / sigma
    b = jax.random.uniform(k3, (n_feat,), maxval=2 * np.pi)
    phi = jnp.cos(X @ W + b) * np.sqrt(2.0 / n_feat)

    if task.task == "regression":
        w = jax.random.normal(k4, (n_feat,))
        clean = phi @ w
        y = clean + task.noise * jax.random.normal(k5, (n,))
        if task.name == "millionsongs":
            y, clean = y + 10.0, clean + 10.0   # positive (year-like) targets
        return (X, y, clean) if return_clean else (X, y)
    if task.task == "binary":
        w = jax.random.normal(k4, (n_feat,))
        margin = phi @ w
        flip = jax.random.uniform(k5, (n,)) < task.noise
        y = jnp.where(jnp.logical_xor(margin > 0, flip), 1.0, -1.0)
        return X, y
    W2 = jax.random.normal(k4, (n_feat, task.n_classes))
    logits = phi @ W2 / task.noise
    y = jax.random.categorical(k5, logits)
    return X, y


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab: int = 512
    seq_len: int = 128
    batch: int = 8
    order: int = 2        # markov order of the synthetic language


def token_stream(cfg: TokenStreamConfig, seed: int = 0) -> Iterator[dict]:
    """Deterministic, restartable synthetic LM stream (markov chain)."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.ones(cfg.vocab) * 0.05, size=cfg.vocab).astype(np.float32)
    step = 0
    while True:
        g = np.random.default_rng(seed * 1_000_003 + step)
        toks = np.empty((cfg.batch, cfg.seq_len + 1), np.int32)
        toks[:, 0] = g.integers(0, cfg.vocab, cfg.batch)
        for t in range(1, cfg.seq_len + 1):
            p = trans[toks[:, t - 1]]
            c = p.cumsum(axis=1)
            u = g.random((cfg.batch, 1), np.float32)
            toks[:, t] = (u < c).argmax(axis=1)
        yield {
            "tokens": jnp.asarray(toks[:,:-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "step": step,
        }
        step += 1
