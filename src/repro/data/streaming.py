"""Host-streaming X loader: FALKON on n that exceeds device HBM.

The FALKON sweep ``w = K(X,C)^T (K(X,C) u + v)`` is additive over row chunks
of X, so the CG data pass never needs all of X resident on the device: chunks
live on the host (or disk, or a generator) and stream through a
double-buffered host-to-device transfer while the device sweeps the previous
chunk. Per-chunk device state is O(chunk_rows * d + M * p) — the paper's O(M)
working set plus one chunk — independent of n.

Layers:

* ``ChunkSource``      — a *re-iterable* source of (X_chunk, y_chunk | None)
                         host arrays. ``ArrayChunkSource`` wraps in-memory
                         arrays (or anything numpy-viewable, e.g. memmaps);
                         custom sources subclass and implement ``chunks()``.
* ``StreamingLoader``  — background-thread host->device feed, ``prefetch``
                         chunks ahead (double-buffered at the default 2), so
                         ``jax.device_put`` of chunk k+1 overlaps the sweep
                         of chunk k. Re-iterable: each ``iter()`` replays the
                         source, which is what the CG loop needs (one full
                         data pass per iteration).
* ``streaming_sweep`` / ``streaming_apply`` — chunked KernelOps primitives.
  They work with ANY registered backend: the jnp backend gives the reference
  semantics (chunked == in-core is a tested identity), the pallas backend
  runs its planner per chunk (fused / two-pass / j-sharded in M).
* ``streaming_uniform_centers`` — exact uniform Nystrom sampling without
  materializing X: draw M global row indices up front, gather while
  streaming.
* ``ShardedChunkSource`` / ``shard_chunk_sources`` — per-host row-range
  views for the multi-device data-parallel fit: each host streams only its
  own n/shards slice, so n is bounded by aggregate host RAM (the sweep is
  additive over rows; shard partials psum to the full result).

These are the pieces ``repro.core.falkon.falkon_fit_streaming`` composes
into the out-of-core fit; ``repro.launch.serve --falkon --stream-chunk``
drives the same path from the CLI.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_END = object()


def default_prefetch() -> int:
    """Chunks in flight when the caller doesn't say: 2 (double-buffered) on
    real accelerators, 0 (synchronous transfers) on CPU, where "host" and
    "device" share one memory arena and an overlap thread only contends
    with compute for the same cores. Shared by the streaming fits and the
    host-tier K_nM cache so every host->device feed makes the same call."""
    return 0 if jax.default_backend() == "cpu" else 2


class ChunkSource:
    """Re-iterable source of ``(X_chunk, y_chunk | None)`` host arrays.

    Subclasses set ``n_rows``/``dim`` and implement ``chunks()``; every call
    to ``chunks()`` must start a fresh pass over the data (the CG solve
    replays the source once per iteration).
    """

    n_rows: int
    dim: int
    chunk_rows: int

    def chunks(self) -> Iterator[tuple[np.ndarray, np.ndarray | None]]:
        raise NotImplementedError

    @property
    def num_chunks(self) -> int:
        return -(-self.n_rows // self.chunk_rows)


class ArrayChunkSource(ChunkSource):
    """Chunk view over in-memory (or memory-mapped) host arrays.

    ``X``: (n, d); ``y``: (n,) or (n, p) or None. Slices are views — no copy
    until the loader's host->device transfer.
    """

    def __init__(self, X, y=None, *, chunk_rows: int = 8192):
        if chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be positive, got {chunk_rows}")
        self.X = np.asarray(X)
        self.y = None if y is None else np.asarray(y)
        if self.y is not None and self.y.shape[0] != self.X.shape[0]:
            raise ValueError(
                f"X has {self.X.shape[0]} rows but y has {self.y.shape[0]}"
            )
        self.n_rows, self.dim = self.X.shape
        self.chunk_rows = int(chunk_rows)

    def chunks(self) -> Iterator[tuple[np.ndarray, np.ndarray | None]]:
        for i0 in range(0, self.n_rows, self.chunk_rows):
            i1 = min(i0 + self.chunk_rows, self.n_rows)
            yc = None if self.y is None else self.y[i0:i1]
            yield self.X[i0:i1], yc


class ShardedChunkSource(ChunkSource):
    """Row-range view: shard ``index`` of ``num_shards`` over a parent source.

    The per-host loader primitive of the multi-device data-parallel fit:
    shard i streams rows ``[i * ceil(n/s), (i+1) * ceil(n/s))`` of the
    parent, so each host's RAM holds only its own n/s slice — n is bounded
    by *aggregate* host memory, not any single machine's. The FALKON sweep
    is additive over rows, so the per-shard streaming sweeps sum (psum, in
    the mesh setting) to exactly the full-source sweep; a ragged final
    shard simply yields fewer rows and the sweep's ``row_mask`` padding
    handles the rest (tested in tests/test_distributed.py).

    Host-side and lazy: the parent's ``chunks()`` is re-walked per pass and
    rows outside this shard's range are skipped without copying; chunks are
    sliced at the range boundary, so this shard's chunk grid aligns with
    the parent's (``chunk_rows`` is inherited).
    """

    def __init__(self, source: ChunkSource, index: int, num_shards: int):
        if not 0 < num_shards:
            raise ValueError(f"num_shards must be positive, got {num_shards}")
        if not 0 <= index < num_shards:
            raise ValueError(f"shard index must be in [0, {num_shards}), got {index}")
        self.source = source
        self.index = index
        self.num_shards = num_shards
        rows_per = -(-source.n_rows // num_shards)
        self.row_start = min(index * rows_per, source.n_rows)
        self.row_stop = min(self.row_start + rows_per, source.n_rows)
        self.n_rows = self.row_stop - self.row_start
        self.dim = source.dim
        self.chunk_rows = source.chunk_rows

    def chunks(self) -> Iterator[tuple[np.ndarray, np.ndarray | None]]:
        offset = 0
        for xc, yc in self.source.chunks():
            lo = max(self.row_start - offset, 0)
            hi = min(self.row_stop - offset, xc.shape[0])
            if hi > lo:
                yield xc[lo:hi], None if yc is None else yc[lo:hi]
            offset += xc.shape[0]
            if offset >= self.row_stop:
                return


def shard_chunk_sources(
    source: ChunkSource, num_shards: int
) -> tuple[ShardedChunkSource, ...]:
    """All ``num_shards`` row-range views of ``source``, in shard order."""
    return tuple(ShardedChunkSource(source, i, num_shards) for i in range(num_shards))


class ShuffledChunkSource(ChunkSource):
    """Epoch-reshuffling view over any ``ChunkSource``.

    The mini-batch solver wants a DIFFERENT data order every epoch, but a
    chunk source streams host (or disk) data that can't be globally permuted
    without materializing all n rows. This wrapper gives the streaming
    approximation SGD practice uses: a **windowed shuffle** — up to
    ``buffer_chunks`` chunks are buffered and emitted in uniformly random
    order (exact global chunk-order shuffle whenever ``buffer_chunks >=
    num_chunks``; a locality-bounded one otherwise), and each emitted
    chunk's ROWS are permuted in place (``shuffle_rows``), which breaks
    intra-chunk ordering exactly.

    Every ``chunks()`` call is a fresh pass with a fresh order: an internal
    pass counter is folded into ``seed``, so epoch k and epoch k+1 of the
    same solve draw different permutations while two sources built with the
    same seed replay identically (deterministic tests). Memory: at most
    ``buffer_chunks + 1`` chunks of host rows alive at once; ``chunk_rows``
    and the row/dim geometry are the parent's (the sweep's one-compiled-
    shape contract is unaffected).
    """

    def __init__(
        self,
        source: ChunkSource,
        *,
        seed: int = 0,
        buffer_chunks: int = 8,
        shuffle_rows: bool = True,
    ):
        if buffer_chunks < 1:
            raise ValueError(f"buffer_chunks must be >= 1, got {buffer_chunks}")
        self.source = source
        self.seed = int(seed)
        self.buffer_chunks = int(buffer_chunks)
        self.shuffle_rows = shuffle_rows
        self.n_rows = source.n_rows
        self.dim = source.dim
        self.chunk_rows = source.chunk_rows
        self._passes = 0

    def chunks(self) -> Iterator[tuple[np.ndarray, np.ndarray | None]]:
        rng = np.random.default_rng((self.seed, self._passes))
        self._passes += 1

        def emit(chunk):
            xc, yc = chunk
            if self.shuffle_rows and xc.shape[0] > 1:
                perm = rng.permutation(xc.shape[0])
                xc = np.asarray(xc)[perm]
                yc = None if yc is None else np.asarray(yc)[perm]
            return xc, yc

        buf: list = []
        for chunk in self.source.chunks():
            buf.append(chunk)
            if len(buf) > self.buffer_chunks:
                yield emit(buf.pop(int(rng.integers(len(buf)))))
        while buf:
            yield emit(buf.pop(int(rng.integers(len(buf)))))


class StreamingLoader:
    """Double-buffered host->device chunk feed over a ``ChunkSource``.

    A background thread walks ``source.chunks()``, converts each chunk with
    ``jax.device_put`` and parks up to ``prefetch`` device-resident chunks in
    a bounded queue — so the transfer of the next chunk overlaps compute on
    the current one, and at most ``prefetch + 1`` chunks exist on the device.
    Iterating yields ``(X_dev, y_dev | None)`` in source order. The loader is
    re-iterable; each ``iter()`` is an independent pass with its own thread.
    Generator errors propagate to the consumer.

    ``prefetch=0`` disables the thread and transfers chunks inline — the
    right mode when "host" and "device" share one memory arena (CPU backend:
    an overlap thread only contends with compute for the same cores).

    ``dtype`` is the width chunks CROSS THE BUS in: under the bf16 precision
    policy ``falkon_fit_streaming`` sets it to the policy's storage dtype,
    halving host->device traffic relative to an fp32 stream.
    """

    def __init__(self, source: ChunkSource, *, prefetch: int = 2, dtype=None):
        if prefetch < 0:
            raise ValueError(f"prefetch must be >= 0, got {prefetch}")
        self.source = source
        self.prefetch = prefetch
        self.dtype = None if dtype is None else jnp.dtype(dtype)

    @property
    def n_rows(self) -> int:
        return self.source.n_rows

    @property
    def dim(self) -> int:
        return self.source.dim

    @property
    def chunk_rows(self) -> int | None:
        """The source's nominal chunk height (None when the source doesn't
        declare one) — what ``streaming_sweep`` pads ragged tails up to so
        every chunk of a fit shares ONE compiled sweep."""
        return getattr(self.source, "chunk_rows", None)

    def _put(self, a):
        a = jnp.asarray(a)
        if self.dtype is not None and jnp.issubdtype(a.dtype, jnp.floating):
            a = a.astype(self.dtype)
        return jax.device_put(a)

    def __iter__(self):
        return self.iter_chunks()

    def iter_chunks(self, *, with_targets: bool = True):
        """Iterate (X_dev, y_dev | None) pairs; ``with_targets=False`` skips
        the host->device transfer of y entirely — the CG matvec passes (all
        but the one RHS pass per fit) never read the targets, and at large n
        re-shipping them every iteration is pure wasted transfer bandwidth.
        """
        if self.prefetch == 0:
            for xc, yc in self.source.chunks():
                keep = with_targets and yc is not None
                yield self._put(xc), self._put(yc) if keep else None
            return
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def push_final(item):
            # The consumer may already be gone (early break sets ``stop``
            # then drains once); never block forever handing off the final
            # END/exception marker — retry with a timeout until delivered
            # or the consumer is known dead.
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return
                except queue.Full:
                    continue

        def work():
            try:
                for xc, yc in self.source.chunks():
                    if stop.is_set():
                        return
                    keep = with_targets and yc is not None
                    yd = self._put(yc) if keep else None
                    q.put((self._put(xc), yd))
                push_final(_END)
            except Exception as e:  # surface source errors to the consumer
                push_final(e)

        thread = threading.Thread(target=work, daemon=True)
        thread.start()
        try:
            while True:
                item = q.get()
                if item is _END:
                    return
                if isinstance(item, Exception):
                    raise item
                yield item
        finally:
            stop.set()
            try:  # unblock a producer parked on a full queue
                while True:
                    q.get_nowait()
            except queue.Empty:
                pass


class JittedOps:
    """Facade jitting a backend's ``sweep``/``apply`` once per fit.

    The streaming solve calls the per-chunk primitives thousands of times
    (chunks x CG iterations); eager dispatch of the backend's scan/pallas
    body per call is pure overhead. Jitting the bound methods once means
    every chunk of the same shape hits the XLA compile cache — this is the
    path both ``falkon_solve_streaming`` and the streaming benchmark run,
    so benchmark numbers measure the real fit path.
    """

    def __init__(self, ops):
        self.ops = ops
        self.sweep = jax.jit(ops.sweep)
        self.apply = jax.jit(ops.apply)


def _pad_rows(a: Array, rows: int) -> Array:
    return jnp.pad(a, ((0, rows - a.shape[0]),) + ((0, 0),) * (a.ndim - 1))


def streaming_sweep(
    ops, loader, C: Array, u: Array, *, use_targets=True, pad_ragged: bool = True
):
    """``K(X,C)^T (K(X,C) u + v)`` accumulated over streamed chunks of X.

    The sweep is additive over row chunks, so the chunked sum equals the
    in-core result exactly (up to fp32 summation order). ``use_targets=True``
    feeds each chunk's y as the sweep's v term (the RHS pass of Alg. 1);
    ``False`` runs the pure normal-equation matvec (v = 0) — and, when the
    loader supports it, skips transferring the targets at all.

    With ``pad_ragged`` (on by default, active when the loader declares
    ``chunk_rows``), a short tail chunk is zero-padded up to ``chunk_rows``
    and swept with a ``row_mask`` zeroing the pad rows' contribution EXACTLY
    — so every chunk of every CG iteration shares ONE sweep shape. Without
    this, a ragged tail misses the jit cache and costs a second XLA compile
    per sweep form per fit; full chunks also carry the (all-ones) mask so
    the tail shares their compiled program rather than adding a mask-less
    sibling trace.
    """
    if use_targets or not hasattr(loader, "iter_chunks"):
        it = iter(loader)
    else:
        it = loader.iter_chunks(with_targets=False)
    chunk_rows = getattr(loader, "chunk_rows", None) if pad_ragged else None
    full_mask = None
    if chunk_rows:
        full_mask = jnp.ones((chunk_rows,), jnp.float32)
    w = None
    out_dtype = None
    for xc, yc in it:
        if use_targets and yc is None:
            raise ValueError(
                "streaming_sweep(use_targets=True): source yielded a chunk "
                "without targets — v would silently become 0 and the RHS "
                "pass would produce a zero (garbage) solution"
            )
        vc = yc if use_targets else None
        nc = xc.shape[0]
        if chunk_rows and nc < chunk_rows:
            xc = _pad_rows(xc, chunk_rows)
            vc = None if vc is None else _pad_rows(vc, chunk_rows)
            mask = (jnp.arange(chunk_rows) < nc).astype(jnp.float32)
            wc = ops.sweep(xc, C, u, vc, row_mask=mask)
        elif chunk_rows and nc == chunk_rows:
            wc = ops.sweep(xc, C, u, vc, row_mask=full_mask)
        else:
            wc = ops.sweep(xc, C, u, vc)
        if out_dtype is None:
            out_dtype = wc.dtype
        # Reduced-storage chunk results (bf16 policy) accumulate in fp32
        # across chunks — the same accumulate-dtype contract as the
        # in-kernel tile loops; on the fp32 path the astype is a no-op, so
        # the chunked == in-core identity stays bit-for-bit.
        if jnp.dtype(out_dtype).itemsize < 4:
            wc = wc.astype(jnp.float32)
        w = wc if w is None else w + wc
    if w is None:
        raise ValueError("streaming_sweep: loader yielded no chunks")
    return w.astype(out_dtype)


def streaming_apply(
    ops, loader, C: Array, u: Array, *, pad_ragged: bool = True
) -> Array:
    """``K(X,C) u`` over streamed chunks of X, concatenated in order.

    Predictions never read targets, so target transfer is skipped when the
    loader supports it. A ragged tail chunk is padded up to the loader's
    ``chunk_rows`` (pad rows applied, then sliced off — apply is row-local,
    so valid rows are untouched): every chunk shares one compiled apply.
    """
    if hasattr(loader, "iter_chunks"):
        it = loader.iter_chunks(with_targets=False)
    else:
        it = iter(loader)
    chunk_rows = getattr(loader, "chunk_rows", None) if pad_ragged else None
    outs = []
    for xc, _ in it:
        nc = xc.shape[0]
        if chunk_rows and nc < chunk_rows:
            outs.append(ops.apply(_pad_rows(xc, chunk_rows), C, u)[:nc])
        else:
            outs.append(ops.apply(xc, C, u))
    if not outs:
        raise ValueError("streaming_apply: loader yielded no chunks")
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)


def streaming_uniform_centers(key: Array, source: ChunkSource, M: int):
    """Uniform (without replacement) Nystrom centers from a chunk source.

    ``source.n_rows`` is known up front, so this is exact uniform sampling —
    not reservoir-approximate: draw M sorted global indices, then gather the
    matching rows from each chunk as it streams past (host-side, one pass,
    no device transfer). Returns (centers, indices) as host arrays.
    """
    n = source.n_rows
    if not 0 < M <= n:
        raise ValueError(f"need 0 < M <= n rows, got M={M}, n={n}")
    seed = int(jax.random.randint(key, (), 0, np.iinfo(np.int32).max))
    idx = np.sort(np.random.default_rng(seed).choice(n, size=M, replace=False))
    rows = []
    offset = 0
    for xc, _ in source.chunks():
        lo = np.searchsorted(idx, offset)
        hi = np.searchsorted(idx, offset + xc.shape[0])
        if hi > lo:
            rows.append(np.asarray(xc)[idx[lo:hi] - offset])
        offset += xc.shape[0]
    centers = np.concatenate(rows, axis=0)
    return centers, idx
