"""Sharded host data pipeline.

Shards each global batch over the mesh data axes (device_put with a
NamedSharding), prefetching ``prefetch`` batches on a background thread so
host data generation overlaps device compute — the standard input-pipeline
overlap trick, minus tf.data.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.mesh import data_axes


class ShardedLoader:
    def __init__(self, it: Iterator[dict], mesh: Mesh | None = None, prefetch: int = 2):
        self._it = it
        self._mesh = mesh
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _shard(self, batch: dict) -> dict:
        if self._mesh is None:
            return batch
        dp = data_axes(self._mesh)
        out = {}
        for k, v in batch.items():
            if hasattr(v, "ndim") and v.ndim >= 1 and v.shape[0] % max(
                1, self._mesh.shape[dp[0]]
            ) == 0:
                spec = P(dp)
            else:
                spec = P()
            out[k] = jax.device_put(v, NamedSharding(self._mesh, spec)) if hasattr(
                v, "ndim"
            ) else v
        return out

    def _work(self):
        try:
            for batch in self._it:
                if self._stop.is_set():
                    return
                self._q.put(self._shard(batch))
        except Exception as e:  # surface generator errors to the consumer
            self._q.put(e)
        self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
