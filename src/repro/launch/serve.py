"""Serving launcher: batched LM prefill+decode loop, or a FALKON predictor.

LM mode (default):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --gen 32

FALKON mode — fit a kernel estimator and serve a ragged request trace
through the batch-coalescing predict server (``repro.serve``): requests are
packed into a power-of-two bucket ladder compiled once at warmup, so
steady-state serving never retraces and one device call serves many
requests. The per-request single-stream loop survives behind
``--per-request`` as the baseline the benchmark gates against:

    PYTHONPATH=src python -m repro.launch.serve --falkon --ops-impl pallas \
        --batch 256 --requests 200

With ``--stream-chunk N`` the fit streams X through the out-of-core path
(``falkon_fit_streaming``): host chunks of N rows double-buffered onto the
device, so n is bounded by host memory, not HBM.

Scaling limits — which (n, M) regime maps to which sweep path:

* ``fused`` (one Gram evaluation per tile): needs the (bm, M) Gram row strip
  and the (M, p) accumulator in VMEM — M up to ~8k at default tiles. n bound
  only by device HBM holding X.
* ``two_pass`` / ``j_sharded`` (two Gram evaluations per tile, chosen
  automatically by the VMEM planner — see ``KernelOps.plan()`` and the
  ``SweepPlanWarning`` it emits on fallback): O(tile) VMEM, M to 10^5+;
  ``t = K u + v`` spills to HBM and the center axis is swept in
  planner-sized C-shards.
* ``--stream-chunk`` (host streaming): n beyond HBM — each CG iteration
  streams X in chunks with O(chunk_rows * d + M * p) device state. Composes
  with either M regime above; the CG loop moves to the host, so the solve is
  no longer one fused XLA program.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp


def serve_lm(args) -> None:
    from repro.configs import ARCH_IDS, get_config, reduced_config
    from repro.models import decode_step, model_params, prefill

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.frontend == "embeds":
        cfg = dataclasses.replace(cfg, frontend="tokens")
    params = model_params(jax.random.PRNGKey(0), cfg)

    B, P, G = args.batch, args.prompt_len, args.gen
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)}
    if cfg.frontend == "tokens+vision":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_vision)
        ) * .05

    t0 = time.perf_counter()
    logits, cache = prefill(params, cfg, batch, S_max=P + G)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    step = jax.jit(lambda c, t: decode_step(params, cfg, c, {"token": t}))
    tok = jnp.argmax(logits, -1)
    out = [tok]
    logits, cache = step(cache, tok)        # compile
    t0 = time.perf_counter()
    for _ in range(G - 2):
        tok = jnp.argmax(logits, -1)
        out.append(tok)
        logits, cache = step(cache, tok)
    jax.block_until_ready(logits)
    t_decode = (time.perf_counter() - t0) / max(G - 2, 1)
    print(f"{cfg.name}: prefill {B}x{P} in {t_prefill*1e3:.0f}ms; "
          f"decode {t_decode*1e3:.1f}ms/token/batch")
    print("sample:", jnp.stack(out, 1)[0,:12].tolist())


def make_request_trace(
    key, n_requests: int, max_batch: int, d: int, seed: int = 0
) -> list:
    """Pre-generated ragged request batches (host arrays, sizes 1..max_batch).

    Generated BEFORE any serving timer starts: the old loop built each batch
    inside the timed region, so "ms/request" charged host-side RNG + array
    construction to the serving path and the numbers measured the generator,
    not the device work.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, max_batch + 1, size=n_requests)
    keys = jax.random.split(key, n_requests)
    return [jax.device_get(jax.random.normal(keys[i], (int(s), d)))
            for i, s in enumerate(sizes)]


def serve_falkon(args) -> None:
    """Fit once, then serve a ragged request trace — coalesced by default,
    the single-stream per-request loop behind ``--per-request``."""
    from repro.core import FalkonConfig, falkon_fit, falkon_fit_streaming
    from repro.data import ArrayChunkSource

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    n, d = args.n, args.d
    X = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (d,))
    y = jnp.sin(X @ w) + 0.05 * jax.random.normal(k3, (n,))

    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", 2.0),),
        lam=1e-5,
        num_centers=args.centers,
        iterations=15,
        block_size=max(args.batch, 128),
        ops_impl=args.ops_impl,
        precision=args.precision,
    )
    plan = cfg.make_ops().plan(n, min(args.centers, n), d)
    print(f"sweep plan: {plan.path} ({plan.reason})")
    t0 = time.perf_counter()
    if args.stream_chunk > 0:
        # out-of-core: X/y live on the host, chunks stream through a
        # double-buffered transfer (see repro.data.streaming)
        src = ArrayChunkSource(
            jax.device_get(X), jax.device_get(y), chunk_rows=args.stream_chunk
        )
        est, state = falkon_fit_streaming(jax.random.PRNGKey(1), src, cfg)
    else:
        est, state = falkon_fit(jax.random.PRNGKey(1), X, y, cfg)
    jax.block_until_ready(est.alpha)
    t_fit = time.perf_counter() - t0

    # the streaming solve skips the power-iteration cond estimate (each
    # probe would cost a full data pass) — don't print a fabricated 0.0
    cond = ("n/a" if args.stream_chunk > 0 else f"{float(state.cond_estimate):.1f}")
    print(f"falkon[{cfg.impl}/{cfg.precision}]: fit n={n} "
          f"M={est.centers.shape[0]} in {t_fit:.2f}s; cond(W)={cond}")

    # The serving step is KernelOps.apply on the backend baked into the
    # estimator — per request one (batch, M) kernel matmul. The trace is
    # pre-generated so the timer below measures serving, not host RNG.
    trace = make_request_trace(jax.random.PRNGKey(2), args.requests, args.batch, d)
    rows = sum(b.shape[0] for b in trace)
    if args.per_request:
        # single-stream baseline: one dispatch per request, one XLA trace
        # per DISTINCT batch shape — the cost profile the coalescing server
        # exists to remove
        step = jax.jit(est.predict)
        jax.block_until_ready(step(jnp.zeros((args.batch, d))))  # compile one
        t0 = time.perf_counter()
        for xb in trace:
            jax.block_until_ready(step(jnp.asarray(xb)))
        dt = time.perf_counter() - t0
        print(f"per-request: {len(trace)} requests ({rows} rows) in "
              f"{dt:.3f}s — {rows / dt:.0f} rows/s, "
              f"{dt / len(trace) * 1e3:.2f} ms/request")
    else:
        from repro.serve import CoalescingPredictServer

        server = CoalescingPredictServer(est, max_batch=args.batch)
        compile_s = server.warmup()
        print(f"coalescing server: ladder {server.ladder}, warmup "
              f"{sum(compile_s.values()):.2f}s "
              f"({len(compile_s)} bucket compiles)")
        t0 = time.perf_counter()
        server.predict_many(trace)
        dt = time.perf_counter() - t0
        s = server.stats
        print(f"coalesced: {len(trace)} requests ({rows} rows) in {dt:.3f}s "
              f"— {rows / dt:.0f} rows/s, {s.dispatches} dispatches, "
              f"pad fraction {s.pad_fraction:.1%}, retraces after warmup: "
              f"{server.retraces_since_warmup()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--falkon",
        action="store_true",
        help="serve a FALKON predictor instead of an LM",
    )
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    # FALKON-mode knobs
    ap.add_argument(
        "--ops-impl",
        default="jnp",
        choices=("jnp", "pallas"),
        help="KernelOps backend for fit + serving",
    )
    ap.add_argument("--precision", default="fp32", choices=("fp32", "bf16"))
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--centers", type=int, default=256)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--per-request", action="store_true",
                    help="serve the trace one request per dispatch (the "
                         "single-stream baseline) instead of coalescing")
    ap.add_argument("--stream-chunk", type=int, default=0,
                    help="fit via the host-streaming loader with this many "
                         "rows per chunk (0 = in-core fit)")
    args = ap.parse_args()

    if args.falkon:
        serve_falkon(args)
    else:
        from repro.configs import ARCH_IDS
        if args.arch not in ARCH_IDS:
            raise SystemExit(f"unknown arch {args.arch}; have {ARCH_IDS}")
        serve_lm(args)


if __name__ == "__main__":
    main()
