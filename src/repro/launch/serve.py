"""Serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --batch 4 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import decode_step, model_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.frontend == "embeds":
        cfg = dataclasses.replace(cfg, frontend="tokens")
    params = model_params(jax.random.PRNGKey(0), cfg)

    B, P, G = args.batch, args.prompt_len, args.gen
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                          cfg.vocab)}
    if cfg.frontend == "tokens+vision":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_vision)) * .05

    t0 = time.perf_counter()
    logits, cache = prefill(params, cfg, batch, S_max=P + G)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    step = jax.jit(lambda c, t: decode_step(params, cfg, c, {"token": t}))
    tok = jnp.argmax(logits, -1)
    out = [tok]
    logits, cache = step(cache, tok)        # compile
    t0 = time.perf_counter()
    for _ in range(G - 2):
        tok = jnp.argmax(logits, -1)
        out.append(tok)
        logits, cache = step(cache, tok)
    jax.block_until_ready(logits)
    t_decode = (time.perf_counter() - t0) / max(G - 2, 1)
    print(f"{cfg.name}: prefill {B}x{P} in {t_prefill*1e3:.0f}ms; "
          f"decode {t_decode*1e3:.1f}ms/token/batch")
    print("sample:", jnp.stack(out, 1)[0, :12].tolist())


if __name__ == "__main__":
    main()
