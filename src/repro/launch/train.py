"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --steps 100 \
        [--reduced] [--mesh dxm] [--ckpt-dir DIR]

On real hardware this runs the full config on the production mesh; on CPU use
--reduced (the smoke-scale config). The Trainer provides checkpoint/restart,
straggler detection and preemption-safe saves (SIGTERM handler installed).
"""
from __future__ import annotations

import argparse
import signal

import jax

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.data import ShardedLoader, TokenStreamConfig, token_stream
from repro.distributed.mesh import AxisRules
from repro.train import TrainConfig, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument(
        "--mesh", default=None, help="e.g. 16x16 or 2x16x16 (None = single device)"
    )
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = rules = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(dims, axes)
        rules = AxisRules(mesh=mesh, fsdp=cfg.fsdp)

    tcfg = TrainConfig(
        learning_rate=args.lr,
        warmup_steps=args.steps // 10,
        total_steps=args.steps,
        microbatch=args.microbatch,
        grad_compression=args.grad_compression,
    )
    rcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=max(10, args.steps // 5))
    trainer = Trainer(
        cfg,
        tcfg,
        rcfg,
        mesh=mesh,
        rules=rules,
        straggler_cb=lambda i,
        dt,
        z: print(f"[straggler] step {i}: {dt*1e3:.0f}ms (z={z:.1f})"),
    )
    signal.signal(signal.SIGTERM, lambda *_: trainer.request_preemption())

    stream = token_stream(TokenStreamConfig(
        vocab=min(cfg.vocab, 4096), seq_len=args.seq, batch=args.batch))
    loader = ShardedLoader(stream, mesh=mesh) if mesh else stream
    hist = trainer.fit(loader, steps=args.steps)
    print(f"{len(hist)} steps; loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f}; stragglers={len(trainer.straggler_events)}")


if __name__ == "__main__":
    main()
