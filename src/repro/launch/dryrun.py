import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell and each mesh (single-pod 16x16,
multi-pod 2x16x16):
    lowered  = jax.jit(step, in_shardings=...).lower(**input_specs(...))
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves the cell fits (or not)
    print(compiled.cost_analysis())     # FLOPs/bytes for the roofline
plus the collective-bytes parse for EXPERIMENTS.md SS Roofline.

Results are cached as JSON under artifacts/dryrun/ so cells can be run
incrementally:  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b
[--shape train_4k] [--mesh single|multi|both] [--all]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, input_specs
from repro.distributed.mesh import AxisRules, use_rules
from repro.launch.mesh import make_production_mesh
from repro.models import cache_pspecs, cache_specs
from repro.roofline.analysis import (
    analytic_memory,
    decode_model_flops,
    derive_roofline,
    memory_report,
    train_model_flops,
)
from repro.train.steps import (
    TrainConfig,
    batch_pspecs,
    make_serve_step,
    make_train_step,
    train_state_pspecs,
    train_state_structs,
)

ART_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun"
)


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def run_cell(
    arch: str, shape: str, multi_pod: bool, *, overrides: dict | None = None
) -> dict:
    cfg = get_config(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = AxisRules(mesh=mesh, fsdp=cfg.fsdp)
    specs = input_specs(cfg, shape)
    t0 = time.time()

    with mesh, use_rules(rules):
        if cell.kind in ("train", "prefill"):
            # grad accumulation down to ~1 batch row per data shard keeps
            # per-microbatch activation memory inside the 16 GB v5e budget
            # (global batch and math unchanged; extra param re-reads show up
            # in the memory roofline term, traded back in SS Perf).
            dp = 32 if multi_pod else 16
            mb = max(1, cell.global_batch // dp)
            tcfg = TrainConfig(microbatch=mb)
            if cell.kind == "train":
                state_structs = train_state_structs(cfg, tcfg)
                state_specs = train_state_pspecs(cfg, tcfg, rules)
                step = make_train_step(
                    cfg, tcfg, grad_shardings=_named(mesh, state_specs.params)
                )
                b_specs = batch_pspecs(cfg, specs, rules)
                jitted = jax.jit(
                    step,
                    in_shardings=(_named(mesh, state_specs), _named(mesh, b_specs)),
                    donate_argnums=(0,),
                )
                lowered = jitted.lower(state_structs, specs)
                tokens = cell.global_batch * cell.seq_len
                model_flops = train_model_flops(cfg, tokens)
            else:  # prefill: forward-only loss-less pass building a cache
                from repro.models import prefill as prefill_fn
                from repro.models import model_param_structs
                from repro.models.model import model_param_pspecs
                p_structs = model_param_structs(cfg)
                p_specs = model_param_pspecs(cfg, rules)
                pre_specs = {k: v for k, v in specs.items() if k != "labels"}
                b_specs = batch_pspecs(cfg, pre_specs, rules)
                fn = lambda params, batch: prefill_fn(
                    params, cfg, batch, S_max=cell.seq_len
                )
                jitted = jax.jit(
                    fn, in_shardings=(_named(mesh, p_specs), _named(mesh, b_specs))
                )
                lowered = jitted.lower(p_structs, pre_specs)
                tokens = cell.global_batch * cell.seq_len
                n_act = cfg.param_count(active_only=bool(cfg.n_experts))
                model_flops = 2.0 * n_act * tokens
        else:  # decode
            from repro.models import model_param_structs
            from repro.models.model import model_param_pspecs
            B, S_max = cell.global_batch, cell.seq_len
            p_structs = model_param_structs(cfg)
            p_specs = model_param_pspecs(cfg, rules)
            c_structs = cache_specs(cfg, B, S_max)
            c_specs = cache_pspecs(cfg, B, S_max, rules)
            b_specs = batch_pspecs(cfg, specs, rules)
            step = make_serve_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(_named(mesh, p_specs),
                                           _named(mesh, c_specs),
                                           _named(mesh, b_specs)),
                             donate_argnums=(1,))
            lowered = jitted.lower(p_structs, c_structs, specs)
            model_flops = decode_model_flops(cfg, B, S_max)

        compiled = lowered.compile()
        mem = memory_report(compiled)
        print(compiled.memory_analysis())     # proves it fits (or not)
        from repro.compat import cost_analysis_dict
        cost = cost_analysis_dict(compiled)
        print({k: v for k, v in cost.items() if k in ("flops", "bytes accessed")})
        roof = derive_roofline(compiled, chips=chips, model_flops=model_flops)

    hbm = 16e9  # v5e per-chip HBM
    result = {
        "arch": arch, "shape": shape,
        "microbatch": (cell.global_batch // (32 if multi_pod else 16))
        if cell.kind == "train" else 0,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": cell.kind,
        "compile_s": round(time.time() - t0, 1),
        "memory": mem,
        "analytic_memory_gb": analytic_memory(
            cfg, cell, rules,
            microbatch=(cell.global_batch // (32 if multi_pod else 16))
            if cell.kind == "train" else 1),
        "fits_hbm": mem["total_per_device"] < hbm,
        "bytes_per_device_gb": round(mem["total_per_device"] / 1e9, 3),
        "roofline": roof.as_dict(),
        "status": "ok",
    }
    return result


FALKON_N, FALKON_D, FALKON_M, FALKON_T = 134_217_728, 90, 16_384, 20


def run_falkon_cell(
    multi_pod: bool,
    *,
    block_size: int = 8192,
    impl: str = "jnp",
    full_mesh_data: bool = False,
) -> dict:
    """Dry-run the paper's own solver on the production mesh: n=2M, d=90
    (MillionSongs-like), M=16384 centers, t=20 CG iterations, X/y sharded
    over the data axes, preconditioner replicated."""
    import jax.numpy as jnp
    from repro.core import GaussianKernel, falkon_solve
    from repro.core.preconditioner import Preconditioner
    from repro.distributed.mesh import data_axes
    from repro.ops import DistributedOps, get_ops

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    kern = GaussianKernel(sigma=6.0)
    n, d, M, t = FALKON_N, FALKON_D, FALKON_M, FALKON_T
    f32 = jnp.float32
    t0 = time.time()

    with mesh:
        # SS Perf iteration 2: the CG sweep is embarrassingly data-parallel,
        # so flatten the WHOLE mesh (incl. the idle "model" axis) into the
        # data sweep — 256/512-way instead of 16/32-way.
        dp = data_axes(mesh) + ("model",) if full_mesh_data else data_axes(mesh)
        dops = DistributedOps(get_ops(impl, kern, block_size=block_size), mesh, dp)

        def solve(X, y, C, T, A):
            pre = Preconditioner(
                T=T, A=A, Q=None, D=None, n=jnp.asarray(n, f32), diag_T=False
            )
            st = falkon_solve(
                X,
                y,
                C,
                pre,
                kern,
                1e-6,
                t,
                block_size=block_size,
                ops=dops,
                estimate_cond=False,
            )
            return st.alpha

        Xs = jax.ShapeDtypeStruct((n, d), f32)
        ys = jax.ShapeDtypeStruct((n,), f32)
        Cs = jax.ShapeDtypeStruct((M, d), f32)
        Ts = jax.ShapeDtypeStruct((M, M), f32)
        sh = lambda spec: NamedSharding(mesh, spec)
        lowered = jax.jit(solve, in_shardings=(
            sh(P(dp)), sh(P(dp)), sh(P()), sh(P()), sh(P()))).lower(
            Xs, ys, Cs, Ts, Ts)
        compiled = lowered.compile()
        mem = memory_report(compiled)
        print(compiled.memory_analysis())
        # paper flop count: (t+2) sweeps x 2 kernel matmuls x 2nMd
        model_flops = (t + 2) * 4.0 * n * M * d
        roof = derive_roofline(compiled, chips=chips, model_flops=model_flops)

    return {
        "arch": "falkon-solver",
        "shape": f"n{n>>20}M_M{M}_t{t}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": "solve",
        "compile_s": round(time.time() - t0, 1),
        "memory": mem,
        "fits_hbm": mem["total_per_device"] < 16e9,
        "bytes_per_device_gb": round(mem["total_per_device"] / 1e9, 3),
        "block_size": block_size,
        "impl": impl,
        "roofline": roof.as_dict(),
        "status": "ok",
    }


def cell_path(arch, shape, multi_pod):
    os.makedirs(ART_DIR, exist_ok=True)
    mesh = "multi" if multi_pod else "single"
    return os.path.join(ART_DIR, f"{arch}__{shape}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--falkon", action="store_true", help="run the FALKON-solver cells only"
    )
    args = ap.parse_args()

    if args.falkon:
        import os as _os
        full = _os.environ.get("FALKON_FULL_MESH", "0") == "1"
        bs = int(_os.environ.get("FALKON_BLOCK", "8192"))
        for mp in {"single": [False], "multi": [True], "both": [False, True]}[
            args.mesh
        ]:
            res = run_falkon_cell(mp, full_mesh_data=full, block_size=bs)
            path = cell_path("falkon-solver", "solve", mp)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print(f"falkon cell ({res['mesh']}): "
                  f"{res['bytes_per_device_gb']} GB/dev, "
                  f"bottleneck={res['roofline']['bottleneck']}")
        return

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape else cfg.runnable_shapes())
        for shape in shapes:
            if shape in cfg.skip_shapes:
                print(f"SKIP {arch} x {shape} (per DESIGN.md SS5)")
                continue
            for mp in meshes:
                path = cell_path(arch, shape, mp)
                if os.path.exists(path) and not args.force:
                    print(f"cached {path}")
                    continue
                tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
                print(f"=== dry-run {tag} ===", flush=True)
                try:
                    res = run_cell(arch, shape, mp)
                    print(f"    ok: {res['bytes_per_device_gb']} GB/dev, "
                          f"bottleneck={res['roofline']['bottleneck']}")
                except Exception as e:
                    traceback.print_exc()
                    res = {
                        "arch": arch,
                        "shape": shape,
                        "mesh": "multi" if mp else "single",
                        "status": "error",
                        "error": repr(e),
                    }
                    failures.append(tag)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
