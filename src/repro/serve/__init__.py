"""FALKON serving layer: batch-coalescing predict server.

    from repro.serve import CoalescingPredictServer
    server = CoalescingPredictServer(est, max_batch=256)
    server.warmup()                       # one compile per bucket rung
    preds = server.predict_many(batches)  # ragged batches, zero retraces

``coalesce`` holds the pure packing policy (bucket ladder + dispatch
planning); ``server`` executes it over ``KernelOps.apply``, including the
multi-model tier that serves a whole ``FalkonPathResult`` through stacked
applies. ``repro.launch.serve --falkon`` drives this from the CLI;
``benchmarks/serve_coalesce.py`` measures it against the per-request loop.
"""
from .coalesce import (Dispatch, Segment, bucket_ladder, pick_bucket, plan_dispatches)
from .server import CoalescingPredictServer, ServeStats

__all__ = [
    "CoalescingPredictServer",
    "Dispatch",
    "Segment",
    "ServeStats",
    "bucket_ladder",
    "pick_bucket",
    "plan_dispatches",
]
