"""Batch-coalescing predict server over ``KernelOps.apply``.

After the O(n sqrt(n)) fit, a FALKON model is O(M) state — centers plus
coefficients — and prediction is ONE (batch, M) kernel matmul. That makes a
single device enough to serve heavy traffic, IF the serving layer doesn't
throw the advantage away. The naive loop does, twice: it pays one device
round-trip per request (dispatch overhead dwarfs a small kernel matmul), and
every novel batch shape retraces the jitted apply. This server fixes both:

* **Coalescing** — pending requests are packed row-wise into dispatches of
  up to ``max_batch`` rows (``repro.serve.coalesce.plan_dispatches``), so
  one device call serves many requests.
* **Bucket ladder** — each dispatch is padded to a power-of-two bucket shape
  compiled once at ``warmup()``; steady-state serving never retraces
  (``trace_count`` is the proof — incremented at trace time, it must not
  move after warmup). Pad rows are zeros; ``apply`` is row-local, so they
  are dropped on scatter-back without perturbing valid rows (fp32
  bucketed == direct ``predict`` bit-for-bit, tested).
* **Multi-model tier** — a :class:`FalkonPathResult` (L lam-estimators
  sharing Nystrom centers) is served through ONE stacked apply per bucket:
  the (L, M[, p]) coefficient stack is flattened to (M, L*p) columns — the
  same one-data-pass-serves-all-lams trick as the path solver's training
  sweep — so L models cost one model's kernel evaluations per request.
* **Double-buffered dispatch** — at most ``pipeline_depth`` dispatches are
  in flight: packing of dispatch k+1 on the host overlaps device compute of
  dispatch k (jax dispatch is asynchronous; the blocking transfer happens
  at scatter-back, one dispatch behind).

The server is synchronous and single-threaded by design: ``submit`` queues,
``flush`` coalesces + runs + scatters. Wrap it in whatever transport
(thread, asyncio, RPC) the deployment needs — batching policy and transport
are separate concerns.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Sequence

import jax
import numpy as np

from .coalesce import Dispatch, bucket_ladder, plan_dispatches


@dataclasses.dataclass
class ServeStats:
    """Counters the benchmark / README cost model read off the server."""

    dispatches: int = 0
    rows_valid: int = 0
    rows_padded: int = 0
    requests: int = 0

    @property
    def pad_fraction(self) -> float:
        total = self.rows_valid + self.rows_padded
        return self.rows_padded / total if total else 0.0


class CoalescingPredictServer:
    """Serve a :class:`FalkonEstimator` or :class:`FalkonPathResult`.

    ``ops`` defaults to the estimator's own cached backend (``est._ops`` —
    the same object ``predict`` uses, so bucketed and direct predictions run
    identical kernel code). ``max_batch`` bounds the rows per device call;
    the bucket ladder spans ``min_bucket .. max_batch`` in powers of two.
    """

    def __init__(
        self,
        model,
        *,
        max_batch: int = 256,
        min_bucket: int = 8,
        ops=None,
        pipeline_depth: int = 2,
    ):
        est, alpha, unstack = _resolve_model(model)
        if pipeline_depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, got {pipeline_depth}")
        self._ladder = bucket_ladder(max_batch, min_bucket)
        self._centers = est.centers
        self._alpha = alpha          # (M,), (M, p) or stacked (M, L*p)
        self._unstack = unstack      # (L, p) to reshape path outputs, or None
        self._ops = est._ops if ops is None else ops
        self._dim = int(est.centers.shape[1])
        self._in_dtype = np.dtype(est.centers.dtype)
        self._depth = pipeline_depth
        self._traces = 0
        self._warm_traces: int | None = None
        self.stats = ServeStats()
        self._pending: list[np.ndarray] = []
        self._scoring_cache = None   # KernelCache over a fixed scoring set

        def _raw_apply(xb, centers, alpha):
            # trace-time counter: jax.jit re-runs this Python body only on
            # a cache miss, so _traces counts XLA compiles, not calls —
            # the zero-retrace-after-warmup proof the tests assert on.
            # centers/alpha enter as ARGUMENTS, not closure constants: a
            # captured constant gets constant-folded by XLA with different
            # rounding than the eager predict path, breaking the fp32
            # bucketed == direct bit-identity this server guarantees.
            self._traces += 1
            return self._ops.apply(xb, centers, alpha)

        self._apply_jit = jax.jit(_raw_apply)

    def _apply(self, xb):
        return self._apply_jit(xb, self._centers, self._alpha)

    # -- introspection -----------------------------------------------------
    @property
    def ladder(self) -> tuple[int, ...]:
        return self._ladder

    @property
    def max_batch(self) -> int:
        return self._ladder[-1]

    @property
    def trace_count(self) -> int:
        """XLA traces of the bucketed apply so far (one per bucket shape)."""
        return self._traces

    def retraces_since_warmup(self) -> int:
        if self._warm_traces is None:
            raise RuntimeError("warmup() has not run")
        return self._traces - self._warm_traces

    # -- lifecycle ---------------------------------------------------------
    def warmup(self) -> dict[int, float]:
        """Compile the apply for every ladder rung; returns rung -> seconds.

        After this, any request mix is served from the compile cache:
        ``retraces_since_warmup()`` staying 0 is the steady-state contract.
        """
        compile_s: dict[int, float] = {}
        for rung in self._ladder:
            t0 = time.perf_counter()
            out = self._apply(np.zeros((rung, self._dim), self._in_dtype))
            jax.block_until_ready(out)
            compile_s[rung] = time.perf_counter() - t0
        self._warm_traces = self._traces
        return compile_s

    def swap_model(self, model) -> None:
        """Swap the served centers/alpha in place — zero retraces.

        The continuous-deployment seam `partial_fit` pairs with: a refreshed
        estimator (same shapes — partial_fit guarantees it) replaces the
        arrays behind the compiled applies. Because the bucketed apply takes
        centers/alpha as jit ARGUMENTS, not closure constants, every ladder
        rung's compiled program is a cache hit for the new arrays:
        ``retraces_since_warmup()`` stays 0 across the swap by construction
        (pinned in tests/test_minibatch.py). A model whose geometry differs
        from the warmed one is refused — that swap WOULD retrace every
        rung, so it must be a new server + warmup, not a hot swap.
        """
        est, alpha, unstack = _resolve_model(model)
        same = (
            est.centers.shape == self._centers.shape
            and est.centers.dtype == self._centers.dtype
            and alpha.shape == self._alpha.shape
            and alpha.dtype == self._alpha.dtype
            and unstack == self._unstack
        )
        if not same:
            raise ValueError(
                f"swap_model needs the warmed geometry: centers "
                f"{self._centers.shape}/{self._centers.dtype} and alpha "
                f"{self._alpha.shape}/{self._alpha.dtype}, got "
                f"{est.centers.shape}/{est.centers.dtype} and "
                f"{alpha.shape}/{alpha.dtype} — a different geometry would "
                f"retrace every ladder rung; build a new server instead"
            )
        self._centers = est.centers
        self._alpha = alpha
        if self._scoring_cache is not None:
            # the stored tiles are K(X_eval, OLD centers): a hot-swapped
            # model must not be scored through them. Invalidate (so a
            # caller still holding the cache object gets a refusal, not a
            # silently-wrong score) and drop it.
            self._scoring_cache.invalidate()
            self._scoring_cache = None

    def attach_scoring_cache(self, cache) -> None:
        """Pin a :class:`repro.ops.KernelCache` over a fixed evaluation set.

        The repeated-scoring loop (validation fold after every
        ``swap_model``-bound ``partial_fit``, canary panels, lam-grid
        selection) re-scores the SAME rows against each deployed model:
        with a cache attached, ``predict_scoring_set`` serves them as one
        GEMM from the stored tiles — zero kernel evaluations per score.
        The cache must serve the CURRENTLY deployed centers (identity
        check); ``swap_model`` invalidates and detaches it.
        """
        cache.check_serves(self._centers)
        self._scoring_cache = cache

    def predict_scoring_set(self) -> np.ndarray:
        """Score the attached evaluation set against the deployed model."""
        if self._scoring_cache is None:
            raise RuntimeError(
                "no scoring cache attached; call attach_scoring_cache first")
        self._scoring_cache.check_serves(self._centers)
        out = np.asarray(self._scoring_cache.apply(self._alpha))
        return self._finalize(out, out.shape[0])

    # -- request path ------------------------------------------------------
    def submit(self, x) -> int:
        """Queue one request of (rows, d) feature rows; returns its ticket
        (position in the next ``flush`` result list)."""
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[1] != self._dim:
            raise ValueError(f"request must be (rows, {self._dim}), got {x.shape}")
        self._pending.append(x.astype(self._in_dtype, copy=False))
        return len(self._pending) - 1

    def flush(self) -> list[np.ndarray]:
        """Coalesce + run + scatter every queued request, in submit order.

        Single model: request k -> (rows_k,) or (rows_k, p) predictions.
        Path model: request k -> (rows_k, L) or (rows_k, L, p) — one column
        block per lam, all from the same stacked applies.
        """
        batches, self._pending = self._pending, []
        if not batches:
            return []
        if self._warm_traces is None:
            self.warmup()
        sizes = [b.shape[0] for b in batches]
        plan = plan_dispatches(sizes, self._ladder)
        outs: list[np.ndarray | None] = [None] * len(batches)

        inflight: collections.deque = collections.deque()
        for disp in plan:
            buf = np.zeros((disp.bucket, self._dim), self._in_dtype)
            for s in disp.segments:
                rows = batches[s.request][s.req_offset : s.req_offset + s.rows]
                buf[s.buf_offset : s.buf_offset + s.rows] = rows
            inflight.append((disp, self._apply(buf)))   # async dispatch
            self.stats.dispatches += 1
            self.stats.rows_valid += disp.rows
            self.stats.rows_padded += disp.pad_rows
            # scatter one dispatch behind: the np.asarray transfer blocks on
            # the OLDEST result while the device runs the newest
            while len(inflight) >= self._depth + 1:
                self._scatter(*inflight.popleft(), sizes, outs)
        while inflight:
            self._scatter(*inflight.popleft(), sizes, outs)
        self.stats.requests += len(batches)
        return [self._finalize(out, size) for out, size in zip(outs, sizes)]

    def predict_many(self, batches: Sequence) -> list[np.ndarray]:
        """submit() every batch, flush(), return predictions in order."""
        for b in batches:
            self.submit(b)
        return self.flush()

    __call__ = predict_many

    # -- internals ---------------------------------------------------------
    def _scatter(self, disp: Dispatch, dev, sizes, outs) -> None:
        host = np.asarray(dev)                     # blocks until ready
        for s in disp.segments:
            out = outs[s.request]
            if out is None:
                out = outs[s.request] = np.empty(
                    (sizes[s.request],) + host.shape[1:], host.dtype
                )
            rows = host[s.buf_offset : s.buf_offset + s.rows]
            out[s.req_offset : s.req_offset + s.rows] = rows

    def _finalize(self, out: np.ndarray | None, size: int) -> np.ndarray:
        if out is None:                            # zero-row request
            trail = (() if self._alpha.ndim == 1 else (int(self._alpha.shape[1]),))
            out = np.empty((0,) + trail, np.dtype("float32"))
        if self._unstack is None:
            return out
        L, p = self._unstack
        out = out.reshape(out.shape[0], L, p)
        return out[..., 0] if p == 1 else out


def _resolve_model(model):
    """(estimator, alpha-or-stack, unstack) for either supported model tier.

    For a path result the per-lam coefficient stack (L, M[, p]) is flattened
    to (M, L*p) columns — estimator i's predictions are columns
    [i*p, (i+1)*p) of the stacked apply. Stacked serving is only valid when
    the estimators share centers; the path fit guarantees it (one centers
    array threaded through every ``_stage_wrap``), and a cheap sanity check
    rejects hand-built results whose center GEOMETRY diverges (value
    equality is trusted, not verified — comparing M x d arrays per server
    construction would defeat the O(M) model-state point).
    """
    # duck-typed to avoid a hard import cycle with repro.core
    if hasattr(model, "estimators") and hasattr(model, "state"):
        ests = model.estimators
        if not ests:
            raise ValueError("path result has no estimators")
        first = ests[0]
        for e in ests[1:]:
            shared = (
                e.centers is first.centers or e.centers.shape == first.centers.shape
            )
            if not shared:
                raise ValueError("path estimators must share centers")
        alphas = np.asarray(model.state.alphas)     # (L, M) or (L, M, p)
        L, M = alphas.shape[0], alphas.shape[1]
        p = alphas.shape[2] if alphas.ndim > 2 else 1
        flat = alphas.reshape(L, M, p).transpose(1, 0, 2).reshape(M, L * p)
        import jax.numpy as jnp
        return first, jnp.asarray(flat, first.alpha.dtype), (L, p)
    if hasattr(model, "centers") and hasattr(model, "alpha"):
        return model, model.alpha, None
    raise TypeError(
        f"expected a FalkonEstimator or FalkonPathResult, got {type(model)}"
    )
