"""Request coalescing: pack ragged predict requests into fixed shape buckets.

Pure host-side planning — no jax imports, unit-testable arithmetic. The
serving problem this solves: every distinct batch shape that reaches a
``jax.jit``-ed apply costs an XLA retrace, so a traffic mix of ragged
request sizes either retraces forever (one compile per novel size) or
serializes tiny dispatches (one device round-trip per request). The fix is
a small LADDER of power-of-two bucket shapes, compiled once at warmup:

* requests are packed row-wise, in arrival order, into dispatches of at
  most ``ladder[-1]`` rows (requests larger than the ladder top are split
  across dispatches — no size limit on a single request);
* each dispatch runs at the smallest ladder rung >= its valid rows, the
  remainder rows zero-padded (``apply`` is row-local, so pad rows cost
  flops but never perturb valid rows — they are simply dropped on
  scatter-back);
* every dispatch therefore hits one of ``len(ladder)`` compiled programs —
  zero retraces in steady state, proved by the server's trace counter.

``plan_dispatches`` is the whole coalescing policy; the server
(``repro.serve.server``) just executes its plan.
"""
from __future__ import annotations

import dataclasses


def _ceil_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def bucket_ladder(max_batch: int, min_bucket: int = 8) -> tuple[int, ...]:
    """Power-of-two bucket shapes ``min_bucket .. >= max_batch``.

    Both ends are rounded UP to powers of two (a ladder of pow2 rungs keeps
    the compile count at log2(max/min) + 1 while bounding pad waste at 2x).
    The top rung is the dispatch row capacity.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if min_bucket < 1:
        raise ValueError(f"min_bucket must be >= 1, got {min_bucket}")
    top = _ceil_pow2(max(max_batch, min_bucket))
    rung = _ceil_pow2(min_bucket)
    rungs = []
    while rung <= top:
        rungs.append(rung)
        rung *= 2
    return tuple(rungs)


def pick_bucket(rows: int, ladder: tuple[int, ...]) -> int:
    """Smallest ladder rung that holds ``rows`` valid rows."""
    if rows < 1:
        raise ValueError(f"rows must be >= 1, got {rows}")
    for b in ladder:
        if rows <= b:
            return b
    raise ValueError(
        f"{rows} rows exceed the ladder top {ladder[-1]} — plan_dispatches "
        "should have split this request")


@dataclasses.dataclass(frozen=True)
class Segment:
    """One contiguous run of rows: request slice -> dispatch-buffer slice."""

    request: int     # index into the submitted request list
    req_offset: int  # first row within the request
    buf_offset: int  # first row within the dispatch buffer
    rows: int


@dataclasses.dataclass(frozen=True)
class Dispatch:
    """One device call: ``rows`` valid rows packed into a ``bucket``-row
    buffer (pad rows zero, dropped on scatter-back)."""

    bucket: int
    rows: int
    segments: tuple[Segment, ...]

    @property
    def pad_rows(self) -> int:
        return self.bucket - self.rows


def plan_dispatches(sizes, ladder: tuple[int, ...]) -> tuple[Dispatch, ...]:
    """Greedy in-order packing of request ``sizes`` into bucket dispatches.

    Arrival order is preserved (request k's rows never land after request
    k+1's — FIFO fairness, no starvation) and dispatches are filled to the
    ladder top before a new one opens; a request crossing the boundary is
    split. Zero-size requests produce no segments (the server returns an
    empty prediction for them).
    """
    max_rows = ladder[-1]
    dispatches: list[Dispatch] = []
    segs: list[Segment] = []
    filled = 0

    def close():
        nonlocal segs, filled
        if filled:
            dispatches.append(Dispatch(bucket=pick_bucket(filled, ladder),
                                       rows=filled, segments=tuple(segs)))
        segs, filled = [], 0

    for req, size in enumerate(sizes):
        size = int(size)
        if size < 0:
            raise ValueError(f"request {req} has negative size {size}")
        off = 0
        while size > 0:
            take = min(size, max_rows - filled)
            segs.append(
                Segment(request=req, req_offset=off, buf_offset=filled, rows=take)
            )
            filled += take
            off += take
            size -= take
            if filled == max_rows:
                close()
    close()
    return tuple(dispatches)
