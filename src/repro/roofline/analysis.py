"""Roofline derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), at TPU v5e constants:
    compute    = HLO_FLOPs_per_device / 197e12        [s]
    memory     = HLO_bytes_per_device / 819e9         [s]
    collective = collective_bytes_per_device / 50e9   [s]

``compiled.cost_analysis()`` reports per-device numbers on the
SPMD-partitioned module (verified empirically: a (data,model)-sharded matmul
reports global_flops/n_devices). collective_bytes is parsed from the
post-partitioning HLO text — operand shapes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (shard shapes, i.e.
per-device wire bytes).
"""
from __future__ import annotations

import dataclasses
import re

# --- v5e hardware constants (per chip) --------------------------------------
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "f8e4m3": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes of collective ops, keyed by collective kind."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:   # -done consumes the -start, no new bytes
            continue
        kind = m.group(1)
        # operands are everything after the op name's '('; their typed shapes
        # appear inline: op(f32[128]{0} %x, bf16[4,8]{1,0} %y)
        args = line[m.end():]
        depth, j = 1, 0
        for j, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        args = args[:j]
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(args))
        out[kind] = out.get(kind, 0) + total
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6 N D (global, per step)
    useful_flops_ratio: float     # model_flops / (flops_per_device * chips)
    chips: int
    xla_flops_once: float         # XLA's (loop-body-once) number, reference
    unbounded_whiles: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def derive_roofline(compiled, *, chips: int, model_flops: float) -> Roofline:
    """Terms from the trip-count-corrected HLO walk (hlo_cost.analyze);
    XLA's cost_analysis counts while bodies once and is kept only as a
    reference field."""
    from .hlo_cost import analyze
    from repro.compat import cost_analysis_dict
    cost = analyze(compiled.as_text())
    ca = cost_analysis_dict(compiled)

    flops = float(cost.flops)
    byts = float(cost.bytes)
    coll_total = cost.collective_total

    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    global_flops = flops * chips
    ratio = model_flops / global_flops if global_flops else 0.0
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes=dict(cost.collective_bytes),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_flops_ratio=ratio,
        chips=chips,
        xla_flops_once=float(ca.get("flops", 0.0)),
        unbounded_whiles=cost.unbounded_whiles,
    )


def memory_report(compiled) -> dict:
    ma = compiled.memory_analysis()
    fields = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ]
    rep = {f: int(getattr(ma, f, 0)) for f in fields}
    rep["total_per_device"] = (rep["argument_size_in_bytes"] +
                               rep["output_size_in_bytes"] +
                               rep["temp_size_in_bytes"] -
                               rep["alias_size_in_bytes"])
    return rep


def train_model_flops(cfg, tokens: int) -> float:
    """MODEL_FLOPS = 6 * N_active * D_tokens (fwd+bwd)."""
    return 6.0 * cfg.param_count(active_only=bool(cfg.n_experts)) * tokens


def decode_model_flops(cfg, batch: int, kv_len: int) -> float:
    """One decode step: 2 * N_active matmul flops + attention over the cache
    (2 * 2 * H*dh * kv_len per layer per sequence, q@k and p@v)."""
    n_active = cfg.param_count(active_only=bool(cfg.n_experts))
    flops = 2.0 * n_active * batch
    attn_layers = sum(1 for s in cfg.layer_pattern if s.kind in ("full", "sliding"))
    if cfg.use_mla:
        per = 2 * 2 * cfg.n_heads * cfg.kv_lora_rank * kv_len
    else:
        per = 2 * 2 * cfg.n_heads * cfg.d_head * kv_len
    flops += attn_layers * per * batch
    return flops


# ---------------------------------------------------------------------------
# Analytic per-device memory (TPU expectation).
#
# The CPU-backend buffer assignment inflates ``memory_analysis`` two ways the
# TPU target does not: (i) bf16 dot operands are converted to f32 copies (no
# native bf16 dot on CPU), (ii) the FSDP all-gather is hoisted out of the
# layer loop (gathering the whole stack at once). We therefore also report an
# analytic estimate: params/optimizer/cache bytes computed EXACTLY from the
# parameter descriptors + sharding rules, plus a coarse activation model.
# ---------------------------------------------------------------------------
def _pd_device_bytes(pd_tree, rules, dtype_bytes: float) -> float:
    import numpy as _np
    from repro.models.params import PD

    def leaf(pd):
        shards = 1
        spec = rules.spec_for(pd.shape, pd.axes)
        for entry in spec:
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for nm in names:
                shards *= rules.mesh.shape[nm]
        return float(_np.prod(pd.shape)) * dtype_bytes / shards

    import jax as _jax
    return float(sum(_jax.tree.leaves(_jax.tree.map(
        leaf, pd_tree, is_leaf=lambda x: isinstance(x, PD)))))


def analytic_memory(cfg, cell, rules, *, microbatch: int = 1) -> dict:
    """Per-device bytes: exact params/opt/grads/cache + coarse activations."""
    from repro.models.model import cache_pd, model_pd, split_periods

    pd_tree = model_pd(cfg)
    params = _pd_device_bytes(pd_tree, rules, 2.0)          # bf16
    out = {"params": params}
    if cell.kind == "train":
        out["grads"] = params
        if cfg.optimizer == "adamw":
            out["opt"] = _pd_device_bytes(pd_tree, rules, 8.0)  # fp32 mu+nu
        elif cfg.optimizer == "adafactor":
            out["opt"] = params * 0.06                       # row+col factors
        else:
            out["opt"] = params * 2
    else:
        out["grads"] = out["opt"] = 0.0
    if cell.kind == "decode":
        out["cache"] = _pd_device_bytes(
            cache_pd(cfg, cell.global_batch, cell.seq_len), rules, 2.0
        )
    else:
        out["cache"] = 0.0
    # activations: tokens/device (per microbatch) x d_model x live-layer count
    dp = 1
    for a in ("pod", "data"):
        if a in rules.mesh.shape:
            dp *= rules.mesh.shape[a]
    if cell.kind == "train":
        tok = cell.global_batch * cell.seq_len / dp / max(microbatch, 1)
        period, n_per, tail = split_periods(cfg.layer_pattern)
        import math
        a = max(1, int(math.sqrt(n_per)))
        live = (a + n_per // a + len(tail)) + 12   # carries + transients
        out["activations"] = tok * cfg.d_model * 2.0 * live
    elif cell.kind == "prefill":
        tok = cell.global_batch * cell.seq_len / dp
        out["activations"] = tok * cfg.d_model * 2.0 * 10
    else:
        out["activations"] = cell.global_batch * cfg.d_model * 2.0 * 64
    out["total"] = sum(out.values())
    return {k: round(v / 1e9, 3) for k, v in out.items()}
