"""Static cost analysis over optimized HLO text with loop trip-count scaling.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified: a 10-iteration scanned matmul reports 1 matmul of
flops). Our models are scan-heavy by design (period-scan over layers, chunked
attention, SSD chunks, CE chunks, microbatching), so we re-derive costs by
walking the HLO call graph and multiplying while bodies by their trip counts
(``backend_config={"known_trip_count":{"n":...}}``, with a condition-constant
fallback).

Scheduled HLO omits operand types, so each computation keeps a symbol table
(op name -> output shape text) to resolve operand shapes.

Costs per computation:
* flops        — dot ops: 2 * prod(output dims) * prod(lhs contracting dims);
                 descends into fusion bodies (fusions hide the dots).
* bytes        — operand+output bytes of top-level ops (fusion calls counted
                 at the call site, matching XLA's "bytes accessed" semantics).
* collectives  — operand bytes of all-gather / all-reduce / reduce-scatter /
                 all-to-all / collective-permute, keyed by kind.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "f8e4m3fn": 1,
    "f8e5m2": 1,
    "f8e4m3": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*->")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\(.*?\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"
)
_ZERO_BYTE_OPS = {
    "parameter",
    "constant",
    "get-tuple-element",
    "tuple",
    "bitcast",
    "after-all",
    "iota",
    "partition-id",
    "replica-id",
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_args(argstr: str) -> tuple[str, str]:
    depth = 1
    for j, ch in enumerate(argstr):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return argstr[:j], argstr[j + 1 :]
    return argstr, ""


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_shape: str
    args: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    shapes: dict      # op name -> out_shape text


def parse_computations(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_HDR.match (line.strip())
                if m:
                    cur = Computation(m.group(2), [], {})
                    if m.group(1):
                        entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match (line)
        if not m:
            continue
        name, out_shape, kind, tail = m.groups()
        args, rest = _split_args(tail)
        op = Op(name, kind, out_shape, args, rest)
        cur.ops.append(op)
        cur.shapes[name] = out_shape
    return comps, entry


def _operand_bytes(op: Op, shapes: dict) -> int:
    total = 0
    for nm in _OPERAND_RE.findall(op.args):
        total += _shape_bytes(shapes.get(nm, ""))
    return total


def _dot_flops(op: Op, shapes: dict) -> float:
    out_elems = 1
    m = _SHAPE_RE.search(op.out_shape)
    if m and m.group(2):
        for d in m.group(2).split(","):
            out_elems *= int(d)
    ops_names = _OPERAND_RE.findall(op.args)
    lhs_shape = shapes.get(ops_names[0], "") if ops_names else ""
    lhs_m = _SHAPE_RE.search(lhs_shape)
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contract = 1
    if lhs_m and cdims and cdims.group(1):
        dims = [int(d) for d in lhs_m.group(2).split(",")] if lhs_m.group(2) else []
        for ci in cdims.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                contract *= dims[ci]
    return 2.0 * out_elems * contract


_CC_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')


def _custom_call_flops(op: Op, shapes: dict) -> float:
    m = _CC_TARGET_RE.search(op.rest)
    target = m.group(1).lower() if m else ""
    names = _OPERAND_RE.findall(op.args)

    def elems(txt):
        mm = _SHAPE_RE.search(txt)
        n = 1
        if mm and mm.group(2):
            for d in mm.group(2).split(","):
                n *= int(d)
        return n

    out_e = elems(op.out_shape)
    if "matmul" in target or "gemm" in target or "dot" in target:
        if len(names) >= 2:
            lhs_e = elems(shapes.get(names[0], ""))
            rhs_e = elems(shapes.get(names[1], ""))
            k = (lhs_e * rhs_e / max(out_e, 1)) ** 0.5   # (m k)(k n)/(m n)=k^2
            return 2.0 * out_e * k
        return 0.0
    if "trsm" in target or "triangular" in target:
        if names:
            a_e = elems(shapes.get(names[0], ""))        # (M, M)
            return float(a_e) * (out_e / max(a_e, 1) ** 0.5)
    if "potrf" in target or "cholesky" in target:
        return elems(shapes.get(names[0], "")) ** 1.5 / 3.0 if names else 0.0
    return 0.0


def _trip_count(op: Op, comps: dict) -> int | None:
    m = _TRIP_RE.search(op.rest)
    if m:
        return int(m.group(1))
    cond_m = _COND_RE.search(op.rest)
    if cond_m and cond_m.group(1) in comps:
        cond = comps[cond_m.group(1)]
        consts = {}
        for o in cond.ops:
            if o.kind == "constant":
                mm = re.match (r"\s*(-?\d+)\s*$", o.args)
                if mm:
                    consts[o.name] = int(mm.group(1))
        for o in cond.ops:
            if o.kind in ("compare", "fusion"):
                for nm in _OPERAND_RE.findall(o.args):
                    if nm in consts:
                        return max(consts[nm], 0)
    return None


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_bytes: dict[str, float]
    unbounded_whiles: int

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": {k: float(v) for k, v in self.collective_bytes.items()},
            "unbounded_whiles": self.unbounded_whiles,
        }


def analyze(text: str) -> HloCost:
    comps, entry = parse_computations(text)
    if entry is None:
        entry = next(iter(comps)) if comps else None
    memo_flops: dict[str, float] = {}
    memo: dict[str, HloCost] = {}

    def flops_of(comp_name: str, _depth=0) -> float:
        if comp_name in memo_flops or _depth > 50:
            return memo_flops.get(comp_name, 0.0)
        c = comps.get(comp_name)
        if c is None:
            return 0.0
        total = 0.0
        for op in c.ops:
            if op.kind == "dot":
                total += _dot_flops(op, c.shapes)
            elif op.kind == "custom-call":
                total += _custom_call_flops(op, c.shapes)
            elif op.kind == "fusion":
                mm = _CALLS_RE.search(op.rest)
                if mm:
                    total += flops_of(mm.group(1), _depth + 1)
        memo_flops[comp_name] = total
        return total

    def cost_of(comp_name: str, _depth=0) -> HloCost:
        if comp_name in memo:
            return memo[comp_name]
        c = comps.get(comp_name)
        if c is None or _depth > 50:
            return HloCost(0, 0, {}, 0)
        fl, by, unb = 0.0, 0.0, 0
        coll: dict[str, float] = defaultdict(float)
        for op in c.ops:
            base_kind = op.kind.replace("-start", "").replace("-done", "")
            if op.kind.endswith("-done"):
                continue
            if base_kind in _COLLECTIVES:
                b = _operand_bytes(op, c.shapes)
                coll[base_kind] += b
                by += b + _shape_bytes(op.out_shape)
            elif op.kind == "while":
                trip = _trip_count(op, comps)
                if trip is None:
                    trip, unb = 1, unb + 1
                body_m = _BODY_RE.search(op.rest)
                if body_m:
                    sub = cost_of(body_m.group(1), _depth + 1)
                    fl += sub.flops * trip
                    by += sub.bytes * trip
                    unb += sub.unbounded_whiles
                    for k, v in sub.collective_bytes.items():
                        coll[k] += v * trip
            elif op.kind == "conditional":
                bm = _BRANCH_RE.search(op.rest)
                if bm:
                    subs = [cost_of(b, _depth + 1) for b in
                            _OPERAND_RE.findall(bm.group(1)) if b in comps] + \
                           [cost_of(b.strip(), _depth + 1) for b in
                            bm.group(1).split(",") if b.strip() in comps]
                    if subs:
                        worst = max(subs, key=lambda s: s.flops + s.bytes)
                        fl += worst.flops
                        by += worst.bytes
                        for k, v in worst.collective_bytes.items():
                            coll[k] += v
            elif op.kind == "call":
                ta = _CALLS_RE.search(op.rest) or re.search(
                    r"to_apply=%?([\w.\-]+)", op.rest
                )
                if ta and ta.group(1) in comps:
                    sub = cost_of(ta.group(1), _depth + 1)
                    fl += sub.flops
                    by += sub.bytes
                    for k, v in sub.collective_bytes.items():
                        coll[k] += v
            elif op.kind == "fusion":
                fl += flops_of_fusion(op)
                by += _operand_bytes(op, c.shapes) + _shape_bytes(op.out_shape)
            elif op.kind == "custom-call":
                # CPU lowers big f32 matmuls to oneDNN/Eigen custom-calls —
                # count them or FALKON's Gram matmuls vanish from the roofline.
                fl += _custom_call_flops(op, c.shapes)
                by += _operand_bytes(op, c.shapes) + _shape_bytes(op.out_shape)
            elif op.kind == "dot":
                fl += _dot_flops(op, c.shapes)
                by += _operand_bytes(op, c.shapes) + _shape_bytes(op.out_shape)
            elif op.kind in _ZERO_BYTE_OPS:
                continue
            else:
                by += _operand_bytes(op, c.shapes) + _shape_bytes(op.out_shape)
        res = HloCost(fl, by, dict(coll), unb)
        memo[comp_name] = res
        return res

    def flops_of_fusion(op: Op) -> float:
        mm = _CALLS_RE.search(op.rest)
        return flops_of(mm.group(1)) if mm else 0.0

    if entry is None:
        return HloCost(0, 0, {}, 0)
    return cost_of(entry)
