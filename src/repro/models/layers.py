"""Layers for the 10 assigned architectures.

Everything is functional: ``<layer>_pd(cfg)`` builds the parameter-descriptor
tree, ``<layer>_apply(params, x, ...)`` the computation. Sharding is expressed
with logical-axis annotations (``lshard``) resolved by the AxisRules engine.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.mesh import lshard
from .params import PD

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------
def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def _act(name: str, gate: Array, up: Array) -> Array:
    if name == "swiglu":
        return jax.nn.silu(gate) * up
    if name == "geglu":
        return jax.nn.gelu(gate) * up
    if name == "gelu":
        return jax.nn.gelu(up)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, dh) or (B, S, dh); positions: (S,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs            # (S, half)
    ang = ang[None,:, None,:] if x.ndim == 4 else ang[None,:,:]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : half], x[..., half :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------
def mlp_pd(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": PD((D, F), ("embed", "ff")),
        "w_up": PD((D, F), ("embed", "ff")),
        "w_down": PD((F, D), ("ff", "embed")),
    }


def mlp_apply(p: dict, x: Array, cfg: ModelConfig) -> Array:
    h = _act(cfg.act, x @ p["w_gate"], x @ p["w_up"])
    h = lshard(h, ("batch", None, "ff"))
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (top-k, capacity-dropped, scatter dispatch)
# ---------------------------------------------------------------------------
def moe_pd(cfg: ModelConfig) -> dict:
    # expert dim padded to a shardable multiple (e.g. granite's 40 -> 48 on a
    # 16-way model axis); padded experts are masked out of the router.
    D, E, Fe = cfg.d_model, cfg.padded_experts, cfg.d_expert
    return {
        "router": PD((D, E), ("embed", "experts"), scale=0.02),
        "w_gate": PD((E, D, Fe), ("experts", "embed", None)),
        "w_up": PD((E, D, Fe), ("experts", "embed", None)),
        "w_down": PD((E, Fe, D), ("experts", None, "embed")),
    }


def moe_apply(p: dict, x: Array, cfg: ModelConfig) -> Array:
    """Token-dropping top-k MoE.

    Two paths:
    * shard_map expert parallelism (training/prefill on a mesh with a model
      axis): tokens resharded (batch->data, seq->model), local dispatch,
      explicit all_to_all to make experts local, local expert matmuls,
      all_to_all back, local combine. Measured SS Perf 4.2: the GSPMD
      scatter fallback all-reduces the full (E*C, D) buffer per layer per
      microbatch (10.5 TB/step/device on jamba-398B); the a2a moves only
      the dispatched tokens.
    * local jnp fallback (single device, tiny token counts, decode S==1):
      cumsum positions + scatter-add.
    """
    from repro.distributed.mesh import current_rules
    rules = current_rules()
    mesh = rules.mesh
    if mesh is not None and "model" in mesh.shape:
        mp = mesh.shape["model"]
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        B, S, D = x.shape
        if S % mp == 0 and B % dp_size == 0 and S // mp >= 1 and S > 1:
            return _moe_sharded(p, x, cfg, mesh, mp, dp)
    return _moe_local(p, x, cfg)


def _moe_sharded(
    p: dict, x: Array, cfg: ModelConfig, mesh, mp: int, dp: tuple
) -> Array:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    E, K = cfg.padded_experts, cfg.top_k
    E_loc = E // mp

    def local(x_loc, router, wg, wu, wd):
        # x_loc: (B_loc, S_loc, D); router replicated; w*: (E_loc, ...)
        Bl, Sl, D = x_loc.shape
        T = Bl * Sl
        C = max(1, int(-(-T * K * cfg.capacity_factor // cfg.n_experts)))
        xf = x_loc.reshape(T, D)
        logits = (xf @ router).astype(jnp.float32)
        if E != cfg.n_experts:
            logits = jnp.where(jnp.arange(E)[None,:] >= cfg.n_experts, -1e30, logits)
        gate, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        gate = (gate / jnp.sum(gate, -1, keepdims=True)).astype(x_loc.dtype)
        e_flat = eidx.reshape(-1)
        onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - onehot,
                                  e_flat[:, None], axis=1)[:, 0]
        keep = pos < C
        slot = jnp.where(keep, e_flat * C + pos, E * C)
        x_rep = jnp.repeat(xf, K, axis=0)
        buf = jnp.zeros((E * C + 1, D), x_loc.dtype).at[slot].add(
            x_rep * keep[:, None].astype(x_loc.dtype)
        )
        xe = buf[:-1].reshape(E, C, D)
        # expert all-to-all: (E, C, D) -> (E_loc, mp*C, D). Expert ids are
        # shard-major (expert = j*E_loc + e_loc, matching P("model") weight
        # sharding). split==concat==0 (symmetric) — the asymmetric form has
        # a broken VJP cotangent layout in jax 0.8.
        xe = jax.lax.all_to_all(xe.reshape(mp, E_loc, C, D), "model", 0, 0,
                                tiled=False)          # (src_shard, E_loc, C, D)
        xe = xe.transpose(1, 0, 2, 3).reshape(E_loc, mp * C, D)
        h = _act(
            cfg.act,
            jnp.einsum("ecd,edf->ecf", xe, wg),
            jnp.einsum("ecd,edf->ecf", xe, wu),
        )
        ye = jnp.einsum("ecf,efd->ecd", h, wd)       # (E_loc, mp*C, D)
        # inverse all-to-all: back to the (E, C, D) source-local layout
        ye = ye.reshape(E_loc, mp, C, D).transpose(1, 0, 2, 3)
        ye = jax.lax.all_to_all(ye, "model", 0, 0, tiled=False)
        ye = ye.reshape(E * C, D)                     # (mp*E_loc, C, D) flat
        y_tok = jnp.where(keep[:, None], ye[jnp.minimum(slot, E * C - 1)], 0.0)
        y = (y_tok.reshape(T, K, D) * gate[..., None]).sum(axis=1)
        return y.reshape(Bl, Sl, D)

    xspec = P(dp, "model", None)
    return shard_map(
        local, mesh=mesh,
        in_specs=(xspec, P(), P("model"), P("model"), P("model")),
        out_specs=xspec, check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_local(p: dict, x: Array, cfg: ModelConfig) -> Array:
    B, S, D = x.shape
    E, K, Fe = cfg.padded_experts, cfg.top_k, cfg.d_expert
    T = B * S
    C = max(1, int(T * K / cfg.n_experts * cfg.capacity_factor))

    xf = x.reshape(T, D)
    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, E_pad)
    if E != cfg.n_experts:   # mask padded experts out of the routing
        logits = jnp.where(jnp.arange(E)[None,:] >= cfg.n_experts, -1e30, logits)
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, K)                     # (T, K)
    gate = (gate / jnp.sum(gate, -1, keepdims=True)).astype(x.dtype)

    e_flat = eidx.reshape(-1)                                # (T*K,)
    # position of each assignment within its expert (priority: token order)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)      # (T*K, E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)              # count before me
    pos = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, e_flat * C + pos, E * C)          # overflow -> pad

    x_rep = jnp.repeat(xf, K, axis=0)                        # (T*K, D)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].add(
        x_rep * keep[:, None].astype(x.dtype)
    )
    xe = buf[:-1].reshape(E, C, D)
    xe = lshard(xe, ("experts", "expert_cap", None))

    h = _act(
        cfg.act,
        jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]),
        jnp.einsum("ecd,edf->ecf", xe, p["w_up"]),
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    ye = lshard(ye, ("experts", "expert_cap", None))

    yf = ye.reshape(E * C, D)
    y_tok = jnp.where(keep[:, None], yf[jnp.minimum(slot, E * C - 1)], 0.0)
    y = (y_tok.reshape(T, K, D) * gate[..., None]).sum(axis=1)
    return y.reshape(B, S, D)


# ---------------------------------------------------------------------------
# Attention (GQA / sliding / cross) with chunked online-softmax option
# ---------------------------------------------------------------------------
def attn_pd(cfg: ModelConfig, cross: bool = False) -> dict:
    D, Hkv, dh = cfg.d_model, cfg.n_kv_heads, cfg.d_head
    Hq = cfg.padded_heads     # dummy heads masked in attn_apply (SS Perf #2)
    kv_in = cfg.d_model if not cross else cfg.d_model   # vision proj upstream
    p = {
        "wq": PD((D, Hq, dh), ("embed", "heads", None)),
        "wk": PD((kv_in, Hkv, dh), ("embed", "kv_heads", None)),
        "wv": PD((kv_in, Hkv, dh), ("embed", "kv_heads", None)),
        "wo": PD((Hq, dh, D), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = PD((Hq, dh), ("heads", None), "zeros")
        p["bk"] = PD((Hkv, dh), ("kv_heads", None), "zeros")
        p["bv"] = PD((Hkv, dh), ("kv_heads", None), "zeros")
    if cross:
        p["q_norm"] = PD((dh,), (None,), "ones")
        p["k_norm"] = PD((dh,), (None,), "ones")
        p["gate"] = PD((1,), (None,), "zeros")   # zero-init cross gate
    return p


def _mask(si: Array, sj: Array, causal: bool, window: int) -> Array:
    """si: query positions (Sq,), sj: key positions (Sk,) -> bool (Sq, Sk)."""
    m = jnp.ones((si.shape[0], sj.shape[0]), bool)
    if causal:
        m &= sj[None,:] <= si[:, None]
    if window > 0:
        m &= sj[None,:] > si[:, None] - window
    return m


def _masked_write(cache: Array, new: Array, idx) -> Array:
    """cache: (B, Smax, ...), new: (B, 1, ...): write at position idx via an
    elementwise select over the (possibly sharded) seq dim."""
    Smax = cache.shape[1]
    mask = (jnp.arange(Smax) == idx)
    mask = mask.reshape((1, Smax) + (1,) * (cache.ndim - 2))
    return jnp.where(mask, new.astype(cache.dtype), cache)


def _block_write(cache: Array, new: Array) -> Array:
    """Write a length-S block at position 0 (prefill). S == Smax short-cuts
    to the block itself; otherwise pad + select (no DUS on sharded dims)."""
    Smax, S = cache.shape[1], new.shape[1]
    if S == Smax:
        return new.astype(cache.dtype)
    pad = [(0, 0), (0, Smax - S)] + [(0, 0)] * (cache.ndim - 2)
    newp = jnp.pad(new.astype(cache.dtype), pad)
    mask = (jnp.arange(Smax) < S).reshape((1, Smax) + (1,) * (cache.ndim - 2))
    return jnp.where(mask, newp, cache)


def _expand_kv(k: Array, groups: int) -> Array:
    """(B,S,Hkv,dh) -> (B,S,Hq,dh). Flat heads shard cleanly over the model
    axis (a (Hkv, G) grouped layout would need Hkv % model == 0)."""
    return jnp.repeat(k, groups, axis=2) if groups > 1 else k


def _sdpa(q: Array, k: Array, v: Array, mask: Array | None) -> Array:
    """q: (B,Sq,H,dh), k/v: (B,Sk,H,dh) -> (B,Sq,H,dh)."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(dh)
    if mask is not None:
        scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def _chunked_sdpa(
    q: Array,
    k: Array,
    v: Array,
    q_pos: Array,
    k_pos: Array,
    causal: bool,
    window: int,
    chunk: int,
    q_block: int = 2048,
) -> Array:
    """Online-softmax attention: q processed in blocks (lax.map, rematted),
    kv scanned in chunks. Peak score tensor: (B, H, q_block, chunk) — capped
    even for archs whose few heads cannot shard over the model axis."""
    B, Sq, H, dh = q.shape
    if Sq > q_block and Sq % q_block == 0:
        nq = Sq // q_block
        qb = q.reshape(B, nq, q_block, H, dh).transpose(1, 0, 2, 3, 4)
        pb = q_pos.reshape(nq, q_block)

        def one(args):
            qi, pi = args
            return _chunked_sdpa_core(qi, k, v, pi, k_pos, causal, window, chunk)

        out = jax.lax.map(jax.checkpoint(one), (qb, pb))
        return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, v.shape[-1])
    return _chunked_sdpa_core(q, k, v, q_pos, k_pos, causal, window, chunk)


def _chunked_sdpa_core(
    q: Array,
    k: Array,
    v: Array,
    q_pos: Array,
    k_pos: Array,
    causal: bool,
    window: int,
    chunk: int,
) -> Array:
    """KV-chunk online-softmax scan. q: (B,Sq,H,dh), k/v: (B,Sk,H,dh|dv)."""
    B, Sq, H, dh = q.shape
    dv = v.shape[-1]
    Sk = k.shape[1]
    nc = -(-Sk // chunk)
    pad = nc * chunk - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kpos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kc = kp.reshape(B, nc, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nc, chunk, H, dv).transpose(1, 0, 2, 3, 4)
    pc = kpos.reshape(nc, chunk)
    scale = 1.0 / jnp.sqrt(dh)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        msk = _mask(q_pos, pb, causal, window) & (pb[None,:] < Sk)
        s = jnp.where(msk[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, -1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, dv), jnp.float32)
    # checkpointed body: the (B,H,Sq,chunk) score tensor is recomputed in
    # bwd instead of being saved per scan step (flash-attention-style memory)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)        # (B,Sq,H,dv)


def attn_apply(
    p: dict,
    x: Array,
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    positions: Array,
    kv_x: Array | None = None,
    cache: dict | None = None,
    pos_scalar: Array | None = None,
):
    """Returns (out, new_cache).

    Modes:
    * train / prefill: ``cache is None`` — full-sequence attention (dense or
      chunked online-softmax above cfg.dense_attn_max_seq).
    * decode: x is (B, 1, D); ``cache`` holds k/v at capacity S_max and
      ``pos_scalar`` is the write index. Cross layers reuse the static image
      kv held in the cache.
    ``positions``: (Sq,) absolute positions of the query tokens.
    """
    B, Sq, D = x.shape
    Hkv, dh = cfg.n_kv_heads, cfg.d_head
    Hq = cfg.padded_heads
    G = Hq // Hkv
    cross = spec.kind == "cross"
    window = cfg.sliding_window if spec.kind == "sliding" else 0

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]

    if cross:
        if cache is not None and kv_x is None:
            k, v = cache["k"], cache["v"]          # static image kv
            new_cache = cache
        else:
            k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
            v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
            new_cache = {"k": k, "v": v}
        if "q_norm" in p:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        q = lshard(q, ("batch", None, "heads", None))
        o = _sdpa(q, _expand_kv(k, G), _expand_kv(v, G), None)
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)     # new tokens only
        q = lshard(q, ("batch", None, "heads", None))

        if cache is not None and Sq > 1:
            # prefill: write the whole kv block at 0, attend over fresh kv
            new_cache = {
                "k": _block_write(cache["k"], k), "v": _block_write(cache["v"], v)
            }
            kf, vf = _expand_kv(k, G), _expand_kv(v, G)
            if Sq <= cfg.dense_attn_max_seq:
                o = _sdpa(q, kf, vf, _mask(positions, positions, True, window))
            else:
                o = _chunked_sdpa(
                    q, kf, vf, positions, positions, True, window, cfg.attn_chunk
                )
        elif cache is not None:
            # decode: write new kv at pos_scalar, attend over the cache.
            # masked elementwise write — a dynamic-update-slice at a traced
            # index on the sharded seq dim would make GSPMD all-gather the
            # whole cache; the select keeps it fully sharded.
            idx = pos_scalar
            kc = _masked_write(cache["k"], k, idx)
            vc = _masked_write(cache["v"], v, idx)
            new_cache = {"k": kc, "v": vc}
            kc = lshard(kc, ("batch", "kv_seq", "kv_heads", None))
            vc = lshard(vc, ("batch", "kv_seq", "kv_heads", None))
            Smax = kc.shape[1]
            k_pos = jnp.arange(Smax)
            valid = k_pos <= idx
            if window > 0:
                valid &= k_pos > idx - window
            # grouped-q form: contract each kv head against its G q-heads
            qg = q.reshape(B, Sq, Hkv, G, dh)
            s = jnp.einsum("bqngd,bknd->bngqk", qg, kc).astype(jnp.float32)
            s = s / jnp.sqrt(dh)
            s = jnp.where(valid[None, None, None, None,:], s, -1e30)
            w = jax.nn.softmax(s, -1).astype(x.dtype)
            o = jnp.einsum("bngqk,bknd->bqngd", w, vc)
        else:
            new_cache = None
            kf, vf = _expand_kv(k, G), _expand_kv(v, G)
            if Sq <= cfg.dense_attn_max_seq:
                o = _sdpa(q, kf, vf, _mask(positions, positions, True, window))
            else:
                o = _chunked_sdpa(
                    q, kf, vf, positions, positions, True, window, cfg.attn_chunk
                )

    o = o.reshape(B, Sq, Hq, dh)
    if Hq != cfg.n_heads:   # zero dummy-head outputs: exact true-head model
        o = o * (jnp.arange(Hq) < cfg.n_heads)[None, None,:, None].astype(o.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if cross and "gate" in p:
        out = out * jnp.tanh(p["gate"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA (MiniCPM3 / DeepSeek-style latent attention)
# ---------------------------------------------------------------------------
def mla_pd(cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.padded_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rp, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": PD((D, r_q), ("embed", None)),
        "q_a_norm": PD((r_q,), (None,), "ones"),
        "wq_b": PD((r_q, H, nope + rp), (None, "heads", None)),
        "w_dkv": PD((D, r_kv), ("embed", None)),
        "kv_a_norm": PD((r_kv,), (None,), "ones"),
        "w_krope": PD((D, rp), ("embed", None)),
        "w_uk": PD((r_kv, H, nope), (None, "heads", None)),
        "w_uv": PD((r_kv, H, vd), (None, "heads", None)),
        "wo": PD((H, vd, D), ("heads", None, "embed")),
    }


def mla_apply(
    p: dict,
    x: Array,
    cfg: ModelConfig,
    *,
    positions: Array,
    cache: dict | None = None,
    pos_scalar: Array | None = None,
):
    B, Sq, D = x.shape
    H = cfg.padded_heads
    nope, rp, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    qa = rms_norm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", qa, p["wq_b"])            # (B,S,H,nope+rp)
    q_nope, q_rope = q[..., : nope], q[..., nope :]
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_a_norm"], cfg.norm_eps)  # (B,S,r_kv)
    k_rope = x @ p["w_krope"]                                  # (B,S,rp)

    if cache is None or Sq > 1:
        q_rope = rope(q_rope, positions, cfg.rope_theta)
        k_rope = rope(k_rope, positions, cfg.rope_theta)
        new_cache = None
        if cache is not None:   # prefill: store compressed kv at position 0
            new_cache = {
                "c_kv": _block_write(cache["c_kv"], c_kv),
                "k_rope": _block_write(cache["k_rope"], k_rope),
            }
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:,:, None], (B, Sq, H, rp))], -1
        )
        qfull = jnp.concatenate([q_nope, q_rope], -1)
        if Sq <= cfg.dense_attn_max_seq:
            o = _sdpa(qfull, k, v, _mask(positions, positions, True, 0))
        else:
            o = _chunked_sdpa(
                qfull, k, v, positions, positions, True, 0, cfg.attn_chunk
            )
    else:
        # absorbed decode: score in the latent space (B,S,r_kv) — the MLA
        # cache is the compressed c_kv + shared k_rope, O(S*(r_kv+rp)) memory.
        idx = pos_scalar
        q_rope = rope(q_rope, idx[None], cfg.rope_theta)
        k_rope = rope(k_rope, idx[None], cfg.rope_theta)
        ckv_c = _masked_write(cache["c_kv"], c_kv, idx)
        krope_c = _masked_write(cache["k_rope"], k_rope, idx)
        new_cache = {"c_kv": ckv_c, "k_rope": krope_c}
        Smax = ckv_c.shape[1]
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])  # absorb W_uk
        s = (jnp.einsum("bshr,btr->bhst", q_lat, ckv_c) +
             jnp.einsum("bshk,btk->bhst", q_rope, krope_c)).astype(jnp.float32)
        s = s / jnp.sqrt(nope + rp)
        valid = jnp.arange(Smax) <= idx
        s = jnp.where(valid[None, None, None,:], s, -1e30)
        w = jax.nn.softmax(s, -1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", w, ckv_c)           # (B,1,H,r_kv)
        o = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"])       # absorb W_uv

    if H != cfg.n_heads:    # zero dummy-head outputs (head padding)
        o = o * (jnp.arange(H) < cfg.n_heads)[None, None,:, None].astype(o.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, new_cache
