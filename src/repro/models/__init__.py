"""Model zoo for the assigned architectures (see repro.configs)."""
from .model import (
    cache_pspecs,
    cache_specs,
    decode_step,
    forward,
    init_cache,
    loss_fn,
    model_param_pspecs,
    model_param_structs,
    model_params,
    prefill,
    split_periods,
)
