"""Model assembly: period-scan layer stacking, loss, prefill/decode.

Heterogeneous layer patterns (jamba's 1-attn:7-mamba, gemma3's 5-local:1-global,
llama-vision's every-5th-cross) are expressed as the smallest repeating
*period*: params for one period are stacked over n_periods and applied with
``lax.scan`` — one traced period body regardless of depth, which is what keeps
the 80–100-layer dry-run HLO small. The non-periodic tail (e.g. gemma3-1b's
last 2 layers) is applied unrolled.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed.mesh import lshard
from .import layers as L
from .import ssm as S
from .params import PD, init_params, param_pspecs, param_shape_structs, stack_pds

Array = jax.Array


# ---------------------------------------------------------------------------
# Period decomposition
# ---------------------------------------------------------------------------
def split_periods(pattern: tuple[LayerSpec, ...]):
    """-> (period, n_periods, tail). Smallest p with pattern = period*k + tail
    and tail a prefix of the period; k maximal."""
    Lp = len(pattern)
    for p in range(1, Lp + 1):
        k = Lp // p
        period = pattern[:p]
        if period * k == pattern[: p * k] and pattern[p * k :] == period[: Lp - p * k]:
            if k >= 1:
                return period, k, pattern[p * k :]
    return pattern, 1, ()


# ---------------------------------------------------------------------------
# Per-layer param descriptors / apply
# ---------------------------------------------------------------------------
def layer_pd(cfg: ModelConfig, spec: LayerSpec) -> dict:
    D = cfg.d_model
    d: dict[str, Any] = {"ln1": PD((D,), ("embed",), "ones")}
    if spec.kind == "mamba":
        d["mixer"] = S.ssm_pd(cfg)
    elif spec.kind == "cross":
        d["mixer"] = L.attn_pd(cfg, cross=True)
    elif cfg.use_mla:
        d["mixer"] = L.mla_pd(cfg)
    else:
        d["mixer"] = L.attn_pd(cfg)
    has_mlp = spec.moe or cfg.d_ff > 0
    if has_mlp:
        d["ln2"] = PD((D,), ("embed",), "ones")
        d["mlp"] = L.moe_pd(cfg) if spec.moe else L.mlp_pd(cfg)
    return d


def layer_apply(
    p: dict,
    x: Array,
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    positions,
    vision_kv=None,
    cache=None,
    pos_scalar=None,
):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.kind == "mamba":
        mix, new_cache = S.ssm_apply(p["mixer"], h, cfg, cache=cache)
    elif spec.kind == "cross":
        mix, new_cache = L.attn_apply(
            p["mixer"],
            h,
            cfg,
            spec,
            positions=positions,
            kv_x=vision_kv,
            cache=cache,
            pos_scalar=pos_scalar,
        )
    elif cfg.use_mla:
        mix, new_cache = L.mla_apply(
            p["mixer"], h, cfg, positions=positions, cache=cache, pos_scalar=pos_scalar
        )
    else:
        mix, new_cache = L.attn_apply(
            p["mixer"],
            h,
            cfg,
            spec,
            positions=positions,
            cache=cache,
            pos_scalar=pos_scalar,
        )
    x = x + mix
    if "mlp" in p:
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        out = L.moe_apply(p["mlp"], h2, cfg) if spec.moe else L.mlp_apply(
            p["mlp"], h2, cfg
        )
        x = x + out
    x = lshard(x, ("batch", None, "embed"))
    return x, new_cache


# ---------------------------------------------------------------------------
# Model params
# ---------------------------------------------------------------------------
def model_pd(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.padded_vocab
    period, n_per, tail = split_periods(cfg.layer_pattern)
    tree: dict[str, Any] = {}
    # the embed table always exists: "embeds" frontends (audio) use it for
    # decode (the EnCodec codebook is the vocab); training consumes embeds.
    tree["embed"] = PD((V, D), ("vocab", "embed"), "embed", scale=0.02)
    if cfg.frontend == "tokens+vision":
        tree["vision_proj"] = PD((cfg.d_vision, D), (None, "embed"))
    tree["period"] = [stack_pds(layer_pd(cfg, spec), n_per) for spec in period]
    tree["tail"] = [layer_pd(cfg, spec) for spec in tail]
    tree["ln_f"] = PD((D,), ("embed",), "ones")
    tree["lm_head"] = PD((D, V), ("embed", "vocab"), scale=0.02)
    return tree


def model_params(key: jax.Array, cfg: ModelConfig):
    return init_params(key, model_pd(cfg), jnp.dtype(cfg.dtype))


def model_param_structs(cfg: ModelConfig):
    return param_shape_structs(model_pd(cfg), jnp.dtype(cfg.dtype))


def model_param_pspecs(cfg: ModelConfig, rules):
    return param_pspecs(model_pd(cfg), rules)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _embed_inputs(params, cfg: ModelConfig, batch: dict) -> Array:
    if "embeds" in batch:
        return batch["embeds"].astype(jnp.dtype(cfg.dtype))
    return jnp.take(params["embed"], batch["tokens"], axis=0)


def _vision_kv_src(params, cfg: ModelConfig, batch: dict) -> Array | None:
    if cfg.frontend != "tokens+vision":
        return None
    return batch["vision_embeds"].astype(jnp.dtype(cfg.dtype)) @ params["vision_proj"]


def _stack_apply(
    params,
    cfg: ModelConfig,
    x: Array,
    *,
    positions,
    vision_kv=None,
    caches=None,
    pos_scalar=None,
):
    """Run period-scan + tail. caches: None or matching structure
    {"period": [stacked per period-slot], "tail": [...]}. Returns (x, caches).
    """
    period, n_per, tail = split_periods(cfg.layer_pattern)

    def period_body(x, slices):
        p_slice, c_slice = slices
        new_cs = []
        for i, spec in enumerate(period):
            x, nc = layer_apply(
                p_slice[i],
                x,
                cfg,
                spec,
                positions=positions,
                vision_kv=vision_kv,
                cache=None if c_slice is None else c_slice[i],
                pos_scalar=pos_scalar,
            )
            new_cs.append(nc if nc is not None else 0)
        return x, new_cs

    body = period_body
    if cfg.remat == "full":
        body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.nothing_saveable
        )

    cache_xs = None if caches is None else caches["period"]
    a = _sqrt_factor(n_per)
    if caches is None and cfg.remat == "full" and n_per >= 12 and a > 1:
        # 2-level (sqrt) checkpointing over periods: bwd keeps O(a + n/a)
        # period carries live instead of O(n) — the difference between a
        # deep stack fitting HBM or not (see EXPERIMENTS.md SS Perf).
        b = n_per // a
        p2 = jax.tree.map(lambda t: t.reshape((a, b) + t.shape[1:]), params["period"])

        def outer_body(xc, p_slice_b):
            xc, _ = jax.lax.scan(lambda xx, ps: body(xx, (ps, None)), xc, p_slice_b)
            return xc, 0

        x, _ = jax.lax.scan(jax.checkpoint(outer_body), x, p2)
        new_period_cache = None
    else:
        x, new_period_cache = jax.lax.scan(body, x, (params["period"], cache_xs))
    new_caches = None
    tail_caches = []
    for i, spec in enumerate(tail):
        c = None if caches is None else caches["tail"][i]

        def tail_fn(p, xx, cc):
            return layer_apply(
                p,
                xx,
                cfg,
                tail[i],
                positions=positions,
                vision_kv=vision_kv,
                cache=cc,
                pos_scalar=pos_scalar,
            )

        if cfg.remat == "full" and caches is None:
            tail_fn = jax.checkpoint(tail_fn)
        x, nc = tail_fn(params["tail"][i], x, c)
        tail_caches.append(nc if nc is not None else 0)
    if caches is not None:
        new_caches = {"period": new_period_cache, "tail": tail_caches}
    return x, new_caches


def _sqrt_factor(n: int) -> int:
    """Largest divisor of n that is <= sqrt(n)."""
    best = 1
    for a in range(2, int(n**0.5) + 1):
        if n % a == 0:
            best = a
    return best


def _backbone(params, cfg: ModelConfig, batch: dict) -> Array:
    """Embed -> stack -> final norm. Returns hidden states (B, S, D)."""
    x = _embed_inputs(params, cfg, batch)
    x = lshard(x, ("batch", None, "embed"))
    S_ = x.shape[1]
    positions = jnp.arange(S_)
    vkv = _vision_kv_src(params, cfg, batch)
    x, _ = _stack_apply(params, cfg, x, positions=positions, vision_kv=vkv)
    return L.rms_norm(x, params["ln_f"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch: dict) -> Array:
    """Training/prefill forward -> logits (B, S, padded_vocab)."""
    x = _backbone(params, cfg, batch)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return lshard(logits, ("batch", None, "vocab"))


def _ce_chunk(x_c: Array, labels_c: Array, lm_head: Array, cfg: ModelConfig):
    """CE over one sequence chunk: logits live only inside this (rematted)
    body, so peak memory is O(B * S_chunk * V) instead of O(B * S * V)."""
    logits = jnp.einsum("bsd,dv->bsv", x_c, lm_head)
    logits = lshard(logits, ("batch", None, "vocab"))
    V = cfg.padded_vocab
    if V != cfg.vocab:   # mask padded vocab entries out of the normalizer
        neg = jnp.asarray(jnp.finfo(logits.dtype).min, logits.dtype)
        logits = jnp.where((jnp.arange(V) >= cfg.vocab)[None, None,:], neg, logits)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    sumexp = jnp.sum(jnp.exp((logits - m[..., None]).astype(jnp.float32)), axis=-1)
    lse = m.astype(jnp.float32) + jnp.log(sumexp)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    return jnp.sum(lse - gold.astype(jnp.float32))


def loss_fn(params, cfg: ModelConfig, batch: dict, *, ce_chunk: int = 512):
    x = _backbone(params, cfg, batch)              # (B,S,D)
    labels = batch["labels"]
    B, S_, D = x.shape
    Sc = min(ce_chunk, S_)
    if S_ % Sc:
        Sc = S_                                     # odd sizes: single chunk
    nc = S_ // Sc
    xs = x.reshape(B, nc, Sc, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, Sc).transpose(1, 0, 2)

    def body(tot, inp):
        xc, lc = inp
        return tot + _ce_chunk(xc, lc, params["lm_head"], cfg), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32), (xs, ls))
    loss = total / (B * S_)
    return loss, {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


# ---------------------------------------------------------------------------
# Serving: cache init/specs, prefill, decode
# ---------------------------------------------------------------------------
def layer_cache_pd(cfg: ModelConfig, spec: LayerSpec, B: int, S_max: int):
    f = jnp.dtype(cfg.dtype)
    if spec.kind == "mamba":
        H, N, P_, di, K = (
            cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim, cfg.d_inner, cfg.ssm_conv
        )
        return {
            "state": PD((B, H, N, P_), ("batch", "heads", None, None), "zeros"),
            "conv": PD((B, K - 1, di + 2 * N), ("batch", None, "ff"), "zeros"),
        }
    if spec.kind == "cross":
        return {
            "k": PD((B, cfg.n_image_tokens, cfg.n_kv_heads, cfg.d_head),
                    ("batch", None, "kv_heads", None), "zeros"),
            "v": PD((B, cfg.n_image_tokens, cfg.n_kv_heads, cfg.d_head),
                    ("batch", None, "kv_heads", None), "zeros"),
        }
    if cfg.use_mla:
        return {
            "c_kv": PD((B, S_max, cfg.kv_lora_rank),
                       ("batch", "cache_seq", None), "zeros"),
            "k_rope": PD((B, S_max, cfg.qk_rope_dim),
                         ("batch", "cache_seq", None), "zeros"),
        }
    seq_ax = "cache_seq" if B == 1 else "kv_seq"
    return {
        "k": PD((B, S_max, cfg.n_kv_heads, cfg.d_head),
                ("batch", seq_ax, "kv_heads", None), "zeros"),
        "v": PD((B, S_max, cfg.n_kv_heads, cfg.d_head),
                ("batch", seq_ax, "kv_heads", None), "zeros"),
    }


def cache_pd(cfg: ModelConfig, B: int, S_max: int) -> dict:
    period, n_per, tail = split_periods(cfg.layer_pattern)
    return {
        "pos": PD((), (), "zeros"),
        "period": [stack_pds(layer_cache_pd(cfg, spec, B, S_max), n_per,
                             axis_name=None) for spec in period],
        "tail": [layer_cache_pd(cfg, spec, B, S_max) for spec in tail],
    }


def cache_specs(cfg: ModelConfig, B: int, S_max: int):
    tree = cache_pd(cfg, B, S_max)
    structs = param_shape_structs(tree, jnp.dtype(cfg.dtype))
    structs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return structs


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    tree = cache_pd(cfg, B, S_max)
    out = init_params(jax.random.PRNGKey(0), tree, jnp.dtype(cfg.dtype))
    out["pos"] = jnp.zeros((), jnp.int32)
    return out


def cache_pspecs(cfg: ModelConfig, B: int, S_max: int, rules):
    tree = cache_pd(cfg, B, S_max)
    specs = param_pspecs(tree, rules)
    from jax.sharding import PartitionSpec as P
    specs["pos"] = P()
    return specs


def prefill(params, cfg: ModelConfig, batch: dict, S_max: int):
    """Run the prompt through the stack, building a cache of capacity S_max."""
    B, S_ = (batch["embeds"] if cfg.frontend == "embeds" else batch["tokens"]).shape[:2]
    cache = init_cache(cfg, B, S_max)
    x = _embed_inputs(params, cfg, batch)
    positions = jnp.arange(S_)
    vkv = _vision_kv_src(params, cfg, batch)
    x, new_caches = _stack_apply(
        params,
        cfg,
        x,
        positions=positions,
        vision_kv=vkv,
        caches={"period": cache["period"], "tail": cache["tail"]},
    )
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], params["lm_head"])
    new_caches["pos"] = jnp.asarray(S_, jnp.int32)
    return logits[:, 0], new_caches


def decode_step(params, cfg: ModelConfig, cache: dict, batch: dict):
    """One token step. batch: {"token": (B,)} (+ vision embeds use cache)."""
    tok = batch["token"]
    x = jnp.take(params["embed"], tok, axis=0)[:, None,:]
    x = lshard(x, ("batch", None, "embed"))
    pos = cache["pos"]
    positions = pos[None]
    x, new_caches = _stack_apply(
        params,
        cfg,
        x,
        positions=positions,
        caches={"period": cache["period"], "tail": cache["tail"]},
        pos_scalar=pos,
    )
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    new_caches["pos"] = pos + 1
    return lshard(logits, ("batch", "vocab")), new_caches
