"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

Chunked SSD algorithm for train/prefill (the quadratic-within-chunk /
recurrent-across-chunks decomposition, chunk = cfg.ssm_chunk) and the O(1)
recurrent update for decode. Multi-value variant: B/C shared across heads
(n_groups = 1), heads H = d_inner / head_dim.

Recurrence (head h, step i):
    a_i = exp(dt_i * A_h)            (A_h < 0)
    h_i = a_i * h_{i-1} + dt_i * B_i (x) x_i
    y_i = C_i . h_i + D_h * x_i
Contribution of x_j to y_i:  C_i B_j dt_j exp(cl_i - cl_j) x_j  with cl the
inclusive cumsum of log a — the "1-semiseparable attention" form the chunked
algorithm factorizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.mesh import lshard
from .params import PD
from .layers import rms_norm

Array = jax.Array


def ssm_pd(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    di, N, H, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    return {
        "w_x": PD((D, di), ("embed", "ff")),
        "w_z": PD((D, di), ("embed", "ff")),
        "w_B": PD((D, N), ("embed", None)),
        "w_C": PD((D, N), ("embed", None)),
        "w_dt": PD((D, H), ("embed", "heads")),
        "dt_bias": PD((H,), ("heads",), "zeros"),
        "conv_w": PD((K, di + 2 * N), (None, "ff"), scale=0.2),
        "A_log": PD((H,), ("heads",), "ssm_A"),
        "D_skip": PD((H,), ("heads",), "ones"),
        "out_norm": PD((di,), ("ff",), "ones"),
        "w_out": PD((di, D), ("ff", "embed")),
    }


def _causal_conv(xBC: Array, w: Array) -> Array:
    """Depthwise causal conv, xBC: (B,S,Ch), w: (K,Ch)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(K):                       # K is tiny (4): unrolled taps
        out = out + pad[:, i : i + xBC.shape[1]] * w[i]
    return out


def ssm_apply(p: dict, x_in: Array, cfg: ModelConfig, *, cache: dict | None = None):
    """x_in: (B,S,D). Returns (out, new_cache).

    cache (decode): {"state": (B,H,N,P), "conv": (B,K-1,di+2N)}.
    """
    B, S, D = x_in.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P_ = cfg.ssm_head_dim
    K = cfg.ssm_conv

    xz = x_in @ p["w_x"]                                    # (B,S,di)
    z = x_in @ p["w_z"]
    Bc = x_in @ p["w_B"]
    Cc = x_in @ p["w_C"]
    dt = jax.nn.softplus((x_in @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (H,)
    xBC = jnp.concatenate([xz, Bc, Cc], -1)                  # (B,S,di+2N)

    if cache is None:
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"]))
        new_cache = None
    else:
        conv_prev = cache["conv"]                            # (B,K-1,Ch)
        window = jnp.concatenate([conv_prev, xBC], 1)        # (B,K-1+S,Ch)
        full = jax.nn.silu(_causal_conv(
            jnp.concatenate([jnp.zeros_like(conv_prev[:, :0]), window], 1),
            p["conv_w"]))
        xBC = full[:, K - 1:]                                # aligned outputs
        new_conv = window[:, -(K - 1):]
        new_cache = {"conv": new_conv}

    xs, Bs, Cs = jnp.split(xBC, [di, di + N], axis=-1)
    xh = xs.reshape(B, S, H, P_)
    xh = lshard(xh, ("batch", None, "heads", None))

    if cache is not None and S == 1:
        # O(1) decode update
        a = jnp.exp(dt[:, 0] * A)                            # (B,H)
        dBx = jnp.einsum(
            "bh,bn,bhp->bhnp",
            dt[:, 0],
            Bs[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
        )
        state = cache["state"] * a[..., None, None] + dBx    # (B,H,N,P)
        y = jnp.einsum("bn,bhnp->bhp", Cs[:, 0].astype(jnp.float32), state)
        y = y + p["D_skip"].astype(jnp.float32)[None,:, None] * xh[:, 0]
        y = y.reshape(B, 1, di).astype(x_in.dtype)
        new_cache = {"state": state, "conv": new_cache["conv"]}
    else:
        y, state = _ssd_chunked(xh, dt, A, Bs, Cs, p["D_skip"], cfg)
        if cache is not None:
            new_cache = {"state": state, "conv": new_cache["conv"]}
        y = y.reshape(B, S, di).astype(x_in.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["w_out"], new_cache


def _ssd_chunked(
    xh: Array,
    dt: Array,
    A: Array,
    Bs: Array,
    Cs: Array,
    D_skip: Array,
    cfg: ModelConfig,
):
    """Chunked SSD, sequential over chunks. xh: (B,S,H,P); dt: (B,S,H) fp32;
    A: (H,) fp32; Bs/Cs: (B,S,N). Returns (y (B,S,H,P), state (B,H,N,P)).

    One lax.scan step = one chunk: the (B,Q,Q,H) intra-chunk decay tensor is
    a transient of a single step (checkpointed body — recomputed in bwd), so
    peak memory is O(B*Q^2*H), not O(B*S*Q*H)."""
    B, S, H, P_ = xh.shape
    N = Bs.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bs = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))

    out_dtype = xh.dtype
    # (nc, B, Q, ...) — chunk-major for the scan
    xc = xh.reshape(B, nc, Q, H, P_).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(B, nc, Q, H).transpose(1, 0, 2, 3)
    Bcq = Bs.reshape(B, nc, Q, N).transpose(1, 0, 2, 3)
    Ccq = Cs.reshape(B, nc, Q, N).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None,:,:, None]

    def body(h, inp):
        x_, dt_, B_, C_ = inp
        x_ = x_.astype(jnp.float32)
        B_ = B_.astype(jnp.float32)
        C_ = C_.astype(jnp.float32)
        l = dt_ * A                                      # (B,Q,H) <= 0
        cl = jnp.cumsum(l, axis=1)
        # intra: scores[i,j] = (C_i.B_j) exp(cl_i - cl_j) dt_j,  j <= i.
        # Mask the exponent BEFORE exp — for j > i it is positive and would
        # overflow to inf, poisoning gradients through the outer where.
        CB = jnp.einsum("bin,bjn->bij", C_, B_)
        diff = cl[:, :, None, :] - cl[:, None, :, :]     # (B,i,j,H)
        decay = jnp.exp(jnp.where(tri, diff, -jnp.inf))
        y = jnp.einsum("bijh,bjh,bjhp->bihp", CB[..., None] * decay, dt_, x_)
        # inter: y_i += C_i . (exp(cl_i) h_prev)
        y = y + jnp.einsum("bin,bih,bhnp->bihp", C_, jnp.exp(cl), h)
        y = y + D_skip.astype(jnp.float32)[None, None,:, None] * x_
        # state update
        dec_end = jnp.exp(cl[:, -1:, :] - cl)            # (B,Q,H)
        h_new = h * jnp.exp(cl[:, -1,:])[..., None, None] + jnp.einsum(
            "bjh,bjh,bjn,bjhp->bhnp", dec_end, dt_, B_, x_
        )
        return h_new, y.astype(out_dtype)

    h0 = jnp.zeros((B, H, N, P_), jnp.float32)
    a = _sqrt_factor(nc)
    if nc >= 16 and a > 1:
        # 2-level (sqrt) checkpointing over chunks: during bwd only
        # O(a + nc/a) fp32 state carries stay live instead of O(nc) — the
        # dominant train-memory term for wide-state SSMs (jamba H=256).
        bI = nc // a
        r2 = lambda t: t.reshape((a, bI) + t.shape[1:])
        xs2 = (r2(xc), r2(dtc), r2(Bcq), r2(Ccq))

        def outer(h, xs_b):
            h, ys_b = jax.lax.scan(jax.checkpoint(body), h, xs_b)
            return h, ys_b

        h_last, ys = jax.lax.scan(jax.checkpoint(outer), h0, xs2)
        ys = ys.reshape((nc,) + ys.shape[2:])
    else:
        h_last, ys = jax.lax.scan(jax.checkpoint(body), h0, (xc, dtc, Bcq, Ccq))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * Q, H, P_)[:,:S]
    return y.astype(jnp.float32), h_last


def _sqrt_factor(n: int) -> int:
    best = 1
    for a in range(2, int(n**0.5) + 1):
        if n % a == 0:
            best = a
    return best
