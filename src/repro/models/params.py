"""Parameter descriptor system.

Layers declare parameters as ``PD(shape, logical_axes, init)`` trees; from one
descriptor tree we derive (a) initialized arrays (smoke tests / examples),
(b) ShapeDtypeStructs (dry-run — no allocation), (c) PartitionSpecs (via the
AxisRules engine). This guarantees the three views never diverge.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.mesh import AxisRules


class PD(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed | ssm_A
    scale: float | None = None    # stddev; default 1/sqrt(fan_in)


def _is_pd(x) -> bool:
    return isinstance(x, PD)


def init_params(key: jax.Array, tree, dtype) -> dict:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_pd)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, pd in zip(keys, leaves):
        if pd.init == "zeros":
            arr = jnp.zeros(pd.shape, dtype)
        elif pd.init == "ones":
            arr = jnp.ones(pd.shape, dtype)
        elif pd.init == "ssm_A":     # A_log in [log 1, log 16]
            arr = jnp.log(jax.random.uniform(k, pd.shape, jnp.float32,
                                             1.0, 16.0)).astype(dtype)
        else:
            fan_in = pd.shape[0] if len(pd.shape) == 1 else int(
                np.prod(pd.shape[:-1]) if pd.init == "embed" else np.prod(pd.shape[:-1])
            )
            scale = pd.scale if pd.scale is not None else fan_in ** -0.5
            if pd.init == "embed":
                scale = 1.0 if pd.scale is None else pd.scale
            arr = (jax.random.normal(k, pd.shape, jnp.float32) * scale).astype(dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def param_shape_structs(tree, dtype) -> dict:
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, jnp.dtype(dtype)),
        tree,
        is_leaf=_is_pd,
    )


def param_pspecs(tree, rules: AxisRules) -> dict:
    return jax.tree.map(
        lambda pd: rules.spec_for(pd.shape, pd.axes), tree, is_leaf=_is_pd
    )


def stack_pds(tree, n: int, axis_name: str | None = "fsdp") -> dict:
    """Stack descriptors along a new leading (scan) axis — period stacking.
    The leading axis carries ``axis_name`` ("fsdp": sharded over data when
    cfg.fsdp, else replicated)."""
    return jax.tree.map(
        lambda pd: PD((n,) + pd.shape, (axis_name,) + pd.axes, pd.init, pd.scale),
        tree,
        is_leaf=_is_pd,
    )
