"""musicgen-large [audio] — 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048, decoder-only over EnCodec tokens. [arXiv:2306.05284]
Frontend is a stub per the assignment: input_specs() provides precomputed
frame embeddings (the 4-codebook delay-pattern sum); the decode path embeds
EnCodec code ids through the (vocab=2048) table.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_head=64,
        d_ff=8192,
        vocab=2048,
        n_codebooks=4,
        frontend="embeds",
        act="gelu",
        skip_shapes=("long_500k",),
    )
