"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attn image layers. [hf:meta-llama/Llama-3.2-90B-Vision]
Pattern: every 5th layer is gated cross-attention to the (stubbed) vision
frontend: input_specs provides precomputed patch embeddings (1601 x 1280).
"""
from .base import LayerSpec, ModelConfig


def _pattern(n):
    return tuple(LayerSpec("cross" if i % 5 == 4 else "full") for i in range(n))


def get_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_head=128,
        d_ff=28672,
        vocab=128256,
        layer_pattern=_pattern(100),
        frontend="tokens+vision",
        n_image_tokens=1601,
        d_vision=1280,
        fsdp=True,
        optimizer="adafactor",
        skip_shapes=("long_500k",),
    )
