"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8. [hf:ibm-granite/granite-3.0-3b-a800m-base; hf]
(Assignment header lists both "40e top-8" and "32 experts top-8"; we follow the
primary spec: 40 experts, top-8 — matching the HF granite-3.0-3b-a800m card.)
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
        d_ff=0, d_expert=512, n_experts=40, top_k=8,
        vocab=49155,
        layer_pattern=tuple(LayerSpec("full", moe=True) for _ in range(32)),
        skip_shapes=("long_500k",),   # pure full attention
    )
