"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global. [hf:google/gemma-3-4b-pt] Window 1024, head_dim 256.
"""
from .base import LayerSpec, ModelConfig


def _pattern(n):
    return tuple(LayerSpec("full" if i % 6 == 5 else "sliding") for i in range(n))


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        d_head=256,
        d_ff=10240,
        vocab=262144,
        layer_pattern=_pattern(34),
        sliding_window=1024,
        rope_theta=1_000_000.0,
    )
