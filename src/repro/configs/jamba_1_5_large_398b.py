"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba:attn 7:1 interleave. [arXiv:2403.19887]
Period of 8: [attn, mamba x7], MoE on every other layer (odd in-period index).
SSM: d_state 16, conv 4, expand 2 (d_inner 16384, 256 heads of 64).
"""
from .base import LayerSpec, ModelConfig


def _pattern(n):
    out = []
    for i in range(n):
        kind = "full" if i % 8 == 0 else "mamba"
        out.append(LayerSpec(kind, moe=(i % 2 == 1)))
    return tuple(out)


def get_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=24576, d_expert=24576, n_experts=16, top_k=2,
        vocab=65536,
        layer_pattern=_pattern(72),
        ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
        ssm_chunk=128,
        fsdp=True, optimizer="adafactor",
        # runs long_500k: hybrid 1:7 attn:mamba.
    )
