"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA.
[hf:openbmb/MiniCPM3-4B] MLA: q_lora 768, kv_lora 256, nope 64, rope 32, v 64.
"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_head=64,
        d_ff=6400,
        vocab=73448,
        use_mla=True,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        skip_shapes=("long_500k",),
    )
