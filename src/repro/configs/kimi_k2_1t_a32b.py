"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE. [arXiv:2501.kimi2]
Per the assignment table this build uses GQA (kv=8), not K2's MLA.
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_head=112,
        d_ff=0,
        d_expert=2048,
        n_experts=384,
        top_k=8,
        vocab=163840,
        layer_pattern=tuple(LayerSpec("full", moe=True) for _ in range(61)),
        fsdp=True,
        optimizer="adafactor",
        skip_shapes=("long_500k",),
    )
