"""Config registry: the 10 assigned architectures + paper-experiment configs.

``get_config(arch)`` returns the full assigned config; ``reduced_config(arch)``
a structurally-identical tiny config (same layer-pattern family, small dims)
for the CPU smoke tests — full configs are only exercised via the dry-run.
"""
from __future__ import annotations

import dataclasses

from .base import LayerSpec, ModelConfig, SHAPES, ShapeCell, input_specs, batch_sample

from .import (
    gemma3_1b,
    gemma3_4b,
    granite_moe_3b_a800m,
    jamba_1_5_large_398b,
    kimi_k2_1t_a32b,
    llama_3_2_vision_90b,
    mamba2_370m,
    minicpm3_4b,
    musicgen_large,
    qwen2_72b,
)

_MODULES = {
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "gemma3-1b": gemma3_1b,
    "qwen2-72b": qwen2_72b,
    "minicpm3-4b": minicpm3_4b,
    "gemma3-4b": gemma3_4b,
    "mamba2-370m": mamba2_370m,
    "llama-3.2-vision-90b": llama_3_2_vision_90b,
    "musicgen-large": musicgen_large,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    return _MODULES[arch].get_config()


def reduced_config(arch: str) -> ModelConfig:
    """Tiny same-family config: 2 periods + tail of the real layer pattern,
    small widths, few experts — one CPU train/serve step in seconds."""
    from repro.models.model import split_periods

    cfg = get_config(arch)
    period, n_per, tail = split_periods(cfg.layer_pattern)
    n_keep = min(n_per, 2)
    pattern = period * n_keep + tail
    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=len(pattern), layer_pattern=pattern,
        d_model=64, n_heads=heads, n_kv_heads=kv, d_head=16,
        d_ff=128 if cfg.d_ff else 0,
        d_expert=32 if cfg.n_experts else 0,
        n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2),
        # lossless capacity (C >= worst-case expert load) so decode ==
        # teacher-forced forward exactly; the full configs keep 1.25.
        capacity_factor=float(min(cfg.n_experts, 4)) if cfg.n_experts else 1.25,
        vocab=512, vocab_pad_multiple=64,
        sliding_window=8,
        q_lora_rank=32 if cfg.use_mla else 0,
        kv_lora_rank=16 if cfg.use_mla else 0,
        qk_nope_dim=16 if cfg.use_mla else 0,
        qk_rope_dim=8 if cfg.use_mla else 0,
        v_head_dim=16 if cfg.use_mla else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        n_image_tokens=16 if cfg.n_image_tokens else 0,
        d_vision=32 if cfg.d_vision else 0,
        dense_attn_max_seq=64,   # exercise the chunked-attention path too
        attn_chunk=16,
        dtype="float32", remat="none", fsdp=False,
    )
