"""ModelConfig + the assigned input shapes + input_specs().

Every assigned architecture is a ``ModelConfig``; the four assigned shape
cells are ``SHAPES`` below. ``input_specs(cfg, shape)`` returns
jax.ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
shardable, no device allocation) — the dry-run contract.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shapes (assigned): seq_len x global_batch
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "full"        # "full" | "sliding" | "mamba" | "cross"
    moe: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # moe|dense|ssm|vlm|audio|hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    layer_pattern: tuple[LayerSpec, ...] = ()
    # attention
    sliding_window: int = 4096
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    attn_chunk: int = 1024            # online-softmax KV chunk for long seq
    dense_attn_max_seq: int = 2048    # above this, use chunked attention
    # (keeps the (S, S) fp32 score tensor out of HBM for the 4k train cells;
    # the chunked path's masked-chunk compute waste is a perf-pass item)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    expert_pad_multiple: int = 16     # pad E so expert dims shard (e.g. 40->48)
    head_pad_multiple: int = 16       # pad q heads so attention shards (40->48)
    # MLA (minicpm3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / jamba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # modality frontend stubs
    n_image_tokens: int = 0           # vlm: precomputed patch embeddings
    d_vision: int = 0
    n_codebooks: int = 0              # audio: EnCodec streams (frontend stub)
    frontend: str = "tokens"          # "tokens" | "embeds" | "tokens+vision"
    # numerics / training
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    act: str = "swiglu"
    vocab_pad_multiple: int = 256
    remat: str = "full"               # "none" | "full"
    optimizer: str = "adamw"
    fsdp: bool = False
    skip_shapes: tuple[str, ...] = ()  # e.g. ("long_500k",) for full-attn

    def __post_init__(self):
        if not self.layer_pattern:
            object.__setattr__(
                self, "layer_pattern", tuple(LayerSpec() for _ in range(self.n_layers))
            )
        assert len(self.layer_pattern) == self.n_layers

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def padded_heads(self) -> int:
        """Query heads padded to a model-axis-shardable multiple; dummy head
        outputs are masked, so the function computed is the true-head model.
        Must stay a multiple of n_kv_heads for the flat-head KV expand."""
        m = self.head_pad_multiple
        hp = -(-self.n_heads // m) * m
        while hp % max(self.n_kv_heads, 1):
            hp += m
        return hp

    @property
    def padded_experts(self) -> int:
        m = self.expert_pad_multiple
        return -(-self.n_experts // m) * m if self.n_experts else 0

    @property
    def d_inner(self) -> int:          # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def group_size(self) -> int:       # GQA group
        return self.n_heads // max(self.n_kv_heads, 1)

    def runnable_shapes(self) -> list[str]:
        return [s for s in SHAPES if s not in self.skip_shapes]

    # --- parameter count (for MODEL_FLOPS = 6 N D) ----------------------
    def param_count(self, active_only: bool = False) -> int:
        n = 0
        emb = self.padded_vocab * self.d_model
        if self.frontend != "embeds":
            n += emb                      # token embedding
        n += emb                          # lm head
        if self.frontend == "tokens+vision":
            n += self.d_vision * self.d_model
        for spec in self.layer_pattern:
            if spec.kind == "mamba":
                di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                n += self.d_model * (2 * di + 2 * N + H)   # in_proj(x,z,B,C,dt)
                n += self.ssm_conv * (di + 2 * N)          # depthwise conv
                n += H + H                                  # A_log, D skip
                n += di * self.d_model                      # out_proj
            elif self.use_mla:
                qd = self.qk_nope_dim + self.qk_rope_dim
                n += self.d_model * self.q_lora_rank
                n += self.q_lora_rank * self.n_heads * qd
                n += self.d_model * (self.kv_lora_rank + self.qk_rope_dim)
                n += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.v_head_dim
                )
                n += self.n_heads * self.v_head_dim * self.d_model
            else:
                n += self.d_model * self.n_heads * self.d_head      # q
                n += 2 * self.d_model * self.n_kv_heads * self.d_head  # k,v
                n += self.n_heads * self.d_head * self.d_model      # o
            # mlp
            if spec.kind != "mamba" or True:
                if spec.moe:
                    k = self.top_k if active_only else self.n_experts
                    n += k * 3 * self.d_model * self.d_expert
                    n += self.d_model * self.n_experts    # router
                else:
                    n += 3 * self.d_model * self.d_ff
            n += 2 * self.d_model                          # norms
        return n


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for the dry-run
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: str) -> dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch x shape) cell. No allocation."""
    cell = SHAPES[shape]
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)

    if cell.kind in ("train", "prefill"):
        if cfg.frontend == "embeds":       # audio backbone: frame embeddings
            specs = {
                "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        else:
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if cfg.frontend == "tokens+vision":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_vision), f
            )
        return specs

    # decode: one new token + a pre-filled cache of S tokens (cache specs are
    # produced by models.cache.cache_specs and passed separately)
    specs = {"token": jax.ShapeDtypeStruct((B,), i32)}
    return specs


def batch_sample(cfg: ModelConfig, shape: str, key) -> dict[str, jax.Array]:
    """Materialized random batch (smoke tests / examples) — small shapes only."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(key, s.shape, 0, cfg.vocab, s.dtype)
        else:
            out[name] = jax.random.normal(key, s.shape, s.dtype) * 0.02
    return out
