"""mamba2-370m [ssm] — 48L d_model=1024 (attn-free) d_ff=0 vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060]
expand=2 -> d_inner 2048, head_dim 64 -> 32 heads. No MLP (d_ff=0).
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=32, n_kv_heads=32, d_head=32,
        d_ff=0, vocab=50280,
        layer_pattern=tuple(LayerSpec("mamba") for _ in range(48)),
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
        ssm_chunk=256,
        # runs long_500k: O(1) recurrent state.
    )
