"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144,
5:1 local:global sliding window, 128k context. [hf:google/gemma-3-1b-pt]
Pattern: (5 sliding + 1 full) x 4 + 2 sliding tail; window 512.
head_dim 256 (gemma3 uses wide heads: q width 1024 != d_model, fine).
"""
from .base import LayerSpec, ModelConfig


def _pattern(n):
    out = []
    for i in range(n):
        out.append(LayerSpec("full" if i % 6 == 5 else "sliding"))
    return tuple(out)


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_head=256,
        d_ff=6912, vocab=262144,
        layer_pattern=_pattern(26), sliding_window=512,
        rope_theta=1_000_000.0,
        # runs long_500k: 5/6 of layers are O(window); the global layers
        # attend to a ("data","model")-sharded cache.
    )
