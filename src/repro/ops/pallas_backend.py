"""Fused Pallas ``KernelOps`` backend (TPU target; interpret mode elsewhere).

* ``sweep`` — routed by the VMEM planner (``repro.ops.base.plan_sweep``):

  - ``fused``     — ONE Pallas pass per CG iteration. Each (block_m x
    block_n) Gram tile is computed once in VMEM, used for the forward
    product ``t = K u (+ v)`` and re-read from the VMEM row strip for the
    transposed accumulation ``w += K^T t`` into an fp32 scratch — half the
    kernel-tile evaluations and HBM round-trips of the two-matmul
    composition. Requires the (bm, Mpad) strip + (Mpad, p) accumulator to
    fit the VMEM budget, which caps M near ~8k at default tiles.
  - ``two_pass`` / ``j_sharded`` — the out-of-core schedule
    (``sharded_sweep_pallas``): forward pass spills ``t = K u + v`` to HBM,
    then per-C-shard transposed passes accumulate ``w_j`` with O(tile) VMEM,
    scaling M to 10^5+ at the cost of 2 Gram evaluations per tile. Falling
    off the fused path emits a structured ``SweepPlanWarning`` naming the
    chosen path and the budget numbers; ``plan()`` exposes the decision.

* ``apply`` / ``gram`` — thin wrappers over the kernel-matmul and pairwise
  Pallas kernels.

With ``precision="bf16"`` the data operands (X, C) are cast to bfloat16 before
entering the bandwidth-bound kernels (``sweep``/``apply``); the
distance/contraction matmuls then feed the MXU bf16 inputs with
``preferred_element_type=float32`` (bf16-in/fp32-accumulate). Coefficients,
v, outputs — and the one-shot ``gram`` feeding the Cholesky — stay full
precision.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from .base import OpsBase, SweepPlan, SweepPlanWarning, plan_sweep, register_ops

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@register_ops("pallas")
@dataclasses.dataclass(frozen=True)
class PallasKernelOps(OpsBase):
    """KernelOps over the fused Pallas kernels, keyed by the kernel's spec."""

    @property
    def _spec(self):
        from repro.core.kernels import spec_of
        return spec_of(self.kernel)

    @property
    def _block_m(self) -> int:
        return min(self.block_size, 256)

    def _inputs(self, X: Array, C: Array) -> tuple[Array, Array]:
        if self.precision == "bf16":
            return X.astype(jnp.bfloat16), C.astype(jnp.bfloat16)
        return X, C

    def plan(self, n: int, M: int, d: int, p: int = 1) -> SweepPlan:
        """The routing decision ``sweep`` will take for these shapes.

        The same VMEM budget model applies in interpret mode: Python
        emulation has no hard VMEM ceiling, but letting the fused kernel
        allocate a (bm, Mpad) strip at M ~ 10^5 is exactly the
        out-of-memory blowup the j-sharded path exists to avoid, and CPU
        tests should exercise the routing real TPUs will use.
        """
        from repro.kernels.kernel_matvec import sweep_block_dims
        bm, bn = sweep_block_dims(n, M, self._block_m, 512)
        return plan_sweep(n, M, d, p, bm=bm, bn=bn,
                          itemsize=2 if self.precision == "bf16" else 4)

    def sweep(self, X: Array, C: Array, u: Array, v: Array | None = None) -> Array:
        from repro.kernels.kernel_matvec import (fused_sweep_pallas,
                                                 sharded_sweep_pallas)
        X, C = self._inputs(X, C)
        p = u.shape[1] if u.ndim > 1 else 1
        plan = self.plan(X.shape[0], C.shape[0], X.shape[1], p)
        if plan.path == "fused":
            return fused_sweep_pallas(X, C, u, v, spec=self._spec,
                                      block_m=self._block_m,
                                      interpret=_interpret())
        warnings.warn(SweepPlanWarning(plan), stacklevel=2)
        return sharded_sweep_pallas(
            X, C, u, v, spec=self._spec,
            shard_m=plan.shard_m if plan.shard_m is not None else plan.M,
            block_m=self._block_m, interpret=_interpret())

    def sweep_with_stats(self, X: Array, C: Array, u: Array,
                         v: Array | None = None) -> tuple[Array, Array]:
        """sweep() plus the kernel's Gram-tile evaluation counter (int32).

        The counter is the fusion proof: it equals
        ceil(n/block_m) * ceil(M/block_n) — one evaluation per tile per call.
        Diagnostic path: it is always the fused kernel, so shapes the planner
        would route to an out-of-core path are rejected here rather than
        silently measuring a different implementation.
        """
        from repro.kernels.kernel_matvec import fused_sweep_pallas
        X, C = self._inputs(X, C)
        p = u.shape[1] if u.ndim > 1 else 1
        plan = self.plan(X.shape[0], C.shape[0], X.shape[1], p)
        if plan.path != "fused":
            raise ValueError(
                f"fused sweep scratch for n={X.shape[0]}, M={C.shape[0]}, "
                f"d={X.shape[1]}, p={p} exceeds the VMEM budget on this "
                f"backend ({plan.reason}); sweep() would take the "
                f"{plan.path!r} path, which has no tile counter")
        return fused_sweep_pallas(X, C, u, v, spec=self._spec,
                                  block_m=self._block_m,
                                  interpret=_interpret(),
                                  return_tile_count=True)

    def apply(self, X: Array, C: Array, u: Array) -> Array:
        from repro.kernels.kernel_matvec import kernel_matmul_pallas
        X, C = self._inputs(X, C)
        squeeze = u.ndim == 1
        u2 = u[:, None] if squeeze else u
        out = kernel_matmul_pallas(X, C, u2, spec=self._spec,
                                   block_m=self._block_m,
                                   interpret=_interpret())
        return out[:, 0] if squeeze else out

    def gram(self, A: Array, B: Array) -> Array:
        # Full precision regardless of the bf16 policy: gram feeds the
        # preconditioner's Cholesky (one-shot O(M^2) work with no bandwidth
        # win to harvest), and bf16 quantization can push a borderline-PSD
        # K_MM indefinite.
        from repro.kernels.kernel_matvec import pairwise_kernel_pallas
        return pairwise_kernel_pallas(A, B, spec=self._spec,
                                      interpret=_interpret())
