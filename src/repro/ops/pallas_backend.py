"""Fused Pallas ``KernelOps`` backend (TPU target; interpret mode elsewhere).

* ``sweep`` — routed by the VMEM planner (``repro.ops.base.plan_sweep``):

  - ``fused``     — ONE Pallas pass per CG iteration. Each (block_m x
    block_n) Gram tile is computed once in VMEM, used for the forward
    product ``t = K u (+ v)`` and re-read from the VMEM row strip for the
    transposed accumulation ``w += K^T t`` into an fp32 scratch — half the
    kernel-tile evaluations and HBM round-trips of the two-matmul
    composition. Requires the (bm, Mpad) strip + (Mpad, p) accumulator to
    fit the VMEM budget, which caps M near ~8k at default tiles.
  - ``two_pass`` / ``j_sharded`` — the out-of-core schedule
    (``sharded_sweep_pallas``): forward pass spills ``t = K u + v`` to HBM,
    then per-C-shard transposed passes accumulate ``w_j`` with O(tile) VMEM,
    scaling M to 10^5+ at the cost of 2 Gram evaluations per tile. Falling
    off the fused path emits a structured ``SweepPlanWarning`` naming the
    chosen path and the budget numbers; ``plan()`` exposes the decision.

* ``apply`` / ``gram`` — thin wrappers over the kernel-matmul and pairwise
  Pallas kernels.

With ``precision="bf16"`` (or any custom :class:`PrecisionPolicy`) the policy
is END-TO-END over the data-space buffers: X, C and the v term are cast to
the storage dtype before entering the bandwidth-bound kernels, and the
j-sharded path's HBM-spilled ``t`` moves at storage width — the full 2x
HBM-footprint/bandwidth win (the sweep's traffic is dominated by these
n-sized objects). The distance/contraction matmuls feed the MXU
storage-dtype inputs with ``preferred_element_type=float32`` and, when the
policy says ``compensated``, every tile-loop reduction runs through
Kahan/two-sum carry buffers (see ``repro.kernels.kernel_matvec``).
Per-buffer overrides keep the M-sized coefficient vectors at the sweep
boundary (u in, w out) and the one-shot ``gram`` feeding the Cholesky in
float32 — see ``PrecisionPolicy`` for why quantizing those is not safe.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp

from .base import OpsBase, SweepPlan, SweepPlanWarning, plan_sweep, register_ops
from .gemm import GemmCacheMixin

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@register_ops("pallas")
@dataclasses.dataclass(frozen=True)
class PallasKernelOps(GemmCacheMixin, OpsBase):
    """KernelOps over the fused Pallas kernels, keyed by the kernel's spec.

    The K_nM-cache primitives (materialize / gemm_sweep / gemm_apply) come
    from the shared ``GemmCacheMixin``: after materialization (one
    ``pairwise_kernel_pallas`` evaluation per row tile) there is no kernel
    math left, only GEMMs, and XLA's native matmuls are the right tool —
    a fused Pallas GEMM would re-solve a solved problem.
    """

    @property
    def _spec(self):
        from repro.core.kernels import spec_of
        return spec_of(self.kernel)

    @property
    def _block_m(self) -> int:
        return min(self.block_size, 256)

    def _inputs(self, X: Array, C: Array) -> tuple[Array, Array]:
        # storage == float32 means "full precision": leave inputs untouched
        # (x64 callers keep their float64), exactly the pre-policy behavior.
        if self.policy.storage == "float32":
            return X, C
        st = jnp.dtype(self.policy.storage)
        return X.astype(st), C.astype(st)

    def _vectors(self, u: Array, v: Array | None) -> tuple[Array, Array | None]:
        """u at the policy's coefficient dtype (float32 by override — see
        PrecisionPolicy: quantized coefficients destabilize preconditioned
        CG), v at data-space storage width (n-sized, the HBM win)."""
        pol = self.policy
        if pol.storage != "float32" and v is not None:
            v = v.astype(jnp.dtype(pol.storage))
        co_name = pol.buffer_dtype("coeffs")
        co = jnp.dtype(co_name)
        if u.dtype != co and (
            co_name != "float32" or jnp.dtype(u.dtype).itemsize < co.itemsize
        ):
            # the override WIDENS any reduced-storage u (bf16/fp16/fp8 CG
            # iterates crossing back into the sweep) — never narrows an
            # fp64 u under the default float32 coeffs (x64 callers)
            u = u.astype(co)
        return u, v

    def plan(self, n: int, M: int, d: int, p: int = 1, systems: int = 1) -> SweepPlan:
        """The routing decision ``sweep`` will take for these shapes.

        The same VMEM budget model applies in interpret mode: Python
        emulation has no hard VMEM ceiling, but letting the fused kernel
        allocate a (bm, Mpad) strip at M ~ 10^5 is exactly the
        out-of-memory blowup the j-sharded path exists to avoid, and CPU
        tests should exercise the routing real TPUs will use. ``systems``
        charges the lam-path stacking (effective width ``p * systems``) so
        a fat path routes off the fused path exactly like a fat multi-rhs.
        """
        from repro.kernels.kernel_matvec import sweep_block_dims
        bm, bn = sweep_block_dims(n, M, self._block_m, 512)
        return plan_sweep(n, M, d, p, systems=systems, bm=bm, bn=bn, policy=self.policy)

    def sweep(
        self,
        X: Array,
        C: Array,
        u: Array,
        v: Array | None = None,
        row_mask: Array | None = None,
    ) -> Array:
        """``row_mask`` (n,), 0/1: masked rows contribute EXACTLY zero (the
        fused kernel zeroes their t_i in VMEM; the sharded path zeroes the
        spilled t rows) — fixed-shape padded chunks sweep correctly."""
        from repro.kernels.kernel_matvec import (
            fused_sweep_pallas, sharded_sweep_pallas
        )
        pol = self.policy
        X, C = self._inputs(X, C)
        u, v = self._vectors(u, v)
        p = u.shape[1] if u.ndim > 1 else 1
        plan = self.plan(X.shape[0], C.shape[0], X.shape[1], p)
        if plan.path == "fused":
            return fused_sweep_pallas(
                X,
                C,
                u,
                v,
                spec=self._spec,
                row_mask=row_mask,
                block_m=self._block_m,
                compensated=pol.compensated,
                interpret=_interpret(),
            )
        warnings.warn(SweepPlanWarning(plan), stacklevel=2)
        # reduced-storage policies pin the HBM t spill to storage width and
        # the final M-sized w to the coefficient dtype; the fp32 policy
        # keeps the legacy promotion (None) so x64 callers stay fp64
        t_dt = out_dt = None
        if pol.storage != "float32":
            t_dt = jnp.dtype(pol.storage)
            out_dt = jnp.dtype(pol.buffer_dtype("coeffs"))
        return sharded_sweep_pallas(
            X,
            C,
            u,
            v,
            spec=self._spec,
            row_mask=row_mask,
            shard_m=plan.shard_m if plan.shard_m is not None else plan.M,
            block_m=self._block_m,
            compensated=pol.compensated,
            t_dtype=t_dt,
            out_dtype=out_dt,
            interpret=_interpret(),
        )

    def sweep_with_stats(
        self, X: Array, C: Array, u: Array, v: Array | None = None
    ) -> tuple[Array, Array]:
        """sweep() plus the kernel's Gram-tile evaluation counter (int32).

        The counter is the fusion proof: it equals
        ceil(n/block_m) * ceil(M/block_n) — one evaluation per tile per call.
        Diagnostic path: it is always the fused kernel, so shapes the planner
        would route to an out-of-core path are rejected here rather than
        silently measuring a different implementation.
        """
        from repro.kernels.kernel_matvec import fused_sweep_pallas
        pol = self.policy
        X, C = self._inputs(X, C)
        u, v = self._vectors(u, v)
        p = u.shape[1] if u.ndim > 1 else 1
        plan = self.plan(X.shape[0], C.shape[0], X.shape[1], p)
        if plan.path != "fused":
            raise ValueError(
                f"fused sweep scratch for n={X.shape[0]}, M={C.shape[0]}, "
                f"d={X.shape[1]}, p={p} exceeds the VMEM budget on this "
                f"backend ({plan.reason}); sweep() would take the "
                f"{plan.path!r} path, which has no tile counter")
        return fused_sweep_pallas(
            X,
            C,
            u,
            v,
            spec=self._spec,
            block_m=self._block_m,
            compensated=pol.compensated,
            interpret=_interpret(),
            return_tile_count=True,
        )

    def apply(self, X: Array, C: Array, u: Array) -> Array:
        from repro.kernels.kernel_matvec import kernel_matmul_pallas
        pol = self.policy
        X, C = self._inputs(X, C)
        u, _ = self._vectors(u, None)
        squeeze = u.ndim == 1
        u2 = u[:, None] if squeeze else u
        out = kernel_matmul_pallas(
            X,
            C,
            u2,
            spec=self._spec,
            block_m=self._block_m,
            compensated=pol.compensated,
            interpret=_interpret(),
        )
        return out[:, 0] if squeeze else out

    def gram(self, A: Array, B: Array) -> Array:
        # Per-buffer override (default float32 regardless of the bf16
        # policy): gram feeds the preconditioner's Cholesky (one-shot O(M^2)
        # work with no bandwidth win to harvest), and bf16 quantization can
        # push a borderline-PSD K_MM indefinite.
        from repro.kernels.kernel_matvec import pairwise_kernel_pallas
        gt = jnp.dtype(self.policy.buffer_dtype("gram"))
        if jnp.dtype(A.dtype).itemsize < gt.itemsize:   # never downcast fp64
            A = A.astype(gt)
        if jnp.dtype(B.dtype).itemsize < gt.itemsize:
            B = B.astype(gt)
        return pairwise_kernel_pallas(A, B, spec=self._spec, interpret=_interpret())
