"""Fused Pallas ``KernelOps`` backend (TPU target; interpret mode elsewhere).

* ``sweep`` — the headline kernel: ONE Pallas pass per CG iteration. Each
  (block_m x block_n) Gram tile is computed once in VMEM, used for the forward
  product ``t = K u (+ v)`` and re-read from the VMEM row strip for the
  transposed accumulation ``w += K^T t`` into an fp32 scratch — half the
  kernel-tile evaluations and HBM round-trips of the two-matmul composition.
* ``apply`` / ``gram`` — thin wrappers over the kernel-matmul and pairwise
  Pallas kernels.

With ``precision="bf16"`` the data operands (X, C) are cast to bfloat16 before
entering the bandwidth-bound kernels (``sweep``/``apply``); the
distance/contraction matmuls then feed the MXU bf16 inputs with
``preferred_element_type=float32`` (bf16-in/fp32-accumulate). Coefficients,
v, outputs — and the one-shot ``gram`` feeding the Cholesky — stay full
precision.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import OpsBase, register_ops

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@register_ops("pallas")
@dataclasses.dataclass(frozen=True)
class PallasKernelOps(OpsBase):
    """KernelOps over the fused Pallas kernels, keyed by the kernel's spec."""

    @property
    def _spec(self):
        from repro.core.kernels import spec_of
        return spec_of(self.kernel)

    @property
    def _block_m(self) -> int:
        return min(self.block_size, 256)

    def _inputs(self, X: Array, C: Array) -> tuple[Array, Array]:
        if self.precision == "bf16":
            return X.astype(jnp.bfloat16), C.astype(jnp.bfloat16)
        return X, C

    def _fused_fits_vmem(self, n: int, M: int, d: int, p: int) -> bool:
        """The fused sweep keeps the Gram row strip and the (M, p) accumulator
        VMEM-resident: scratch ~ (bm * Mpad + Mpad * pp * 2) fp32, on top of
        the double-buffered (bm, dp)/(bn, dp) input tiles. Past ~16MB of VMEM
        that fails to compile on real TPUs, so fall back to the two-pass
        composition there (interpret mode has no such limit)."""
        if _interpret():
            return True
        from repro.kernels.kernel_matvec import sweep_block_dims
        lane = 128
        Mpad = -(-M // lane) * lane
        dp = -(-d // lane) * lane
        pp = -(-max(p, 1) // lane) * lane
        bm, bn = sweep_block_dims(n, M, self._block_m, 512)
        itemsize = 2 if self.precision == "bf16" else 4
        scratch_bytes = 4 * (bm * Mpad + 2 * Mpad * pp + bm * pp)
        # inputs/outputs are pipelined double-buffered: X_i, C_j, u_j, v_i
        io_bytes = 2 * (itemsize * (bm + bn) * dp + 4 * (bn + bm) * pp)
        return scratch_bytes + io_bytes <= 12 * 2**20

    def sweep(self, X: Array, C: Array, u: Array, v: Array | None = None) -> Array:
        from repro.kernels.kernel_matvec import fused_sweep_pallas
        from repro.kernels.ops import two_pass_knm_matvec
        X, C = self._inputs(X, C)
        p = u.shape[1] if u.ndim > 1 else 1
        if not self._fused_fits_vmem(X.shape[0], C.shape[0], X.shape[1], p):
            return two_pass_knm_matvec(X, C, u, v, self.kernel,
                                       block_size=self.block_size)
        return fused_sweep_pallas(X, C, u, v, spec=self._spec,
                                  block_m=self._block_m,
                                  interpret=_interpret())

    def sweep_with_stats(self, X: Array, C: Array, u: Array,
                         v: Array | None = None) -> tuple[Array, Array]:
        """sweep() plus the kernel's Gram-tile evaluation counter (int32).

        The counter is the fusion proof: it equals
        ceil(n/block_m) * ceil(M/block_n) — one evaluation per tile per call.
        Diagnostic path: it is always the fused kernel, so shapes the VMEM
        guard would route to the two-pass fallback are rejected here rather
        than silently measuring a different implementation.
        """
        from repro.kernels.kernel_matvec import fused_sweep_pallas
        X, C = self._inputs(X, C)
        p = u.shape[1] if u.ndim > 1 else 1
        if not self._fused_fits_vmem(X.shape[0], C.shape[0], X.shape[1], p):
            raise ValueError(
                f"fused sweep scratch for n={X.shape[0]}, M={C.shape[0]}, "
                f"d={X.shape[1]}, p={p} exceeds the VMEM budget on this "
                "backend; sweep() would fall back to the two-pass path, "
                "which has no tile counter")
        return fused_sweep_pallas(X, C, u, v, spec=self._spec,
                                  block_m=self._block_m,
                                  interpret=_interpret(),
                                  return_tile_count=True)

    def apply(self, X: Array, C: Array, u: Array) -> Array:
        from repro.kernels.kernel_matvec import kernel_matmul_pallas
        X, C = self._inputs(X, C)
        squeeze = u.ndim == 1
        u2 = u[:, None] if squeeze else u
        out = kernel_matmul_pallas(X, C, u2, spec=self._spec,
                                   block_m=self._block_m,
                                   interpret=_interpret())
        return out[:, 0] if squeeze else out

    def gram(self, A: Array, B: Array) -> Array:
        # Full precision regardless of the bf16 policy: gram feeds the
        # preconditioner's Cholesky (one-shot O(M^2) work with no bandwidth
        # win to harvest), and bf16 quantization can push a borderline-PSD
        # K_MM indefinite.
        from repro.kernels.kernel_matvec import pairwise_kernel_pallas
        return pairwise_kernel_pallas(A, B, spec=self._spec,
                                      interpret=_interpret())
