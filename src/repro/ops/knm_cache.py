"""Materialized K_nM cache: evaluate kernel entries once, run CG on GEMMs.

FALKON's O(n sqrt(n)) time is dominated by re-evaluating all n*M kernel
entries of K_nM on EVERY CG iteration — the paper's cost model counts one
full kernel pass per sweep, so a fit at t iterations pays for the same
entries ~2(t+1) times across the RHS and matvec forms. A
:class:`KernelCache` evaluates each (block_size, M) row tile exactly once
(``ops.materialize`` -> ``ops.gram`` per tile), stores the entries at the
precision policy's STORAGE dtype (bf16 => half footprint — the cache
composes with the precision work), and serves every subsequent sweep/apply
as pure GEMMs with fp32 accumulation (``ops.gemm_sweep``/``gemm_apply``,
see ``repro.ops.gemm`` for the parity contract: fp32 cached == recompute
bit-identically on the jnp backend).

Residency is a :func:`~repro.ops.base.plan_cache` decision (the
``plan_sweep``/``plan_factor`` sibling, budgets ``REPRO_KNM_BUDGET_MB`` /
``REPRO_KNM_HOST_BUDGET_MB``):

* ``device`` — K lives in HBM; sweeps are two GEMMs, zero kernel math.
* ``host``   — tiles are pinned host-side (numpy) and streamed through the
  double-buffered :class:`~repro.data.streaming.StreamingLoader` (the SAME
  machinery the out-of-core X fits use — a K tile is just a (rows, M)
  chunk), with per-tile jitted GEMMs and fp32 cross-tile accumulation.
* ``off``    — no cache is built; callers fall back to the recompute path,
  bit-identical to a build without this module.

Staleness: the cache pins the EXACT centers array it was built against
(identity, not value — comparing M x d arrays per call would defeat the
O(M) serving point). ``check_serves`` refuses a cache whose centers are
not the serving model's centers object or that was explicitly
``invalidate()``-d — the seam ``swap_model`` uses so a stale cache cannot
serve a swapped model.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .base import CachePlan, plan_cache


def data_shards(ops) -> int:
    """Row-shard count behind an ops facade chain (1 when not distributed).

    Walks ``.inner`` / ``.ops`` (the facade conventions ``CountingOps`` /
    ``DistributedOps`` / ``JittedOps`` use) looking for a ``num_shards`` —
    what :func:`~repro.ops.base.plan_cache` charges the per-shard budget
    with, and how the cache refuses the host tier under sharding.
    """
    seen: set[int] = set()
    o = ops
    while o is not None and id(o) not in seen:
        seen.add(id(o))
        ns = getattr(o, "num_shards", None)
        if ns is not None:
            return int(ns)
        o = getattr(o, "inner", None) or getattr(o, "ops", None)
    return 1


class KernelCache:
    """One materialized K(X, C), served as GEMM sweeps/applies.

    Built once per fit (or per repeated-scoring set) and shared across the
    RHS sweep, every CG iteration, the ``estimate_cond`` power-iteration
    diagnostics, and all L lam-path systems — they all consume the same
    stored entries. ``plan`` defaults to the auto-routed
    :func:`~repro.ops.base.plan_cache`; pass a forced-tier plan to pin
    residency (tests, benchmarks). A plan whose tier is ``"off"`` is
    refused — the caller owns the decision not to build a cache.
    """

    def __init__(self, ops, X, C, *, plan: CachePlan | None = None,
                 prefetch: int | None = None):
        n, M = int(X.shape[0]), int(C.shape[0])
        if plan is None:
            plan = plan_cache(n, M, policy=ops.policy)
        if plan.tier == "off":
            raise ValueError(
                f"refusing to build a KernelCache from an 'off'-tier plan "
                f"({plan.reason}); the caller should take the recompute path")
        if plan.tier == "host" and data_shards(ops) > 1:
            raise ValueError(
                "host-tier K_nM cache is not supported under DistributedOps "
                "— each shard's local block is 1/shards the size, so either "
                "it fits HBM (device tier) or the fit should run recompute "
                "(tier 'off')")
        self.ops = ops
        self.X = X            # identity only: which rows the tiles cover
        self.C = C
        self.n = n
        self.M = M
        self.plan = plan
        self._invalidated = False
        if plan.tier == "device":
            self.K = ops.materialize(X, C)
            self._loader = None
            # the backend owns the padded row count (a DistributedOps pads
            # to a multiple of shards * block_size, not just block_size)
            self.n_pad = int(self.K.shape[0])
        else:
            self._build_host(ops, X, C)   # sets n_pad / K_host / loader
        # pad-row mask folded into every sweep: pad rows contribute EXACTLY
        # zero, the same contract the recompute sweep's internal padding has
        self._pad_mask = (jnp.arange(self.n_pad) < n).astype(jnp.float32)

    # -- construction ------------------------------------------------------
    def _build_host(self, ops, X, C) -> None:
        """Materialize into pinned host memory, slab by slab, and stand up
        the double-buffered tile loader the streamed sweeps replay."""
        from repro.data.streaming import (
            ArrayChunkSource, StreamingLoader, default_prefetch
        )

        import jax

        bs = ops.block_size
        self.n_pad = -(-self.n // bs) * bs
        host = None
        # slabs of up to 8 tiles bound the transient device residency of
        # the build to O(slab * M), independent of n; slab starts are tile
        # multiples, so the per-slab materialize padding lands exactly on
        # the global tile grid (row i of host == row i of the padded X)
        slab = 8 * bs
        for i0 in range(0, self.n_pad, slab):
            i1 = min(i0 + slab, self.n_pad)
            Ks = np.asarray(ops.materialize(X[i0:min(i1, self.n)], C))
            if host is None:
                # Ks already carries the policy storage dtype (numpy sees
                # bfloat16 through ml_dtypes)
                host = np.empty((self.n_pad, self.M), Ks.dtype)
            host[i0:i0 + Ks.shape[0]] = Ks
        self.K_host = host
        self._tile_rows = bs
        self._loader = StreamingLoader(
            ArrayChunkSource(host, chunk_rows=bs),
            prefetch=default_prefetch(),
        )
        self.K = None
        # per-tile GEMMs are jitted once (every tile shares one shape, so
        # one compile per sweep form per fit — the JittedOps convention;
        # a CountingOps underneath counts compiles, not tile calls)
        self._jit_gemm_sweep = jax.jit(ops.gemm_sweep)
        self._jit_gemm_apply = jax.jit(ops.gemm_apply)

    # -- staleness ---------------------------------------------------------
    def invalidate(self) -> None:
        """Mark the cache unusable (the model behind it was swapped)."""
        self._invalidated = True

    def matches(self, centers) -> bool:
        """True iff this cache serves exactly ``centers`` (identity check)."""
        return (not self._invalidated) and centers is self.C

    def check_serves(self, centers, n: int | None = None, X=None) -> None:
        """Refuse to serve a swapped/foreign model or a mismatched row set."""
        if self._invalidated:
            raise ValueError(
                "stale KernelCache: the model behind it was swapped "
                "(invalidate() was called); rebuild the cache against the "
                "new centers")
        if centers is not self.C:
            raise ValueError(
                "KernelCache was built against a different centers array "
                "(identity check); a cache cannot serve a swapped model — "
                "rebuild it")
        if n is not None and n != self.n:
            raise ValueError(
                f"KernelCache covers {self.n} rows but the request has {n}")
        if X is not None and X is not self.X:
            raise ValueError(
                "KernelCache was built over a different X (identity check); "
                "its stored tiles are K(X_cache, C), not K of this scoring "
                "set — rebuild the cache for the new rows")

    # -- served primitives -------------------------------------------------
    def _mask(self, row_mask):
        if row_mask is None:
            # aligned cache (n == n_pad, no caller mask): no rows to zero,
            # and gemm_sweep's no-mask fast path skips a full pass over the
            # stored entries (x * 1.0 is exact — results are unchanged)
            return None if self.n_pad == self.n else self._pad_mask
        m = row_mask.astype(jnp.float32)
        return jnp.pad(m, (0, self.n_pad - self.n)) * self._pad_mask

    def _pad_v(self, v):
        if v is None:
            return None
        widths = ((0, self.n_pad - self.n),) + ((0, 0),) * (v.ndim - 1)
        return jnp.pad(v, widths)

    def sweep(self, u, v=None, row_mask=None):
        """K^T (K u + v) from stored entries — drop-in for
        ``ops.sweep(X, C, u, v, row_mask)`` over the cached rows."""
        mask = self._mask(row_mask)
        vp = self._pad_v(v)
        if self._loader is None:
            return self.ops.gemm_sweep(self.K, u, vp, mask)
        return self._host_sweep(u, vp, mask)

    def apply(self, u):
        """K u from stored entries — drop-in for ``ops.apply(X, C, u)``."""
        if self._loader is None:
            return self.ops.gemm_apply(self.K, u)[:self.n]
        outs = [self._jit_gemm_apply(Kt, u)
                for Kt, _ in self._loader.iter_chunks(with_targets=False)]
        out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
        return out[:self.n]

    def _host_sweep(self, u, vp, mask):
        """One streamed pass over the host tiles, fp32 accumulation across
        tiles (the ``streaming_sweep`` contract: reduced-storage per-tile
        results widen before the cross-tile sum)."""
        tr = self._tile_rows
        w = None
        out_dtype = None
        for i, (Kt, _) in enumerate(
            self._loader.iter_chunks(with_targets=False)
        ):
            i0 = i * tr
            vt = None if vp is None else vp[i0:i0 + tr]
            mt = None if mask is None else mask[i0:i0 + tr]
            wc = self._jit_gemm_sweep(Kt, u, vt, mt)
            if out_dtype is None:
                out_dtype = wc.dtype
            if jnp.dtype(out_dtype).itemsize < 4:
                wc = wc.astype(jnp.float32)
            w = wc if w is None else w + wc
        return w.astype(out_dtype)

    # -- introspection -----------------------------------------------------
    @property
    def tier(self) -> str:
        return self.plan.tier

    @property
    def num_tiles(self) -> int:
        """ceil(n / block_size) — the exact ``gram_tile_evals`` a cached
        fit charges for K_nM (the one-eval-per-tile acceptance number)."""
        return self.n_pad // self.ops.block_size
