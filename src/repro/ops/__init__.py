"""Pluggable kernel-op backends (the ``KernelOps`` layer).

    from repro.ops import get_ops
    ops = get_ops("pallas", kernel, block_size=2048, precision="bf16")
    w   = ops.sweep(X, C, u, v)     # K^T (K u + v)  — one CG iteration
    yh  = ops.apply(Xte, C, alpha)  # K u            — prediction
    KMM = ops.gram(C, C)            # K(A, B)        — preconditioner

See ``base.py`` for the protocol/registry, ``jnp_backend.py`` for the
reference implementation and ``pallas_backend.py`` for the fused TPU path.
"""
from .base import (
    CountingOps,
    FACTOR_PATHS,
    FactorPlan,
    FactorPlanWarning,
    KernelOps,
    OpsBase,
    POLICIES,
    PRECISIONS,
    PrecisionPolicy,
    SWEEP_PATHS,
    SweepPlan,
    SweepPlanWarning,
    available_ops,
    get_ops,
    plan_factor,
    plan_sweep,
    register_ops,
    resolve_precision,
)
from . import jnp_backend as _jnp_backend    # noqa: F401  (registers "jnp")
from . import pallas_backend as _pallas_backend  # noqa: F401  ("pallas")
from .distributed_backend import DistributedOps

__all__ = [
    "CountingOps",
    "DistributedOps",
    "FACTOR_PATHS",
    "FactorPlan",
    "FactorPlanWarning",
    "KernelOps",
    "OpsBase",
    "POLICIES",
    "PRECISIONS",
    "PrecisionPolicy",
    "SWEEP_PATHS",
    "SweepPlan",
    "SweepPlanWarning",
    "available_ops",
    "get_ops",
    "plan_factor",
    "plan_sweep",
    "register_ops",
    "resolve_precision",
]
