"""Pluggable kernel-op backends (the ``KernelOps`` layer).

    from repro.ops import get_ops
    ops = get_ops("pallas", kernel, block_size=2048, precision="bf16")
    w   = ops.sweep(X, C, u, v)     # K^T (K u + v)  — one CG iteration
    yh  = ops.apply(Xte, C, alpha)  # K u            — prediction
    KMM = ops.gram(C, C)            # K(A, B)        — preconditioner

Cache path (``plan_cache`` routes residency; ``KernelCache`` evaluates
each K_nM row tile once and serves sweeps/applies as GEMMs):

    cache = KernelCache(ops, X, C)  # one kernel pass, tiles stored
    w     = cache.sweep(u, v)       # pure GEMMs from then on

See ``base.py`` for the protocol/registry/planners, ``jnp_backend.py`` for
the reference implementation, ``pallas_backend.py`` for the fused TPU path,
``gemm.py`` for the shared materialize/GEMM primitives and ``knm_cache.py``
for the cache itself.
"""
from .base import (
    CACHE_TIERS,
    CachePlan,
    CachePlanWarning,
    CountingOps,
    FACTOR_PATHS,
    FactorPlan,
    FactorPlanWarning,
    KernelOps,
    OpsBase,
    POLICIES,
    PRECISIONS,
    PrecisionPolicy,
    SWEEP_PATHS,
    SweepPlan,
    SweepPlanWarning,
    available_ops,
    get_ops,
    plan_cache,
    plan_factor,
    plan_sweep,
    register_ops,
    resolve_precision,
)
from . import jnp_backend as _jnp_backend    # noqa: F401  (registers "jnp")
from . import pallas_backend as _pallas_backend  # noqa: F401  ("pallas")
from .distributed_backend import DistributedOps
from .knm_cache import KernelCache, data_shards

__all__ = [
    "CACHE_TIERS",
    "CachePlan",
    "CachePlanWarning",
    "CountingOps",
    "DistributedOps",
    "FACTOR_PATHS",
    "FactorPlan",
    "FactorPlanWarning",
    "KernelCache",
    "KernelOps",
    "OpsBase",
    "POLICIES",
    "PRECISIONS",
    "PrecisionPolicy",
    "SWEEP_PATHS",
    "SweepPlan",
    "SweepPlanWarning",
    "available_ops",
    "data_shards",
    "get_ops",
    "plan_cache",
    "plan_factor",
    "plan_sweep",
    "register_ops",
    "resolve_precision",
]
