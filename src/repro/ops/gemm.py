"""Materialized-K_nM GEMM primitives shared by every backend.

The recompute sweep pays one full kernel evaluation of K_nM per CG
iteration. The :class:`~repro.ops.knm_cache.KernelCache` path instead calls
``materialize`` ONCE — each (block, M) row tile evaluated a single time via
the backend's ``gram`` — and serves every later sweep/apply as pure matmuls
over the stored entries:

    materialize(X, C) -> K        (n_pad, M) at the policy's STORAGE dtype
    gemm_sweep(K, u, v, mask)  =  (K*mask)^T ((K*mask) u + v*mask)
    gemm_apply(K, u)           =  K u        (caller slices [:n])

These are deliberately implemented ONCE here (``GemmCacheMixin``) and
inherited by both the jnp and Pallas backends: after materialization there
is no kernel math left — only GEMMs — so there is nothing backend-specific
to fuse, and XLA's native matmuls are the right tool on every platform.

Numerical contract (the cache's parity guarantees hang off this):

* ``gemm_sweep`` replays the jnp reference sweep's EXACT blocked
  ``lax.scan`` arithmetic — same (block_size, M) strips, same mask
  multiply, same accumulation order, same Kahan compensation under a
  ``compensated`` policy — over stored entries instead of freshly
  evaluated ones. Under the fp32 policy the stored entries ARE the
  entries the recompute sweep computes (``materialize`` quantizes X/C
  through the same storage round-trip before ``gram``), so cached and
  recompute sweeps are bit-identical on the jnp backend.
* Under a reduced-storage policy (bf16) the tiles are stored at storage
  width — the halved-footprint point of composing with the precision
  work — which adds ONE extra rounding of the kernel entries; every
  contraction still accumulates in float32 (widened inside the scan), so
  parity vs recompute stays within the policy tolerance.

Row-padding contract: ``materialize`` zero-pads X to a multiple of
``block_size`` (row i of K is row i of the padded X), and the GEMM calls
take operands already padded to ``K.shape[0]`` rows — the cache owner
(``KernelCache``) folds the pad mask into ``row_mask`` so pad rows
contribute exactly zero, the same contract the recompute sweep's internal
padding satisfies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_storage(policy, a: Array | None) -> Array | None:
    """Data-space storage quantization, fp32 compute — the jnp reference
    sweep's ``_quant``: round through the storage dtype, widen back for the
    contraction. float32 storage means full precision: pass through
    untouched (x64 callers keep their float64)."""
    if a is None or policy.storage == "float32":
        return a
    return a.astype(jnp.dtype(policy.storage)).astype(jnp.float32)


def quantize_coeffs(policy, u: Array) -> Array:
    """u at the policy's coefficient dtype (float32 by override; any
    reduced-storage u — bf16/fp16/fp8 CG iterates — is widened for compute;
    an fp64 u under float32 coeffs is never narrowed)."""
    co_name = policy.buffer_dtype("coeffs")
    co = jnp.dtype(co_name)
    if co_name != "float32":
        return u.astype(co).astype(jnp.float32)
    if jnp.dtype(u.dtype).itemsize < co.itemsize:
        return u.astype(jnp.float32)
    return u


def _compute_dtype(K: Array):
    """fp32 floor for the GEMM contraction; stored fp64 stays fp64."""
    dt = jnp.dtype(K.dtype)
    return dt if dt.itemsize >= 4 else jnp.dtype(jnp.float32)


class GemmCacheMixin:
    """The three cache primitives, shared by every concrete backend.

    Mixes into a frozen ``OpsBase`` dataclass: uses only ``self.kernel``,
    ``self.block_size``, ``self.policy`` and ``self.gram`` — no state.
    """

    def materialize(self, X: Array, C: Array) -> Array:
        """Evaluate K(X, C) once, blocked, at the policy's storage dtype.

        Returns (n_pad, M) with n_pad = ceil(n / block_size) * block_size;
        row i is row i of the zero-padded X (pad rows carry K(0, C) values
        — finite, and masked/sliced away by every consumer). Each row tile
        goes through ONE ``gram`` evaluation — the single kernel pass a
        cached fit performs, and what ``CountingOps.gram_tile_evals``
        charges.
        """
        pol = self.policy
        Xq = quantize_storage(pol, X)
        Cq = quantize_storage(pol, C)
        bs = self.block_size
        n = Xq.shape[0]
        nb = -(-n // bs)
        Xp = jnp.pad(Xq, ((0, nb * bs - n), (0, 0)))
        st = jnp.dtype(pol.storage)
        tiles = []
        for i in range(nb):
            Kt = self.gram(Xp[i * bs:(i + 1) * bs], Cq)
            # store at storage width (bf16 => half footprint); float32
            # storage keeps gram's full-precision output untouched
            tiles.append(Kt if pol.storage == "float32" else Kt.astype(st))
        return tiles[0] if nb == 1 else jnp.concatenate(tiles, axis=0)

    def gemm_sweep(
        self,
        K: Array,
        u: Array,
        v: Array | None = None,
        row_mask: Array | None = None,
    ) -> Array:
        """K^T (K u + v) over STORED entries — the cached CG iteration.

        ``K``: (rows, M) from ``materialize`` (rows % block_size == 0);
        ``v``/``row_mask`` must already be padded to ``rows`` (the cache
        folds its pad mask in). Replays the jnp reference sweep's blocked
        scan arithmetic exactly — fp32-stored entries give bit-identical
        results to the recompute sweep.
        """
        pol = self.policy
        bs = self.block_size
        rows, M = K.shape
        if rows % bs != 0:
            raise ValueError(
                f"cached K has {rows} rows, not a multiple of "
                f"block_size={bs} — materialize() pads; hand-built caches "
                f"must too")
        if v is not None and v.shape[0] != rows:
            raise ValueError(
                f"v has {v.shape[0]} rows but cached K has {rows}; pad v "
                f"(and mask the pad rows) to the cache's row count")
        u = quantize_coeffs(pol, u)
        v = quantize_storage(pol, v)
        cd = _compute_dtype(K)
        nb = rows // bs
        Kb = K.reshape(nb, bs, M)
        # No-mask fast path: a fully-aligned cache (no pad rows, no caller
        # mask) skips the mask multiply — a whole read+write pass over the
        # n x M entries, the dominant memory traffic of a served sweep.
        # Bit-identity survives because x * 1.0 is EXACT in IEEE: the
        # reference sweep's all-ones multiply returns bitwise-unchanged
        # entries, so dropping it feeds the same bits to the same matmuls.
        mb = None if row_mask is None else row_mask.astype(cd).reshape(nb, bs)
        out_shape = (M,) + u.shape[1:]
        if v is not None:
            vb = v.reshape((nb, bs) + v.shape[1:])

        def delta(inp):
            if v is None:
                if mb is None:
                    (kb,) = inp
                    Kf = kb.astype(cd)
                else:
                    kb, m = inp
                    Kf = kb.astype(cd) * m[:, None]
                t = Kf @ u
            elif mb is None:
                kb, vblk = inp
                Kf = kb.astype(cd)
                t = Kf @ u + vblk
            else:
                kb, m, vblk = inp
                Kf = kb.astype(cd) * m[:, None]
                t = Kf @ u + vblk * (m[:, None] if vblk.ndim > 1 else m)
            return Kf.T @ t

        if mb is None:
            xs = (Kb,) if v is None else (Kb, vb)
        else:
            xs = (Kb, mb) if v is None else (Kb, mb, vb)
        if pol.compensated:
            # identical cross-block Kahan to the recompute sweep (lazy
            # import: ops must not import kernels at module load)
            from repro.kernels.kernel_matvec import _two_sum

            def body(carry, inp):
                acc, comp = carry
                return _two_sum(acc, comp, delta(inp)), None

            init = (jnp.zeros(out_shape, cd), jnp.zeros(out_shape, cd))
            (w, _), _ = jax.lax.scan(body, init, xs)
        else:
            def body(carry, inp):
                return carry + delta(inp), None

            w, _ = jax.lax.scan(body, jnp.zeros(out_shape, cd), xs)
        co = pol.buffer_dtype("coeffs")
        return w.astype(jnp.dtype(co)) if co != "float32" else w

    def gemm_apply(self, K: Array, u: Array) -> Array:
        """K u over stored entries — the cached prediction path.

        Returns ALL ``K.shape[0]`` rows (pad rows included); the cache
        slices back to the valid n, mirroring the recompute ``apply``.
        """
        u = quantize_coeffs(self.policy, u)
        cd = _compute_dtype(K)
        bs = self.block_size
        rows, M = K.shape
        if rows % bs != 0:
            raise ValueError(
                f"cached K has {rows} rows, not a multiple of "
                f"block_size={bs}")
        Kb = K.reshape(rows // bs, bs, M)

        def body(kb):
            return kb.astype(cd) @ u

        out = jax.lax.map(body, Kb)
        return out.reshape((rows,) + u.shape[1:])
