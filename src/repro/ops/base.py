"""The ``KernelOps`` backend protocol and registry.

FALKON's entire O(n sqrt(n)) time budget reduces to three primitives over an
(n, d) dataset ``X``, (M, d) Nystrom centers ``C`` and coefficient vectors:

    sweep(X, C, u, v)  =  K(X,C)^T (K(X,C) u + v)    — one CG iteration
    apply(X, C, u)     =  K(X,C) u                    — the prediction path
    gram(A, B)         =  K(A, B)                     — the preconditioner path

A ``KernelOps`` backend implements exactly these three, parameterized by a
kernel object carrying a declarative ``KernelSpec`` (see
``repro.core.kernels``). Backends are selected by name from a registry:

    ops = get_ops("pallas", kernel, block_size=2048, precision="bf16")
    w = ops.sweep(X, C, u, v)

Registered implementations:

* ``"jnp"``    — pure-jnp blocked reference (lax.scan over row blocks); runs
                 anywhere, fp32/fp64, the numerical ground truth.
* ``"pallas"`` — fused TPU path: the sweep is ONE Pallas pass that computes
                 each Gram tile once (see ``repro.kernels.kernel_matvec``).

Everything above this layer (core/matvec.py, core/falkon.py, the distributed
shard_map wrapper, serving, benchmarks) talks to a KernelOps and never to a
concrete kernel implementation. This module deliberately has no imports from
``repro.core`` or ``repro.kernels`` so it can never participate in an import
cycle; backends duck-type the kernel via its ``spec`` attribute / call.

``precision`` is the input/accumulate policy of the hot loop:

* ``"fp32"`` (default) — inputs and accumulation in float32 (or float64
  under x64).
* ``"bf16"`` — X and C are quantized to bfloat16 before entering the
  bandwidth-bound ``sweep``/``apply`` (halving HBM traffic and feeding the
  MXU bf16 inputs); all contractions still accumulate in float32, and
  ``gram`` (the preconditioner's Cholesky input) stays full precision.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

PRECISIONS = ("fp32", "bf16")


@runtime_checkable
class KernelOps(Protocol):
    """The three primitives the whole codebase needs — and nothing else."""

    kernel: Any
    block_size: int
    precision: str

    def sweep(self, X, C, u, v=None):
        """K(X,C)^T (K(X,C) u + v); ``v=None`` means v == 0."""
        ...

    def apply(self, X, C, u):
        """K(X,C) u — the prediction path."""
        ...

    def gram(self, A, B):
        """K(A, B) materialized — the preconditioner path."""
        ...


_REGISTRY: dict[str, type] = {}


def register_ops(name: str):
    """Class decorator registering a KernelOps implementation under ``name``."""
    def deco(cls):
        cls.impl_name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_ops() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_ops(impl: str, kernel, *, block_size: int = 2048,
            precision: str = "fp32") -> KernelOps:
    """Construct the named backend for ``kernel``.

    ``kernel`` must carry a ``KernelSpec`` (anything built by
    ``repro.core.kernels.make_kernel`` / ``@register_kernel`` does).
    """
    if impl not in _REGISTRY:
        raise ValueError(
            f"unknown KernelOps impl {impl!r}; registered: {available_ops()}")
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; supported: {PRECISIONS}")
    return _REGISTRY[impl](kernel=kernel, block_size=block_size,
                           precision=precision)


@dataclasses.dataclass(frozen=True)
class OpsBase:
    """Shared constructor shape for backends (kernel + static knobs)."""

    kernel: Any
    block_size: int = 2048
    precision: str = "fp32"
