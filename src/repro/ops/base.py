"""The ``KernelOps`` backend protocol and registry.

FALKON's entire O(n sqrt(n)) time budget reduces to three primitives over an
(n, d) dataset ``X``, (M, d) Nystrom centers ``C`` and coefficient vectors:

    sweep(X, C, u, v)  =  K(X,C)^T (K(X,C) u + v)    — one CG iteration
    apply(X, C, u)     =  K(X,C) u                    — the prediction path
    gram(A, B)         =  K(A, B)                     — the preconditioner path

A ``KernelOps`` backend implements exactly these three, parameterized by a
kernel object carrying a declarative ``KernelSpec`` (see
``repro.core.kernels``). Backends are selected by name from a registry:

    ops = get_ops("pallas", kernel, block_size=2048, precision="bf16")
    w = ops.sweep(X, C, u, v)

Registered implementations:

* ``"jnp"``    — pure-jnp blocked reference (lax.scan over row blocks); runs
                 anywhere, fp32/fp64, the numerical ground truth.
* ``"pallas"`` — fused TPU path: the sweep is ONE Pallas pass that computes
                 each Gram tile once (see ``repro.kernels.kernel_matvec``).

Everything above this layer (core/matvec.py, core/falkon.py, the distributed
shard_map wrapper, serving, benchmarks) talks to a KernelOps and never to a
concrete kernel implementation. This module deliberately has no imports from
``repro.core`` or ``repro.kernels`` so it can never participate in an import
cycle; backends duck-type the kernel via its ``spec`` attribute / call.

``precision`` is the storage/accumulate policy of the hot loop, resolved to a
:class:`PrecisionPolicy` (a name is just a registry key):

* ``"fp32"`` (default) — every buffer float32 (or float64 under x64), plain
  accumulation. Numerically identical to the pre-policy code path.
* ``"bf16"`` — END-TO-END bfloat16 storage for every DATA-SPACE (n-sized)
  buffer: X, C, the v term, the forward buffer ``t`` (including its HBM
  spill in the j-sharded sweep), the CG iterates, and the streamed
  host->device chunks — the full 2x HBM-footprint/bandwidth win, since the
  sweep's traffic is dominated by n-sized objects — while every contraction
  accumulates in float32 with Kahan/two-sum COMPENSATION inside the tile
  loops, so the reduction error stays O(eps_fp32) instead of growing with
  the tile count. Per-buffer overrides keep three things float32: ``gram``
  (the preconditioner's Cholesky input), ``cholesky`` (the factors), and
  ``coeffs`` — the M-sized coefficient vectors crossing the sweep boundary
  (u in, w out). The last one is measured, not taste: quantizing u/w makes
  the PRECONDITIONED operator nonlinear at the quantization scale, the
  triangular solves amplify that noise, and CG stalls near 1e-1 relative
  residual (vs 5e-4 with fp32 coeffs); u/w are O(M*p) so keeping them wide
  costs no meaningful bandwidth. The bf16 CG iterates are safe precisely
  because the operator stays exact-at-the-point (see repro.core.cg).

Error model (tested against an fp64 oracle in tests/test_precision.py and
measured by benchmarks/precision_sweep.py): with bf16 storage the dominant
term is input/vector quantization, |w - w_fp64| / |w_fp64| <= c * eps_bf16
with eps_bf16 = 2^-8 ~= 3.9e-3; compensated fp32 accumulation keeps the
summation term at O(eps_fp32) independent of n/M, so the documented
end-to-end ceiling is 1e-2 relative across all registered kernels.

This module also hosts the two memory planners — pure static-shape
arithmetic (no jax, safe at trace time), each emitting a structured warning
carrying the full plan when it routes off the default path:

* :func:`plan_sweep` -> :class:`SweepPlan` (+ ``SweepPlanWarning``): routes
  a sweep fused -> two_pass -> j_sharded against the VMEM budget
  (``REPRO_VMEM_BUDGET_MB``).
* :func:`plan_factor` -> :class:`FactorPlan` (+ ``FactorPlanWarning``):
  routes the preconditioner's O(M^2) Cholesky factors incore -> blocked
  against a device-memory budget (``REPRO_FACTOR_BUDGET_MB``, default
  512 MB). The blocked path (``repro.kernels.blocked_cholesky``, consumed
  by ``repro.core.preconditioner``) keeps the factor host-resident and
  bounds peak device bytes at ``FactorPlan.device_ceiling_bytes`` =
  3 * 2 * block * M * itemsize — O(b*M), not O(M^2). ``tile_dtype`` honors
  the PrecisionPolicy ``cholesky`` override (float32 floor; see above).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Protocol, runtime_checkable

PRECISIONS = ("fp32", "bf16")

#: dtype-name -> bytes, kept local so this module stays jax-import-free.
_ITEMSIZE = {
    "float64": 8,
    "float32": 4,
    "bfloat16": 2,
    "float16": 2,
    "float8_e4m3fn": 1,
    "float8_e5m2": 1,
}


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """The full precision contract of the FALKON hot loop.

    ``storage`` is the dtype the DATA-SPACE (n-sized) buffers live in (HBM
    footprint and host->device transfer width): X, C, v, the forward buffer
    ``t`` and its j-sharded HBM spill, the streamed chunks, and the CG
    iterates. ``accumulate`` is the dtype every contraction reduces in (the
    MXU runs storage-in/accumulate-out via ``preferred_element_type``).
    With ``compensated=True`` the Pallas tile loops (and the jnp reference
    scan) carry a Kahan/two-sum compensation buffer next to each
    accumulator, so the summation error is O(eps_accumulate), independent
    of the number of tiles reduced. ``overrides`` pins individual buffers
    to a different storage dtype — by default three stay float32:

    * ``gram`` / ``cholesky`` — the preconditioner's K_MM is one-shot
      O(M^2) work with no bandwidth win to harvest, and quantizing it can
      push a borderline-PSD matrix indefinite.
    * ``coeffs`` — the M-sized coefficient vectors at the sweep boundary
      (u in, w out). Quantizing them makes the preconditioned CG operator
      nonlinear at eps_storage scale, which the triangular solves amplify
      into a ~1e-1 residual stall (measured in tests/test_precision.py);
      they are O(M*p), so float32 costs nothing against the n-sized
      buffers the policy shrinks.

    CG scalars (alpha, beta, residual norms) are ALWAYS computed in
    ``accumulate`` precision regardless of ``storage`` — see repro.core.cg.
    """

    name: str
    storage: str = "float32"
    accumulate: str = "float32"
    compensated: bool = False
    overrides: tuple[tuple[str, str], ...] = (
        ("gram", "float32"), ("cholesky", "float32"), ("coeffs", "float32")
    )

    def buffer_dtype(self, buffer: str) -> str:
        """Storage dtype for a named buffer, honoring per-buffer overrides."""
        return dict(self.overrides).get(buffer, self.storage)

    @property
    def storage_itemsize(self) -> int:
        return _ITEMSIZE[self.storage]

    @property
    def accumulate_itemsize(self) -> int:
        return _ITEMSIZE[self.accumulate]

    @property
    def coeffs_itemsize(self) -> int:
        return _ITEMSIZE[self.buffer_dtype("coeffs")]


#: Named policies ``get_ops(precision=...)`` accepts as strings.
POLICIES: dict[str, PrecisionPolicy] = {
    "fp32": PrecisionPolicy(name="fp32"),
    "bf16": PrecisionPolicy(name="bf16", storage="bfloat16",
                            accumulate="float32", compensated=True),
}


def resolve_precision(precision) -> PrecisionPolicy:
    """Resolve a policy name (or pass through a ``PrecisionPolicy``)."""
    if isinstance(precision, PrecisionPolicy):
        return precision
    if precision in POLICIES:
        return POLICIES[precision]
    raise ValueError(
        f"unknown precision {precision!r}; supported: {PRECISIONS} "
        f"(or a PrecisionPolicy instance)")

SWEEP_PATHS = ("fused", "two_pass", "j_sharded", "jnp")

#: Default VMEM budget for the fused sweep's scratch + pipelined IO tiles.
#: Real TPUs fail to compile somewhere past ~16MB of requested VMEM; 12MB
#: leaves headroom for the compiler's own allocations. Override per-process
#: with ``REPRO_VMEM_BUDGET_MB`` or per-call via ``plan_sweep(vmem_budget=)``.
DEFAULT_VMEM_BUDGET = 12 * 2**20

_LANE = 128  # MXU lane width — mirrors repro.kernels.kernel_matvec.LANE


def _vmem_budget() -> int:
    mb = os.environ.get("REPRO_VMEM_BUDGET_MB")
    return int(float(mb) * 2**20) if mb else DEFAULT_VMEM_BUDGET


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """The sweep-path decision for one (n, M, d, p) problem, with the budget
    numbers that produced it — exposed via ``KernelOps.plan()`` so tests and
    benchmarks can assert on routing instead of reverse-engineering it."""

    path: str                  # one of SWEEP_PATHS
    n: int
    M: int
    d: int
    p: int                     # TOTAL column width charged (= systems * p_rhs)
    block_m: int               # (bm, bn) tile dims the sweep runs with
    block_n: int
    shard_m: int | None        # C-shard rows for the j_sharded path
    scratch_bytes: int         # fused-path VMEM scratch estimate
    io_bytes: int              # double-buffered operand/output tiles
    vmem_budget_bytes: int
    reason: str
    input_dtype: str = "float32"    # X/C storage dtype
    vector_dtype: str = "float32"   # v/t data-space storage dtype
    accum_dtype: str = "float32"    # contraction accumulate dtype
    coeffs_dtype: str = "float32"   # u-in / w-out coefficient dtype
    compensated: bool = False       # Kahan carry buffers counted in scratch
    systems: int = 1                # stacked lam-path systems sharing the sweep

    @property
    def total_bytes(self) -> int:
        return self.scratch_bytes + self.io_bytes

    @property
    def hbm_bytes(self) -> int:
        """Storage-dtype HBM working set of one sweep: X, C, v and the
        forward buffer t (spilled on the out-of-core paths) at storage
        width, plus the M-sized u/w at coefficient width. This is the
        footprint the bf16 policy halves (the n-sized terms dominate) —
        the planner-model number the precision benchmark reports as
        headroom."""
        in_item = _ITEMSIZE[self.input_dtype]
        vec_item = _ITEMSIZE[self.vector_dtype]
        co_item = _ITEMSIZE[self.coeffs_dtype]
        return (in_item * (self.n + self.M) * self.d
                + vec_item * 2 * self.n * self.p
                + co_item * 2 * self.M * self.p)


def plan_sweep(
    n: int,
    M: int,
    d: int,
    p: int = 1,
    *,
    bm: int,
    bn: int,
    systems: int = 1,
    itemsize: int = 4,
    vec_itemsize: int | None = None,
    coeffs_itemsize: int | None = None,
    acc_itemsize: int = 4,
    compensated: bool = False,
    policy: "PrecisionPolicy | None" = None,
    vmem_budget: int | None = None,
    shard_m: int | None = None,
) -> SweepPlan:
    """Pick fused / two-pass / j-sharded from a VMEM budget model.

    The fused single-pass sweep needs, in VMEM: the (bm, Mpad) accumulate-
    dtype Gram row strip; the (Mpad, pp) w accumulator and (bm, pp) forward
    block in the accumulate dtype (doubled when ``compensated`` — each
    accumulator carries a same-shape Kahan compensation buffer); the
    (Mpad, pp) w OUTPUT buffer at ``coeffs_itemsize``; plus double-buffered
    input/output tiles — ``itemsize`` bytes for the X/C tiles,
    ``vec_itemsize`` for the data-space v tile and ``coeffs_itemsize`` for
    the u tile (the pre-policy model wrongly charged every vector at 4
    bytes regardless of its storage dtype). When the total exceeds the
    budget the sweep must evaluate each Gram tile twice, and the only
    question left is the C-shard granularity: ``shard_m`` is sized so one
    shard's padded storage-dtype copy stays within the budget-scaled HBM
    workspace. A single shard covering all of M degenerates to the classic
    two-pass composition.

    ``systems`` is the lam-path stacking factor: the path solver stacks L
    independent regularization systems along the column axis so one data
    sweep serves all of them, which means every p-sized term above is
    charged at the WIDENED width ``p * systems`` — a fat path that no
    longer fits the fused budget must route to two_pass/j_sharded exactly
    as a fat multi-rhs would (the plan records the effective ``p`` and the
    ``systems`` factor separately). Passing the stacked width directly as
    ``p`` is equivalent; ``systems`` exists so callers planning a path can
    ask about it without pre-multiplying.

    ``policy`` (a :class:`PrecisionPolicy`) is the preferred way to set the
    dtype knobs; explicit ``itemsize``/``vec_itemsize``/``compensated``
    remain for direct calls. Pure arithmetic on static shapes — safe to call
    at trace time, no jax imports (this module must stay import-cycle-free).
    """
    _names = {8: "float64", 4: "float32", 2: "bfloat16"}
    if policy is not None:
        itemsize = policy.storage_itemsize
        vec_itemsize = policy.storage_itemsize
        coeffs_itemsize = policy.coeffs_itemsize
        acc_itemsize = policy.accumulate_itemsize
        compensated = policy.compensated
        # dtype NAMES come straight from the policy (the itemsize map below
        # cannot tell float16 from bfloat16)
        names = dict(
            input_dtype=policy.storage,
            vector_dtype=policy.storage,
            accum_dtype=policy.accumulate,
            coeffs_dtype=policy.buffer_dtype("coeffs"),
        )
    else:
        names = None
    if vec_itemsize is None:
        vec_itemsize = itemsize if itemsize >= 4 else 4
    if coeffs_itemsize is None:
        coeffs_itemsize = vec_itemsize
    if names is None:
        names = dict(
            input_dtype=_names.get(itemsize, "float32"),
            vector_dtype=_names.get(vec_itemsize, "float32"),
            accum_dtype=_names.get(acc_itemsize, "float32"),
            coeffs_dtype=_names.get(coeffs_itemsize, "float32"),
        )
    if vmem_budget is None:
        vmem_budget = _vmem_budget()
    systems = max(systems, 1)
    p = max(p, 1) * systems
    Mpad = -(-M // _LANE) * _LANE
    dp = -(-d // _LANE) * _LANE
    pp = -(-p // _LANE) * _LANE
    acc = acc_itemsize * (Mpad * pp + bm * pp)      # w + t accumulators
    if compensated:
        acc *= 2                                    # Kahan carry buffers
    scratch = (acc_itemsize * bm * Mpad             # Gram row strip
               + acc
               + coeffs_itemsize * Mpad * pp)       # w output buffer
    io = 2 * (itemsize * (bm + bn) * dp            # X_i / C_j tiles
              + coeffs_itemsize * bn * pp          # u_j tile
              + vec_itemsize * bm * pp)            # v_i tile
    base = dict(
        n=n,
        M=M,
        d=d,
        p=p,
        block_m=bm,
        block_n=bn,
        scratch_bytes=scratch,
        io_bytes=io,
        vmem_budget_bytes=vmem_budget,
        compensated=compensated,
        systems=systems,
        **names,
    )

    if scratch + io <= vmem_budget:
        return SweepPlan(
            path="fused", shard_m=None,
            reason=(f"fused scratch {scratch}B + io {io}B fits the "
                    f"{vmem_budget}B VMEM budget"),
            **base)

    if shard_m is None:
        # one shard's padded storage-dtype C copy ~ one budget of HBM
        # workspace
        shard_m = max(bn, vmem_budget // (itemsize * dp))
    shard_m = max(bn, (int(shard_m) // bn) * bn)
    over = (f"fused scratch {scratch}B + io {io}B exceeds the "
            f"{vmem_budget}B VMEM budget")
    if shard_m >= M:
        return SweepPlan(
            path="two_pass",
            shard_m=None,
            reason=f"{over}; single C-shard covers M={M} — two-pass sweep",
            **base,
        )
    return SweepPlan(
        path="j_sharded", shard_m=shard_m,
        reason=(f"{over}; j-sharded sweep over "
                f"{-(-M // shard_m)} C-shards of {shard_m} rows"),
        **base)


class SweepPlanWarning(UserWarning):
    """Structured fallback notice: the fused single-pass sweep did not fit
    the VMEM budget and a 2-evaluations-per-tile path was chosen. Carries the
    full ``SweepPlan`` as ``.plan`` for programmatic inspection."""

    def __init__(self, plan: SweepPlan):
        self.plan = plan
        super().__init__(
            f"falkon sweep (n={plan.n}, M={plan.M}, d={plan.d}, p={plan.p}): "
            f"taking the {plan.path!r} path — {plan.reason}")


# ---------------------------------------------------------------------------
# Factorization planning: in-core vs blocked (out-of-core) Cholesky
# ---------------------------------------------------------------------------
FACTOR_PATHS = ("incore", "blocked")

#: Default budget for a DENSE in-core Cholesky factor. FALKON's statistical
#: optimality wants M ~ sqrt(n) Nystrom centers, and the preconditioner's
#: O(M^2) factors are the first thing that stops fitting as M grows: a dense
#: fp32 factor is 1 GB at M = 16384 and 40 GB at M = 10^5. Past this budget
#: ``plan_factor`` routes to the blocked right-looking Cholesky
#: (``repro.kernels.blocked_cholesky``), which keeps the matrix host-resident
#: in (b, b) tiles and holds only O(b * M) panel bytes device-resident at any
#: moment. Override per-process with ``REPRO_FACTOR_BUDGET_MB`` (the forcing
#: knob tests use, mirroring ``REPRO_VMEM_BUDGET_MB``).
DEFAULT_FACTOR_BUDGET = 512 * 2**20

#: Blocked-path tile bounds: lane-aligned (multiples of _LANE*2 = 256) so the
#: Pallas tile kernels need no ragged-edge handling inside the hot loop.
_FACTOR_BLOCK_MIN = 256
_FACTOR_BLOCK_MAX = 2048


def _factor_budget() -> int:
    mb = os.environ.get("REPRO_FACTOR_BUDGET_MB")
    return int(float(mb) * 2**20) if mb else DEFAULT_FACTOR_BUDGET


@dataclasses.dataclass(frozen=True)
class FactorPlan:
    """The Cholesky-path decision for one (M, M) factorization — the
    ``SweepPlan`` sibling for the preconditioner stack, exposed so tests and
    benchmarks can assert on routing and on the device-residency model
    instead of reverse-engineering them.

    ``dense_bytes`` is what the in-core path keeps device-resident (the
    factor itself, before LAPACK workspace); ``panel_bytes`` is the blocked
    path's algorithmic working set — the current factor panel plus one
    trailing column panel, 2 * block * M * itemsize — the O(b * M) bound the
    acceptance tests measure against (with slack for XLA temporaries; see
    ``device_ceiling_bytes``).
    """

    path: str                  # one of FACTOR_PATHS
    M: int
    block: int | None          # (b, b) tile side for the blocked path
    itemsize: int              # bytes per element of the factor dtype
    dense_bytes: int           # M * M * itemsize — in-core factor residency
    panel_bytes: int           # 2 * block * M * itemsize — blocked working set
    factor_budget_bytes: int
    reason: str
    tile_dtype: str = "float32"   # in-tile compute dtype (policy `cholesky`
    #                               override: fp32 floor even under bf16
    #                               storage — the PR 3 measured constraint)

    @property
    def device_ceiling_bytes(self) -> int:
        """The bound the blocked path's measured peak device residency must
        stay under: 3x the two-panel model, covering the update's output
        buffer and transient XLA copies. Still O(b * M) — the point is that
        it does not scale with M^2."""
        return 3 * self.panel_bytes


def plan_factor(
    M: int,
    *,
    itemsize: int = 4,
    policy: "PrecisionPolicy | None" = None,
    block: int | None = None,
    factor_budget: int | None = None,
) -> FactorPlan:
    """Pick in-core vs blocked Cholesky from a dense-factor budget model.

    In-core ``jnp.linalg.cholesky`` keeps the full (M, M) factor (plus the
    jittered input and LAPACK workspace) device-resident: ``M^2 * itemsize``
    bytes. When that exceeds the budget the factorization routes to the
    tiled right-looking blocked path, whose device working set is two
    (M, block) panels. ``block`` is sized so those panels fit the budget
    (lane-aligned, clamped to [{_FACTOR_BLOCK_MIN}, {_FACTOR_BLOCK_MAX}]).

    ``policy`` pins the in-tile compute dtype through the ``cholesky``
    per-buffer override — float32 by default even under the bf16 storage
    policy (quantized factors destabilize the preconditioned CG operator;
    the PR 3 measured constraint). ``itemsize`` is the factor storage width
    (4 for fp32, 8 for x64 callers). Pure arithmetic on static shapes — safe
    at trace time, no jax imports (this module stays import-cycle-free).
    """
    if factor_budget is None:
        factor_budget = _factor_budget()
    tile_dtype = "float32"
    if policy is not None:
        tile_dtype = policy.buffer_dtype("cholesky")
        itemsize = max(_ITEMSIZE[tile_dtype], 4)  # fp32 floor
    dense = M * M * itemsize

    if block is None:
        # two (M, block) panels ~ one budget of device workspace
        block = factor_budget // max(2 * M * itemsize, 1)
        block = (block // _FACTOR_BLOCK_MIN) * _FACTOR_BLOCK_MIN
        block = max(_FACTOR_BLOCK_MIN, min(_FACTOR_BLOCK_MAX, block))
    panel = 2 * block * M * itemsize
    base = dict(
        M=M,
        itemsize=itemsize,
        dense_bytes=dense,
        panel_bytes=panel,
        factor_budget_bytes=factor_budget,
        tile_dtype=tile_dtype,
    )

    if dense <= factor_budget:
        return FactorPlan(
            path="incore", block=None, panel_bytes=0,
            reason=(f"dense factor {dense}B fits the {factor_budget}B "
                    f"factor budget — in-core cholesky"),
            **{k: v for k, v in base.items() if k != "panel_bytes"})
    return FactorPlan(
        path="blocked", block=block,
        reason=(f"dense factor {dense}B exceeds the {factor_budget}B factor "
                f"budget — blocked right-looking cholesky over "
                f"{-(-M // block)} panels of {block} columns "
                f"(device working set ~{panel}B)"),
        **base)


class FactorPlanWarning(UserWarning):
    """Structured notice that a preconditioner factorization left the
    in-core path: the dense (M, M) factor exceeded the factor budget and the
    blocked out-of-core Cholesky was chosen (host-resident tiles, O(b * M)
    device-resident panels). Carries the full ``FactorPlan`` as ``.plan``."""

    def __init__(self, plan: FactorPlan):
        self.plan = plan
        super().__init__(
            f"falkon preconditioner (M={plan.M}): taking the {plan.path!r} "
            f"factor path — {plan.reason}")


# ---------------------------------------------------------------------------
# K_nM cache planning: device-resident vs host-streamed vs recompute
# ---------------------------------------------------------------------------
CACHE_TIERS = ("device", "host", "off")

#: Default device-memory budget for a materialized K_nM. The cached sweep
#: turns every CG iteration's kernel re-evaluation (the paper's one-full-
#: kernel-pass-per-sweep cost model) into two GEMMs over stored entries, so
#: the only question is where n*M*itemsize bytes live. Up to this budget the
#: cache is device-resident ("device" tier); past it the tiles are pinned
#: host-side and streamed ("host" tier, double-buffered via
#: ``repro.data.streaming.StreamingLoader``); past ``REPRO_KNM_HOST_BUDGET_MB``
#: the cache is refused outright ("off" — today's recompute path, bit-
#: identical). Override per-process with ``REPRO_KNM_BUDGET_MB`` (the
#: forcing knob tests use, mirroring ``REPRO_VMEM_BUDGET_MB``).
DEFAULT_KNM_BUDGET = 1024 * 2**20
DEFAULT_KNM_HOST_BUDGET = 8192 * 2**20


def _knm_budget() -> int:
    mb = os.environ.get("REPRO_KNM_BUDGET_MB")
    return int(float(mb) * 2**20) if mb is not None else DEFAULT_KNM_BUDGET


def _knm_host_budget() -> int:
    mb = os.environ.get("REPRO_KNM_HOST_BUDGET_MB")
    return int(float(mb) * 2**20) if mb is not None else DEFAULT_KNM_HOST_BUDGET


@dataclasses.dataclass(frozen=True)
class CachePlan:
    """The K_nM-residency decision for one (n, M) problem — the
    ``SweepPlan``/``FactorPlan`` sibling for the materialized-sweep cache,
    exposed so tests and benchmarks can assert on tier routing and on the
    bytes model instead of reverse-engineering them.

    ``cache_bytes`` is the full materialized K_nM at the policy's STORAGE
    width (the bf16 policy halves it — the cache composes with the
    precision work); ``shard_bytes`` is what one data shard actually holds
    (``DistributedOps`` caches only its local row block, so the budget is
    charged per shard — zero extra communication, the psum invariants are
    unchanged).
    """

    tier: str                  # one of CACHE_TIERS
    n: int
    M: int
    shards: int                # data shards splitting the rows (1 = local)
    itemsize: int              # bytes per stored kernel entry
    cache_bytes: int           # n * M * itemsize — the full cache
    shard_bytes: int           # per-shard residency the budgets are charged on
    budget_bytes: int          # device (HBM) budget
    host_budget_bytes: int     # pinned-host budget for the streamed tier
    reason: str
    storage_dtype: str = "float32"  # dtype the tiles are stored at


def plan_cache(
    n: int,
    M: int,
    *,
    itemsize: int = 4,
    policy: "PrecisionPolicy | None" = None,
    shards: int = 1,
    tier: str | None = None,
    budget: int | None = None,
    host_budget: int | None = None,
) -> CachePlan:
    """Pick the K_nM cache tier (device / host / off) from a bytes model.

    A cached fit evaluates each of the ceil(n/block) row tiles of K_nM
    exactly ONCE (via ``KernelOps.materialize``) and serves every later
    sweep/apply as GEMMs over the stored entries, so the decision is purely
    residency: ``n * M * itemsize`` bytes at the policy's storage width
    (``overrides`` do NOT apply — the cache deliberately stores at the
    data-space storage dtype to harvest the bf16 footprint halving;
    accumulation back to float32 happens in the GEMM consumers). Charged
    per data shard: a ``DistributedOps`` wrapper splits the rows over
    ``shards`` devices and each holds only its block.

    ``tier`` forces a specific tier (tests and the benchmark's routing
    table use it); ``None`` routes device -> host -> off against the
    budgets (``REPRO_KNM_BUDGET_MB`` / ``REPRO_KNM_HOST_BUDGET_MB``).
    Pure arithmetic on static shapes — safe at trace time, no jax imports
    (this module stays import-cycle-free).
    """
    if policy is not None:
        itemsize = policy.storage_itemsize
        storage_dtype = policy.storage
    else:
        storage_dtype = {8: "float64", 4: "float32", 2: "bfloat16"}.get(
            itemsize, "float32")
    if budget is None:
        budget = _knm_budget()
    if host_budget is None:
        host_budget = _knm_host_budget()
    shards = max(int(shards), 1)
    total = n * M * itemsize
    shard_bytes = -(-total // shards)
    base = dict(
        n=n,
        M=M,
        shards=shards,
        itemsize=itemsize,
        cache_bytes=total,
        shard_bytes=shard_bytes,
        budget_bytes=budget,
        host_budget_bytes=host_budget,
        storage_dtype=storage_dtype,
    )
    if tier is not None:
        if tier not in CACHE_TIERS:
            raise ValueError(
                f"unknown cache tier {tier!r}; supported: {CACHE_TIERS}")
        return CachePlan(tier=tier, reason=f"tier {tier!r} forced by caller",
                         **base)
    if shard_bytes <= budget:
        return CachePlan(
            tier="device",
            reason=(f"K_nM shard {shard_bytes}B fits the {budget}B device "
                    f"budget — device-resident cache"),
            **base)
    if shard_bytes <= host_budget:
        return CachePlan(
            tier="host",
            reason=(f"K_nM shard {shard_bytes}B exceeds the {budget}B device "
                    f"budget but fits the {host_budget}B host budget — "
                    f"host-pinned tiles, streamed sweeps"),
            **base)
    return CachePlan(
        tier="off",
        reason=(f"K_nM shard {shard_bytes}B exceeds the {host_budget}B host "
                f"budget — recompute path (no cache)"),
        **base)


class CachePlanWarning(UserWarning):
    """Structured notice that a requested K_nM cache routed off the
    device-resident default (host-streamed tiles, or refused entirely and
    fell back to the recompute path). Carries the full ``CachePlan`` as
    ``.plan`` for programmatic inspection."""

    def __init__(self, plan: CachePlan):
        self.plan = plan
        super().__init__(
            f"falkon K_nM cache (n={plan.n}, M={plan.M}, "
            f"shards={plan.shards}): taking the {plan.tier!r} tier — "
            f"{plan.reason}")


@runtime_checkable
class KernelOps(Protocol):
    """The three primitives the whole codebase needs — and nothing else
    (plus ``plan``, the introspectable routing decision behind ``sweep``)."""

    kernel: Any
    block_size: int
    precision: "str | PrecisionPolicy"

    def sweep(self, X, C, u, v=None, row_mask=None):
        """K(X,C)^T (K(X,C) u + v); ``v=None`` means v == 0.

        ``row_mask`` (n,), 0/1 (or None = all valid): rows with mask 0
        contribute EXACTLY zero to the result. The sweep is additive over
        rows, so this lets callers pad a ragged row chunk to a fixed shape
        (one XLA compile per fit instead of one per distinct chunk shape —
        see ``repro.data.streaming``) without changing the math.
        """
        ...

    def apply(self, X, C, u):
        """K(X,C) u — the prediction path."""
        ...

    def gram(self, A, B):
        """K(A, B) materialized — the preconditioner path."""
        ...

    def plan(self, n: int, M: int, d: int, p: int = 1, systems: int = 1) -> SweepPlan:
        """The sweep path this backend would take for these shapes.

        ``systems`` charges the lam-path stacking: the planner models the
        widened ``p * systems`` column block the path solve actually sweeps.
        """
        ...


_REGISTRY: dict[str, type] = {}


def register_ops(name: str):
    """Class decorator registering a KernelOps implementation under ``name``."""
    def deco(cls):
        cls.impl_name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_ops() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_ops(
    impl: str,
    kernel,
    *,
    block_size: int = 2048,
    precision: "str | PrecisionPolicy" = "fp32",
) -> KernelOps:
    """Construct the named backend for ``kernel``.

    ``kernel`` must carry a ``KernelSpec`` (anything built by
    ``repro.core.kernels.make_kernel`` / ``@register_kernel`` does).
    ``precision`` is a policy name ("fp32"/"bf16") or a full
    :class:`PrecisionPolicy`.
    """
    if impl not in _REGISTRY:
        raise ValueError(
            f"unknown KernelOps impl {impl!r}; registered: {available_ops()}"
        )
    resolve_precision(precision)  # validate early; backends resolve lazily
    return _REGISTRY[impl](kernel=kernel, block_size=block_size, precision=precision)


@dataclasses.dataclass(frozen=True)
class OpsBase:
    """Shared constructor shape for backends (kernel + static knobs)."""

    kernel: Any
    block_size: int = 2048
    precision: "str | PrecisionPolicy" = "fp32"

    @property
    def policy(self) -> PrecisionPolicy:
        """The resolved :class:`PrecisionPolicy` this backend runs under."""
        return resolve_precision(self.precision)


class CountingOps:
    """Invocation-counting facade over any :class:`KernelOps`.

    The instrumentation seam behind the lam-path acceptance claim: a path
    fit over L regularizers must issue ~1/L the ``sweep`` calls of L
    sequential fits, and "number of sweeps" is exactly what this wrapper
    counts. Pure delegation (same primitives, same results, same plan) plus
    the counters — ``sweeps``, ``applies``, ``grams``, and the K_nM-cache
    seam's quartet:

    * ``gram_tile_evals`` — kernel-entry evaluation work, in units of
      ceil(rows / block_size) row tiles, charged by every primitive that
      EVALUATES kernel entries (``sweep``, ``apply``, ``gram``,
      ``materialize``). This is the cache acceptance seam: a cached fit
      materializes each K_nM row tile exactly once, so its K_nM share of
      ``gram_tile_evals`` equals the tile count — where the recompute path
      charges it once per sweep/apply program point.
    * ``materializes`` / ``gemm_sweeps`` / ``gemm_applies`` — the cache-path
      primitives. The GEMM calls consume STORED entries and charge no
      ``gram_tile_evals``; that asymmetry is what makes the one-eval-per-
      tile claim provable by counting.

    The counters are PROGRAM-POINT counts, not executed-data-pass counts:
    a primitive called under a trace (``jax.jit``, or the matvec inside the
    scanned CG driver's ``lax.scan`` body) increments once at trace time no
    matter how many times the compiled program replays it. That is still
    the right invariant for the sharing claim — a solve whose scan body
    contains ONE sweep serving L systems counts 1 where L sequential solves
    count L, and both execute their traced sweep t times — but it means a
    fixed count does NOT scale with the iteration count t, and jitted
    facades (e.g. the streaming ``JittedOps``) count compilations, not
    calls.
    """

    def __init__(self, ops):
        self.ops = ops
        self.sweeps = 0
        self.applies = 0
        self.grams = 0
        self.gram_tile_evals = 0
        self.materializes = 0
        self.gemm_sweeps = 0
        self.gemm_applies = 0

    @property
    def kernel(self):
        return self.ops.kernel

    @property
    def block_size(self):
        return self.ops.block_size

    @property
    def precision(self):
        return self.ops.precision

    @property
    def policy(self):
        return self.ops.policy

    def _tiles(self, rows) -> int:
        bs = self.ops.block_size
        return -(-int(rows) // bs)

    def sweep(self, X, C, u, v=None, row_mask=None):
        self.sweeps += 1
        self.gram_tile_evals += self._tiles(X.shape[0])
        return self.ops.sweep(X, C, u, v, row_mask)

    def apply(self, X, C, u):
        self.applies += 1
        self.gram_tile_evals += self._tiles(X.shape[0])
        return self.ops.apply(X, C, u)

    def gram(self, A, B):
        self.grams += 1
        self.gram_tile_evals += self._tiles(A.shape[0])
        return self.ops.gram(A, B)

    def materialize(self, X, C):
        # ONE kernel evaluation per row tile — the only K_nM entry
        # evaluation a cached fit performs.
        self.materializes += 1
        self.gram_tile_evals += self._tiles(X.shape[0])
        return self.ops.materialize(X, C)

    def gemm_sweep(self, K, u, v=None, row_mask=None):
        # consumes STORED entries: no gram_tile_evals charge
        self.gemm_sweeps += 1
        return self.ops.gemm_sweep(K, u, v, row_mask)

    def gemm_apply(self, K, u):
        self.gemm_applies += 1
        return self.ops.gemm_apply(K, u)

    def plan(self, n: int, M: int, d: int, p: int = 1, systems: int = 1) -> SweepPlan:
        return self.ops.plan(n, M, d, p, systems)

    def reset(self) -> None:
        self.sweeps = self.applies = self.grams = 0
        self.gram_tile_evals = 0
        self.materializes = self.gemm_sweeps = self.gemm_applies = 0
