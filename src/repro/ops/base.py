"""The ``KernelOps`` backend protocol and registry.

FALKON's entire O(n sqrt(n)) time budget reduces to three primitives over an
(n, d) dataset ``X``, (M, d) Nystrom centers ``C`` and coefficient vectors:

    sweep(X, C, u, v)  =  K(X,C)^T (K(X,C) u + v)    — one CG iteration
    apply(X, C, u)     =  K(X,C) u                    — the prediction path
    gram(A, B)         =  K(A, B)                     — the preconditioner path

A ``KernelOps`` backend implements exactly these three, parameterized by a
kernel object carrying a declarative ``KernelSpec`` (see
``repro.core.kernels``). Backends are selected by name from a registry:

    ops = get_ops("pallas", kernel, block_size=2048, precision="bf16")
    w = ops.sweep(X, C, u, v)

Registered implementations:

* ``"jnp"``    — pure-jnp blocked reference (lax.scan over row blocks); runs
                 anywhere, fp32/fp64, the numerical ground truth.
* ``"pallas"`` — fused TPU path: the sweep is ONE Pallas pass that computes
                 each Gram tile once (see ``repro.kernels.kernel_matvec``).

Everything above this layer (core/matvec.py, core/falkon.py, the distributed
shard_map wrapper, serving, benchmarks) talks to a KernelOps and never to a
concrete kernel implementation. This module deliberately has no imports from
``repro.core`` or ``repro.kernels`` so it can never participate in an import
cycle; backends duck-type the kernel via its ``spec`` attribute / call.

``precision`` is the input/accumulate policy of the hot loop:

* ``"fp32"`` (default) — inputs and accumulation in float32 (or float64
  under x64).
* ``"bf16"`` — X and C are quantized to bfloat16 before entering the
  bandwidth-bound ``sweep``/``apply`` (halving HBM traffic and feeding the
  MXU bf16 inputs); all contractions still accumulate in float32, and
  ``gram`` (the preconditioner's Cholesky input) stays full precision.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Protocol, runtime_checkable

PRECISIONS = ("fp32", "bf16")

SWEEP_PATHS = ("fused", "two_pass", "j_sharded", "jnp")

#: Default VMEM budget for the fused sweep's scratch + pipelined IO tiles.
#: Real TPUs fail to compile somewhere past ~16MB of requested VMEM; 12MB
#: leaves headroom for the compiler's own allocations. Override per-process
#: with ``REPRO_VMEM_BUDGET_MB`` or per-call via ``plan_sweep(vmem_budget=)``.
DEFAULT_VMEM_BUDGET = 12 * 2**20

_LANE = 128  # MXU lane width — mirrors repro.kernels.kernel_matvec.LANE


def _vmem_budget() -> int:
    mb = os.environ.get("REPRO_VMEM_BUDGET_MB")
    return int(float(mb) * 2**20) if mb else DEFAULT_VMEM_BUDGET


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """The sweep-path decision for one (n, M, d, p) problem, with the budget
    numbers that produced it — exposed via ``KernelOps.plan()`` so tests and
    benchmarks can assert on routing instead of reverse-engineering it."""

    path: str                  # one of SWEEP_PATHS
    n: int
    M: int
    d: int
    p: int
    block_m: int               # (bm, bn) tile dims the sweep runs with
    block_n: int
    shard_m: int | None        # C-shard rows for the j_sharded path
    scratch_bytes: int         # fused-path VMEM scratch estimate
    io_bytes: int              # double-buffered operand/output tiles
    vmem_budget_bytes: int
    reason: str

    @property
    def total_bytes(self) -> int:
        return self.scratch_bytes + self.io_bytes


def plan_sweep(
    n: int, M: int, d: int, p: int = 1, *,
    bm: int, bn: int,
    itemsize: int = 4,
    vmem_budget: int | None = None,
    shard_m: int | None = None,
) -> SweepPlan:
    """Pick fused / two-pass / j-sharded from a VMEM budget model.

    The fused single-pass sweep needs, in VMEM: the (bm, Mpad) fp32 Gram row
    strip, the (Mpad, pp) fp32 accumulator twice over (strip-major layout),
    the (bm, pp) fp32 forward block, plus double-buffered input/output tiles
    (``itemsize`` bytes for X/C — 2 under bf16). When that exceeds the budget
    the sweep must evaluate each Gram tile twice, and the only question left
    is the C-shard granularity: ``shard_m`` is sized so one shard's padded
    fp32 copy stays within the budget-scaled HBM workspace. A single shard
    covering all of M degenerates to the classic two-pass composition.

    Pure arithmetic on static shapes — safe to call at trace time, no jax
    imports (this module must stay import-cycle-free).
    """
    if vmem_budget is None:
        vmem_budget = _vmem_budget()
    p = max(p, 1)
    Mpad = -(-M // _LANE) * _LANE
    dp = -(-d // _LANE) * _LANE
    pp = -(-p // _LANE) * _LANE
    scratch = 4 * (bm * Mpad + 2 * Mpad * pp + bm * pp)
    io = 2 * (itemsize * (bm + bn) * dp + 4 * (bn + bm) * pp)
    base = dict(n=n, M=M, d=d, p=p, block_m=bm, block_n=bn,
                scratch_bytes=scratch, io_bytes=io,
                vmem_budget_bytes=vmem_budget)

    if scratch + io <= vmem_budget:
        return SweepPlan(
            path="fused", shard_m=None,
            reason=(f"fused scratch {scratch}B + io {io}B fits the "
                    f"{vmem_budget}B VMEM budget"),
            **base)

    if shard_m is None:
        # one shard's padded fp32 C copy ~ one budget of HBM workspace
        shard_m = max(bn, vmem_budget // (4 * dp))
    shard_m = max(bn, (int(shard_m) // bn) * bn)
    over = (f"fused scratch {scratch}B + io {io}B exceeds the "
            f"{vmem_budget}B VMEM budget")
    if shard_m >= M:
        return SweepPlan(
            path="two_pass", shard_m=None,
            reason=f"{over}; single C-shard covers M={M} — two-pass sweep",
            **base)
    return SweepPlan(
        path="j_sharded", shard_m=shard_m,
        reason=(f"{over}; j-sharded sweep over "
                f"{-(-M // shard_m)} C-shards of {shard_m} rows"),
        **base)


class SweepPlanWarning(UserWarning):
    """Structured fallback notice: the fused single-pass sweep did not fit
    the VMEM budget and a 2-evaluations-per-tile path was chosen. Carries the
    full ``SweepPlan`` as ``.plan`` for programmatic inspection."""

    def __init__(self, plan: SweepPlan):
        self.plan = plan
        super().__init__(
            f"falkon sweep (n={plan.n}, M={plan.M}, d={plan.d}, p={plan.p}): "
            f"taking the {plan.path!r} path — {plan.reason}")


@runtime_checkable
class KernelOps(Protocol):
    """The three primitives the whole codebase needs — and nothing else
    (plus ``plan``, the introspectable routing decision behind ``sweep``)."""

    kernel: Any
    block_size: int
    precision: str

    def sweep(self, X, C, u, v=None):
        """K(X,C)^T (K(X,C) u + v); ``v=None`` means v == 0."""
        ...

    def apply(self, X, C, u):
        """K(X,C) u — the prediction path."""
        ...

    def gram(self, A, B):
        """K(A, B) materialized — the preconditioner path."""
        ...

    def plan(self, n: int, M: int, d: int, p: int = 1) -> SweepPlan:
        """The sweep path this backend would take for these shapes."""
        ...


_REGISTRY: dict[str, type] = {}


def register_ops(name: str):
    """Class decorator registering a KernelOps implementation under ``name``."""
    def deco(cls):
        cls.impl_name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_ops() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_ops(impl: str, kernel, *, block_size: int = 2048,
            precision: str = "fp32") -> KernelOps:
    """Construct the named backend for ``kernel``.

    ``kernel`` must carry a ``KernelSpec`` (anything built by
    ``repro.core.kernels.make_kernel`` / ``@register_kernel`` does).
    """
    if impl not in _REGISTRY:
        raise ValueError(
            f"unknown KernelOps impl {impl!r}; registered: {available_ops()}")
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; supported: {PRECISIONS}")
    return _REGISTRY[impl](kernel=kernel, block_size=block_size,
                           precision=precision)


@dataclasses.dataclass(frozen=True)
class OpsBase:
    """Shared constructor shape for backends (kernel + static knobs)."""

    kernel: Any
    block_size: int = 2048
    precision: str = "fp32"
