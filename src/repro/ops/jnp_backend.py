"""Reference ``KernelOps`` backend: pure jnp, blocked, runs anywhere.

The sweep is the paper's Alg. 1 ``KnM_times_vector``: a ``lax.scan`` over row
blocks of X, each step materializing one (block, M) Gram strip, using it for
both the forward product and the transposed accumulation, then discarding it —
O(M * block) memory, never the full K_nM. This is the numerical ground truth
the Pallas backend is tested against (same math via the shared
``tile_transform`` registry), and the fp64-capable path for the theory tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import OpsBase, SweepPlan, register_ops
from .gemm import GemmCacheMixin, quantize_coeffs, quantize_storage

Array = jax.Array


def _pad_blocks(
    X: Array, v: Array | None, block_size: int, row_mask: Array | None = None
):
    """Pad rows of X (and v) to a multiple of block_size; return mask.

    ``row_mask`` (n,), 0/1 — a caller-supplied validity mask folded into the
    block-padding mask, so masked rows drop out of the sweep exactly like
    the block padding does (their Gram rows are zeroed)."""
    n = X.shape[0]
    nb = -(-n // block_size)
    pad = nb * block_size - n
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    valid = (jnp.ones((n,), X.dtype) if row_mask is None else row_mask.astype(X.dtype))
    mask = jnp.pad(valid, (0, pad))
    vp = None
    if v is not None:
        widths = ((0, pad),) + ((0, 0),) * (v.ndim - 1)
        vp = jnp.pad(v, widths)
    return Xp.reshape(nb, block_size, X.shape[1]), mask.reshape(nb, block_size), vp, nb


@register_ops("jnp")
@dataclasses.dataclass(frozen=True)
class JnpKernelOps(GemmCacheMixin, OpsBase):
    """Blocked lax.scan reference implementation of the three primitives
    (plus the shared materialize/gemm cache primitives — see
    ``repro.ops.gemm``, whose blocked GEMM arithmetic mirrors this sweep's
    scan exactly, the cached == recompute bit-identity contract)."""

    def _quant(self, a: Array | None) -> Array | None:
        """Storage-dtype quantization, fp32 compute — mirrors the Pallas
        backend's storage-in/fp32-accumulate policy bit-for-policy (not
        bit-for-bit: MXU bf16 matmuls round differently). float32 storage
        means full precision: pass through untouched (x64 callers keep
        their float64). Shared with the GEMM cache path (one definition of
        "quantize" keeps the parity contract honest)."""
        return quantize_storage(self.policy, a)

    def _quant_coeffs(self, u: Array) -> Array:
        """u at the coefficient dtype — see ``gemm.quantize_coeffs``."""
        return quantize_coeffs(self.policy, u)

    def _inputs(self, X: Array, C: Array) -> tuple[Array, Array]:
        return self._quant(X), self._quant(C)

    def sweep(
        self,
        X: Array,
        C: Array,
        u: Array,
        v: Array | None = None,
        row_mask: Array | None = None,
    ) -> Array:
        """K_nM^T (K_nM u + v) with blocked O(M * block) memory.

        ``u``: (M,) or (M, p); ``v``: (n,) or (n, p) or None (treated as 0).
        ``row_mask`` (n,), 0/1: rows with mask 0 contribute EXACTLY zero —
        the contract that lets streamed tail chunks be padded to a fixed
        shape (one XLA compile per fit) without changing the result.
        Under a non-fp32 policy the data-space v is quantized through the
        storage dtype, u through the policy's coefficient dtype (float32 by
        override — quantized coefficients destabilize preconditioned CG),
        and the block reduction is Kahan-compensated when the policy says
        so — mirroring the Pallas backend's end-to-end contract, w included
        (returned at the coefficient dtype).
        """
        pol = self.policy
        X, C = self._inputs(X, C)
        u, v = self._quant_coeffs(u), self._quant(v)
        block_size = self.block_size
        kernel = self.kernel
        Xb, mask, vp, nb = _pad_blocks(X, v, block_size, row_mask)
        out_shape = (C.shape[0],) + u.shape[1:]
        if vp is not None:
            vb = vp.reshape((nb, block_size) + v.shape[1:])

        def delta(inp):
            if v is None:
                xb, mb = inp
                Kb = kernel(xb, C) * mb[:, None]          # mask padded rows
                t = Kb @ u
            else:
                xb, mb, vblk = inp
                Kb = kernel(xb, C) * mb[:, None]
                # Kb's zeroed rows already null padded contributions in
                # Kb.T @ t; masking v too keeps t finite for arbitrary pads.
                t = Kb @ u + vblk * (mb[:, None] if vblk.ndim > 1 else mb)
            return Kb.T @ t

        xs = (Xb, mask) if v is None else (Xb, mask, vb)
        if pol.compensated:
            # Kahan/two-sum across row blocks — literally the same _two_sum
            # the Pallas tile loops run (lazy import: kernels -> core is the
            # allowed direction, ops must not import kernels at module load)
            from repro.kernels.kernel_matvec import _two_sum

            def body(carry, inp):
                acc, comp = carry
                return _two_sum(acc, comp, delta(inp)), None

            init = (jnp.zeros(out_shape, X.dtype), jnp.zeros(out_shape, X.dtype))
            (w, _), _ = jax.lax.scan(body, init, xs)
        else:
            def body(carry, inp):
                return carry + delta(inp), None

            w, _ = jax.lax.scan(body, jnp.zeros(out_shape, X.dtype), xs)
        co = pol.buffer_dtype("coeffs")
        return w.astype(jnp.dtype(co)) if co != "float32" else w

    def apply(self, X: Array, C: Array, u: Array) -> Array:
        """K_nM u (prediction path), blocked over rows of X."""
        X, C = self._inputs(X, C)
        u = self._quant_coeffs(u)
        n = X.shape[0]
        Xb, mask, _, nb = _pad_blocks(X, None, self.block_size)
        kernel = self.kernel

        def body(xb):
            return kernel(xb, C) @ u

        out = jax.lax.map(body, Xb)
        out = out.reshape((nb * Xb.shape[1],) + u.shape[1:])
        return out[:n]

    def gram(self, A: Array, B: Array) -> Array:
        """K(A, B) dense (M x M for the preconditioner — paper's memory
        budget, no blocking needed). Full precision by per-buffer override
        (policy ``gram`` buffer, float32 by default): the Cholesky
        downstream is the numerically fragile step, and the bf16 policy's
        bandwidth win does not apply to this one-shot block."""
        gt = jnp.dtype(self.policy.buffer_dtype("gram"))
        if jnp.dtype(A.dtype).itemsize < gt.itemsize:   # never downcast fp64
            A = A.astype(gt)
        if jnp.dtype(B.dtype).itemsize < gt.itemsize:
            B = B.astype(gt)
        return self.kernel(A, B)

    def plan(self, n: int, M: int, d: int, p: int = 1, systems: int = 1) -> SweepPlan:
        """Reference backend has one path: the lax.scan row sweep. Reported
        through the same ``SweepPlan`` shape so callers can introspect any
        backend uniformly (``systems`` widens p exactly as the Pallas
        planner charges a stacked lam-path block)."""
        systems = max(systems, 1)
        p = max(p, 1) * systems
        pol = self.policy
        return SweepPlan(
            path="jnp", n=n, M=M, d=d, p=p, systems=systems,
            block_m=self.block_size, block_n=M, shard_m=None,
            scratch_bytes=4 * self.block_size * M, io_bytes=0,
            vmem_budget_bytes=0,
            input_dtype=pol.storage, vector_dtype=pol.storage,
            accum_dtype=pol.accumulate, compensated=pol.compensated,
            reason=(f"jnp reference: lax.scan over {self.block_size}-row "
                    f"blocks, O(block * M) live memory"))
