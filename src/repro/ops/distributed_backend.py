"""Mesh-sharded ``KernelOps``: data-parallel FALKON every layer inherits.

FALKON's O(nM) cost is the data sweep ``w = K(X,C)^T (K(X,C) u + v)``, which
is additive over rows of X — embarrassingly parallel in n. This module turns
that observation into a *backend*, not a bespoke wrapper:
:class:`DistributedOps` composes over any registered ``KernelOps`` (the jnp
reference, the fused/two-pass/j-sharded Pallas paths — planner, precision
policy and ``row_mask`` semantics all apply per shard, unchanged) and
shard_maps its primitives over the mesh data axes:

* ``sweep``  — X, v, row_mask row-sharded; C, u replicated. Each device runs
  the wrapped backend's sweep on its local (n/shards)-row shard, then ONE
  ``psum`` merges the (M, p) partials. That psum is the *only* communication:
  CG state is M-sized and replicated, so per-iteration interconnect traffic
  is exactly M * p floats no matter how large n grows. The lam-path solver
  stacks L systems into the column axis, so a path fit psums one (M, L*p)
  block — still one collective per sweep.
* ``apply``  — row-local, so X shards in and predictions shard out with no
  collective at all (the output is reassembled by the out-spec).
* ``gram``   — (M, M) work on replicated operands: delegated to the wrapped
  backend with no shard_map and no communication.
* ``plan``   — the wrapped planner budgeted at ``n_local = ceil(n/shards)``
  rows: fused -> two_pass -> j_sharded routing and the bf16 storage policy
  are decided per shard, exactly as they would be on a single device of
  that size.

Ragged n is handled here, once, for every caller: when n does not divide the
shard count, X is zero-padded up to the next multiple and the pad rows are
masked out via the backends' existing ``row_mask`` contract — masked rows
contribute EXACTLY zero, so the padded distributed sweep is bit-identical to
the unpadded math (tested in tests/test_distributed.py).

**Communication accounting.** ``psums`` / ``psum_floats`` count, at Python
trace time, every collective this backend issues and the elements it moves —
the seam behind the acceptance claim "one (M, p) psum per sweep and nothing
else". Like ``CountingOps`` (which composes with this class on either side),
these are program-point counts: a sweep traced once inside the scanned CG
driver counts once however many iterations replay it.

**Wire compression (opt-in).** ``compress="int8"`` rounds each device's
(M, p) partial through int8 symmetric quantization (one scale per partial,
``repro.distributed.compression``) before the psum — the same
bound-the-wire-precision hook the LM trainer applies to gradients. The psum
itself still reduces in the accumulate dtype (per-device scales differ, so
the int8 payloads cannot be summed directly); what the hook bounds is the
precision each partial crosses the wire with, adding a quantization error of
at most ``max|w_local| / 127`` per shard (parity-tested). Off by default:
an (M, p) partial is tiny next to the O(n_local * M) sweep it follows, so
this only pays on very slow interconnects or very fat L*p path blocks.

Construction — either wrap explicitly, or let the config do it:

    ops = DistributedOps(get_ops("pallas", kernel), mesh, ("data",))
    est, _ = falkon_fit(key, X, y, FalkonConfig(ops_impl="pallas",
                                                mesh=mesh))

``FalkonConfig(mesh=...)`` routes every fit variant — ``falkon_fit``,
``falkon_fit_path``, ``falkon_fit_streaming`` and the path-streaming fit —
through this wrapper via ``config.make_ops()``; none of them contain any
mesh-specific code of their own.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .base import KernelOps, SweepPlan

Array = jax.Array

#: Wire formats ``compress=`` accepts (None = fp32/accumulate-width psum).
COMPRESSIONS = (None, "int8")


def _pad_rows(a: Array, rows: int) -> Array:
    """Zero-pad axis 0 of ``a`` up to ``rows`` (no-op when already there)."""
    if a.shape[0] == rows:
        return a
    return jnp.pad(a, ((0, rows - a.shape[0]),) + ((0, 0),) * (a.ndim - 1))


class DistributedOps:
    """Data-parallel :class:`KernelOps` over the mesh data axes.

    Wraps ``inner`` (any registered backend — or a ``CountingOps`` around
    one, the instrumentation seam) and runs its primitives shard-locally:
    one device sweeps one row shard, one psum merges the (M, p) partials.
    Not registered by name: a backend instance needs a live ``Mesh``, which
    a registry string cannot carry — construct it directly or through
    ``FalkonConfig(mesh=..., data_axes=...)``.
    """

    def __init__(
        self,
        inner: KernelOps,
        mesh,
        data_axes=("data",),
        *,
        compress: str | None = None,
    ):
        data_axes = tuple(data_axes)
        if not data_axes:
            raise ValueError("data_axes must name at least one mesh axis")
        missing = [a for a in data_axes if a not in mesh.shape]
        if missing:
            raise ValueError(
                f"data axes {missing} not in mesh axes {tuple(mesh.shape)}"
            )
        if compress not in COMPRESSIONS:
            raise ValueError(
                f"unknown compress {compress!r}; supported: {COMPRESSIONS}"
            )
        self.inner = inner
        self.mesh = mesh
        self.data_axes = data_axes
        self.compress = compress
        self.psums = 0          # collectives issued (trace-time count)
        self.psum_floats = 0    # elements moved across those collectives

    # -- delegated static attributes (KernelOps protocol surface) ----------
    @property
    def kernel(self) -> Any:
        return self.inner.kernel

    @property
    def block_size(self) -> int:
        return self.inner.block_size

    @property
    def precision(self):
        return self.inner.precision

    @property
    def policy(self):
        return self.inner.policy

    @property
    def num_shards(self) -> int:
        """Total devices along the data axes (the row-shard count)."""
        return math.prod(self.mesh.shape[a] for a in self.data_axes)

    def reset_comm_stats(self) -> None:
        self.psums = self.psum_floats = 0

    # -- the three primitives ---------------------------------------------
    def _wire(self, w: Array) -> Array:
        """Apply the opt-in wire-compression round-trip to a local partial."""
        if self.compress is None:
            return w
        from repro.distributed.compression import (dequantize_int8, quantize_int8)
        q, scale = quantize_int8(w)
        return dequantize_int8(q, scale, w.dtype)

    def sweep(
        self,
        X: Array,
        C: Array,
        u: Array,
        v: Array | None = None,
        row_mask: Array | None = None,
    ) -> Array:
        """Shard-local sweeps + ONE (M, p) psum.

        X (and v / row_mask when given) split row-wise over the data axes;
        C and u are replicated. A ragged n is zero-padded up to the next
        multiple of the shard count with the pad rows masked out — the
        backends' ``row_mask`` contract makes their contribution exactly
        zero, so padding never changes the result. Every shard always
        carries a mask (all-ones when nothing is padded and no caller mask
        was given): one trace shape serves ragged and even n alike.
        """
        shards = self.num_shards
        n = X.shape[0]
        n_pad = -(-n // shards) * shards
        valid = (jnp.ones((n,), jnp.float32) if row_mask is None
                 else row_mask.astype(jnp.float32))
        mask = _pad_rows(valid, n_pad)
        X = _pad_rows(X, n_pad)
        if v is not None:
            v = _pad_rows(v, n_pad)

        inner, axes, wire = self.inner, self.data_axes, self._wire
        self.psums += 1
        p = u.shape[1] if u.ndim > 1 else 1
        self.psum_floats += C.shape[0] * p

        xspec = P(axes)
        if v is None:
            def local(Xl, C, u, ml):
                wl = inner.sweep(Xl, C, u, None, row_mask=ml)
                return jax.lax.psum(wire(wl), axes)

            fn = shard_map(
                local, mesh=self.mesh, in_specs=(xspec, P(), P(), xspec), out_specs=P()
            )
            return fn(X, C, u, mask)

        def local(Xl, C, u, vl, ml):
            wl = inner.sweep(Xl, C, u, vl, row_mask=ml)
            return jax.lax.psum(wire(wl), axes)

        fn = shard_map(
            local,
            mesh=self.mesh,
            in_specs=(xspec, P(), P(), xspec, xspec),
            out_specs=P(),
        )
        return fn(X, C, u, v, mask)

    def apply(self, X: Array, C: Array, u: Array) -> Array:
        """K(X, C) u with X row-sharded; no collective (apply is row-local).

        Pad rows (ragged n) produce garbage output rows on the last shard;
        they are sliced off after reassembly and valid rows are untouched —
        each output row depends only on its own X row.
        """
        shards = self.num_shards
        n = X.shape[0]
        n_pad = -(-n // shards) * shards
        Xp = _pad_rows(X, n_pad)
        inner = self.inner
        xspec = P(self.data_axes)

        def local(Xl, C, u):
            return inner.apply(Xl, C, u)

        fn = shard_map(
            local, mesh=self.mesh, in_specs=(xspec, P(), P()), out_specs=xspec
        )
        return fn(Xp, C, u)[:n]

    def gram(self, A: Array, B: Array) -> Array:
        """K(A, B) on replicated operands — the preconditioner's O(M^2)
        block needs no sharding and no communication; straight delegation
        (so Gram evaluation counts match single-device exactly)."""
        return self.inner.gram(A, B)

    # -- K_nM cache primitives (shard-local, zero extra comm) --------------
    def materialize(self, X: Array, C: Array) -> Array:
        """Each shard materializes ONLY its local row block of K_nM.

        X is zero-padded to a multiple of ``shards * block_size`` so every
        shard's slice is itself a whole number of tiles — the wrapped
        backend's ``materialize`` adds no further padding, and the
        row-sharded output keeps global row order (row i of K is row i of
        the padded X, exactly the single-device contract). No collective:
        the cache build is as embarrassingly row-parallel as the sweep it
        replaces.
        """
        shards = self.num_shards
        unit = shards * self.block_size
        n = X.shape[0]
        n_pad = -(-n // unit) * unit
        Xp = _pad_rows(X, n_pad)
        inner = self.inner
        xspec = P(self.data_axes)

        def local(Xl, C):
            return inner.materialize(Xl, C)

        fn = shard_map(
            local, mesh=self.mesh, in_specs=(xspec, P()), out_specs=xspec
        )
        return fn(Xp, C)

    def gemm_sweep(
        self,
        K: Array,
        u: Array,
        v: Array | None = None,
        row_mask: Array | None = None,
    ) -> Array:
        """Shard-local GEMM sweeps over the cached rows + ONE (M, p) psum —
        identical communication accounting to the recompute ``sweep`` (the
        psum invariants the distributed tests pin are unchanged by
        caching). ``K`` comes from :meth:`materialize`; ``v``/``row_mask``
        must already be padded to its row count (the ``KernelCache`` owner
        folds the pad mask in)."""
        shards = self.num_shards
        rows = K.shape[0]
        if rows % (shards * self.block_size) != 0:
            raise ValueError(
                f"cached K has {rows} rows, not a multiple of shards * "
                f"block_size = {shards * self.block_size}; build it with "
                f"this wrapper's materialize()")
        mask = (jnp.ones((rows,), jnp.float32) if row_mask is None
                else row_mask.astype(jnp.float32))
        inner, axes, wire = self.inner, self.data_axes, self._wire
        self.psums += 1
        p = u.shape[1] if u.ndim > 1 else 1
        self.psum_floats += K.shape[1] * p
        xspec = P(axes)
        if v is None:
            def local(Kl, u, ml):
                wl = inner.gemm_sweep(Kl, u, None, ml)
                return jax.lax.psum(wire(wl), axes)

            fn = shard_map(
                local, mesh=self.mesh, in_specs=(xspec, P(), xspec),
                out_specs=P(),
            )
            return fn(K, u, mask)

        def local(Kl, u, vl, ml):
            wl = inner.gemm_sweep(Kl, u, vl, ml)
            return jax.lax.psum(wire(wl), axes)

        fn = shard_map(
            local, mesh=self.mesh, in_specs=(xspec, P(), xspec, xspec),
            out_specs=P(),
        )
        return fn(K, u, v, mask)

    def gemm_apply(self, K: Array, u: Array) -> Array:
        """K u over the sharded cache — row-local, no collective; returns
        ALL cached rows (pad rows included), the mixin contract the cache
        slices back to n."""
        inner = self.inner
        xspec = P(self.data_axes)

        def local(Kl, u):
            return inner.gemm_apply(Kl, u)

        fn = shard_map(
            local, mesh=self.mesh, in_specs=(xspec, P()), out_specs=xspec
        )
        return fn(K, u)

    def plan(self, n: int, M: int, d: int, p: int = 1, systems: int = 1) -> SweepPlan:
        """The wrapped backend's routing decision for ONE shard's rows.

        The planner budgets ``n_local = ceil(n/shards)``: each device sees
        only its shard, so fused/two_pass/j_sharded routing (and the VMEM
        numbers behind it) are a per-shard question — sharding n never
        changes the M-axis routing, but it is what keeps the per-device
        working set (and the streaming chunk budget) at n/shards.
        """
        n_local = -(-max(n, 1) // self.num_shards)
        return self.inner.plan(n_local, M, d, p, systems)
