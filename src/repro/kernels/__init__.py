"""Pallas TPU kernels for the paper's compute hot-spot (the K_nM sweeps).

kernel_matvec.py — pl.pallas_call kernels (BlockSpec VMEM tiling), including
                   the single-pass fused sweep ``fused_sweep_pallas`` and the
                   out-of-core j-sharded sweep ``sharded_sweep_pallas``
ops.py           — jit'd wrappers (interpret=True off-TPU), KernelSpec-keyed
ref.py           — pure-jnp oracles

The user-facing entry point is the ``repro.ops`` backend layer (KernelOps),
which selects between these kernels and the jnp reference path by name.
"""
from .ops import (
    fused_knm_matvec,
    kernel_matmul,
    pairwise_kernel,
    sharded_knm_matvec,
    two_pass_knm_matvec,
)
