"""Pallas TPU kernels for the paper's compute hot-spot (the K_nM sweeps).

kernel_matvec.py — pl.pallas_call kernels (BlockSpec VMEM tiling)
ops.py           — jit'd wrappers (interpret=True off-TPU)
ref.py           — pure-jnp oracles
"""
from .ops import fused_knm_matvec, kernel_matmul, pairwise_kernel
