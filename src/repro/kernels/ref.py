"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth).

Kernel math comes from the same ``tile_transform`` registry the Pallas bodies
use (``repro.core.kernels``), so an oracle/kernel mismatch can only be a
tiling/masking bug, never a formula drift.
"""
from __future__ import annotations

import jax

from repro.core.kernels import KernelSpec, tile_eval

Array = jax.Array


def _spec(kind: str, scale: float) -> KernelSpec:
    if kind in ("gaussian", "laplacian", "matern32"):
        return KernelSpec(kind, (("sigma", scale),))
    raise ValueError(
        f"legacy (kind, scale) interface supports only the sigma kernels; "
        f"use tile_eval with a full KernelSpec for {kind!r}")


def kernel_tile(A: Array, B: Array, kind: str, scale: float) -> Array:
    """K(A, B) for any registered kernel kind."""
    return tile_eval(_spec(kind, scale), A, B)


def kernel_matmul_ref(A: Array, B: Array, V: Array, kind: str, scale: float) -> Array:
    """out = K(A, B) @ V  — the primitive both FALKON sweeps reduce to."""
    return kernel_tile(A, B, kind, scale) @ V


def fused_knm_matvec_ref(
    X: Array, C: Array, u: Array, v: Array | None, kind: str, scale: float
) -> Array:
    """w = K(X,C)^T (K(X,C) u + v) — one full FALKON CG sweep."""
    K = kernel_tile(X, C, kind, scale)
    t = K @ u if v is None else K @ u + v
    return K.T @ t


def pairwise_kernel_ref(A: Array, B: Array, kind: str, scale: float) -> Array:
    return kernel_tile(A, B, kind, scale)
