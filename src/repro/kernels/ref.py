"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _sqdist(A: Array, B: Array) -> Array:
    a2 = jnp.sum(A * A, axis=-1, keepdims=True)
    b2 = jnp.sum(B * B, axis=-1, keepdims=True).T
    return jnp.maximum(a2 + b2 - 2.0 * (A @ B.T), 0.0)


def kernel_tile(A: Array, B: Array, kind: str, scale: float) -> Array:
    """K(A, B) for the kernels the Pallas path supports."""
    sq = _sqdist(A, B)
    if kind == "gaussian":
        return jnp.exp(-0.5 / (scale * scale) * sq)
    if kind == "laplacian":
        return jnp.exp(-jnp.sqrt(sq + 1e-12) / scale)
    if kind == "matern32":
        a = jnp.sqrt(3.0) * jnp.sqrt(sq + 1e-12) / scale
        return (1.0 + a) * jnp.exp(-a)
    raise ValueError(kind)


def kernel_matmul_ref(A: Array, B: Array, V: Array, kind: str,
                      scale: float) -> Array:
    """out = K(A, B) @ V  — the primitive both FALKON sweeps reduce to."""
    return kernel_tile(A, B, kind, scale) @ V


def fused_knm_matvec_ref(X: Array, C: Array, u: Array, v: Array | None,
                         kind: str, scale: float) -> Array:
    """w = K(X,C)^T (K(X,C) u + v) — one full FALKON CG sweep."""
    K = kernel_tile(X, C, kind, scale)
    t = K @ u if v is None else K @ u + v
    return K.T @ t


def pairwise_kernel_ref(A: Array, B: Array, kind: str, scale: float) -> Array:
    return kernel_tile(A, B, kind, scale)
