"""jit'd wrappers around the Pallas kernels, with jnp fallback.

``fused_knm_matvec`` is the drop-in replacement for
``repro.core.matvec.knm_matvec`` (selected via FalkonConfig.matvec_impl =
"pallas"): one FALKON CG sweep ``w = K_nM^T (K_nM u + v)`` as two kernel
matmuls. On non-TPU backends the kernels run in interpret mode (Python
emulation — correctness only); on TPU they compile to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel_matvec import kernel_matmul_pallas, pairwise_kernel_pallas

Array = jax.Array

_SUPPORTED = ("gaussian", "laplacian", "matern32")


def _kernel_kind_scale(kernel) -> tuple[str, float]:
    name = type(kernel).__name__.lower()
    for kind in _SUPPORTED:
        if kind.replace("32", "") in name or kind in name:
            return kind, float(getattr(kernel, "sigma"))
    raise ValueError(
        f"pallas matvec supports {_SUPPORTED}, got {type(kernel).__name__}; "
        "use matvec_impl='jnp'")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_knm_matvec(
    X: Array, C: Array, u: Array, v: Array | None, kernel, *,
    block_size: int = 2048,
) -> Array:
    """w = K(X,C)^T (K(X,C) u + v), Gram tiles VMEM-resident only."""
    kind, scale = _kernel_kind_scale(kernel)
    squeeze = u.ndim == 1
    u2 = u[:, None] if squeeze else u
    t = kernel_matmul_pallas(X, C, u2, kind=kind, scale=scale,
                             block_m=min(block_size, 256),
                             interpret=_interpret())
    if v is not None:
        t = t + (v[:, None] if squeeze else v)
    w = kernel_matmul_pallas(C, X, t, kind=kind, scale=scale,
                             block_m=min(block_size, 256),
                             interpret=_interpret())
    return w[:, 0] if squeeze else w


def kernel_matmul(A: Array, B: Array, V: Array, kernel, *,
                  block_m: int = 256, block_n: int = 512) -> Array:
    kind, scale = _kernel_kind_scale(kernel)
    squeeze = V.ndim == 1
    V2 = V[:, None] if squeeze else V
    out = kernel_matmul_pallas(A, B, V2, kind=kind, scale=scale,
                               block_m=block_m, block_n=block_n,
                               interpret=_interpret())
    return out[:, 0] if squeeze else out


def pairwise_kernel(A: Array, B: Array, kernel, *,
                    block_m: int = 256, block_n: int = 256) -> Array:
    """K(A, B) materialized (preconditioner's K_MM builder)."""
    kind, scale = _kernel_kind_scale(kernel)
    return pairwise_kernel_pallas(A, B, kind=kind, scale=scale,
                                  block_m=block_m, block_n=block_n,
                                  interpret=_interpret())
