"""jit'd wrappers around the Pallas kernels, with interpret-mode fallback.

This module is the thin waist between the ``repro.ops`` backend layer (see
``repro/ops/pallas_backend.py``) and the raw ``pl.pallas_call`` kernels in
``kernel_matvec.py``. Kernels are identified by their declarative
``KernelSpec`` (``repro.core.kernels.spec_of``) — there is no class-name
sniffing and no per-backend list of supported kernels: anything registered in
``core/kernels.py`` runs here.

``fused_knm_matvec`` is the single-pass FALKON sweep
``w = K_nM^T (K_nM u + v)``: each Gram tile is computed once in VMEM and used
for both the forward product and the transposed accumulation
(``fused_sweep_pallas``). ``two_pass_knm_matvec`` keeps the legacy
two-kernel-matmul composition (every Gram tile evaluated twice) for A/B
benchmarking — see ``benchmarks/sweep_fusion.py``. On non-TPU backends the
kernels run in interpret mode (Python emulation — correctness only); on TPU
they compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.core.kernels import spec_of
from .kernel_matvec import (
    fused_sweep_pallas,
    kernel_matmul_pallas,
    pairwise_kernel_pallas,
    sharded_sweep_pallas,
)

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_knm_matvec(
    X: Array,
    C: Array,
    u: Array,
    v: Array | None,
    kernel,
    *,
    block_size: int = 2048,
) -> Array:
    """w = K(X,C)^T (K(X,C) u + v), single pass, Gram tiles VMEM-resident
    only and evaluated exactly once each."""
    return fused_sweep_pallas(
        X,
        C,
        u,
        v,
        spec=spec_of(kernel),
        block_m=min(block_size, 256),
        interpret=_interpret(),
    )


def sharded_knm_matvec(
    X: Array,
    C: Array,
    u: Array,
    v: Array | None,
    kernel,
    *,
    shard_m: int = 8192,
    block_size: int = 2048,
) -> Array:
    """Out-of-core sweep for M past the fused kernel's VMEM reach: forward
    product spilled to HBM, then per-C-shard transposed passes (2 Gram
    evaluations per tile, O(tile) VMEM — see ``sharded_sweep_pallas``)."""
    return sharded_sweep_pallas(
        X,
        C,
        u,
        v,
        spec=spec_of(kernel),
        shard_m=shard_m,
        block_m=min(block_size, 256),
        interpret=_interpret(),
    )


def two_pass_knm_matvec(
    X: Array,
    C: Array,
    u: Array,
    v: Array | None,
    kernel,
    *,
    block_size: int = 2048,
) -> Array:
    """Legacy sweep as two kernel matmuls (K(X,C) @ u then K(C,X) @ t, using
    K^T(X,C) = K(C,X)). Evaluates every Gram tile twice — kept only as the
    baseline the fused kernel is benchmarked against."""
    spec = spec_of(kernel)
    squeeze = u.ndim == 1
    u2 = u[:, None] if squeeze else u
    t = kernel_matmul_pallas(
        X, C, u2, spec=spec, block_m=min(block_size, 256), interpret=_interpret()
    )
    if v is not None:
        t = t + (v[:, None] if squeeze else v)
    w = kernel_matmul_pallas(
        C, X, t, spec=spec, block_m=min(block_size, 256), interpret=_interpret()
    )
    return w[:, 0] if squeeze else w


def kernel_matmul(
    A: Array, B: Array, V: Array, kernel, *, block_m: int = 256, block_n: int = 512
) -> Array:
    """out = K(A, B) @ V (the prediction path's primitive)."""
    squeeze = V.ndim == 1
    V2 = V[:, None] if squeeze else V
    out = kernel_matmul_pallas(
        A,
        B,
        V2,
        spec=spec_of(kernel),
        block_m=block_m,
        block_n=block_n,
        interpret=_interpret(),
    )
    return out[:, 0] if squeeze else out


def pairwise_kernel(
    A: Array, B: Array, kernel, *, block_m: int = 256, block_n: int = 256
) -> Array:
    """K(A, B) materialized (preconditioner's K_MM builder)."""
    return pairwise_kernel_pallas(
        A,
        B,
        spec=spec_of(kernel),
        block_m=block_m,
        block_n=block_n,
        interpret=_interpret(),
    )
