"""Tiled right-looking blocked Cholesky — the out-of-core factor path.

``make_preconditioner`` historically did ONE in-core ``jnp.linalg.cholesky``
on the dense (M, M) regularized Gram. FALKON's statistical optimality wants
M ~ sqrt(n) Nystrom centers, so the dense factor is the first wall the
preconditioner hits as n grows: 1 GB fp32 at M = 16384, 40 GB at M = 10^5.
This module factors the matrix while keeping it HOST-resident, moving only
O(block * M) panel bytes onto the device at any moment.

Algorithm (right-looking, by column panels of width b = ``block``):

    for panel k over the (b, b) tile grid:
        POTRF   L_kk          = chol(A_kk)            — one (b, b) tile
        TRSM    L_panel       = A[below, k] L_kk^{-T} — (rows, b) panel
        SYRK    A[j:, j]     -= L[j:, k] L[j, k]^T    — trailing update,
                                                        per column block j > k

The factor accumulates in a host numpy working buffer; each step uploads one
panel, runs the tile math on device, copies the result back and ``delete()``s
the device buffers, so the device working set is two (M, b) panels plus the
update's output tile — the O(b * M) bound ``FactorPlan.device_ceiling_bytes``
models and ``tests/test_blocked_cholesky.py`` measures via
``jax.live_arrays()``.

Two interchangeable TILE ENGINES supply the three per-tile primitives:

* ``"jnp"``    — BLAS-backed ``jnp.linalg.cholesky`` / ``solve_triangular`` /
                 matmul per tile. Default off-TPU; the numerical ground truth.
* ``"pallas"`` — Pallas kernels (masked-column in-VMEM POTRF/TRSM, gridded
                 SYRK update) following the ``repro.kernels.kernel_matvec``
                 idioms. Default on TPU; interpret-mode on CPU for parity
                 tests (``tile_impl="auto"`` picks per backend).

Tiles compute in float32 at minimum — the ``PrecisionPolicy`` ``cholesky``
override's fp32 floor (quantized factors destabilize preconditioned CG; the
PR 3 measured constraint) — and in float64 when the input is float64 and x64
is enabled. Conventions match the preconditioner stack: ``blocked_cholesky``
returns the UPPER factor T with A = T^T T (the repo-wide ``chol(...).T``
convention), as host numpy; callers move it to device for solve time, which
is the remaining O(M^2) device-residency ceiling (documented in
``docs/architecture.md``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128   # MXU/VREG lane width — last-dim tile alignment
SUBLANE = 8  # fp32 sublane granularity

TILE_IMPLS = ("auto", "jnp", "pallas")


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_tile_impl(tile_impl: str) -> str:
    """Resolve ``"auto"`` to the per-backend default engine."""
    if tile_impl not in TILE_IMPLS:
        raise ValueError(f"unknown tile_impl {tile_impl!r}; supported: {TILE_IMPLS}")
    if tile_impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return tile_impl


# ---------------------------------------------------------------------------
# Device-residency accounting
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FactorStats:
    """Self-accounted device residency of one blocked factorization.

    Every device buffer the driver creates is charged on upload and credited
    when it is copied back and ``delete()``d, so ``peak_device_bytes`` is the
    algorithmic working set (panels + tiles), comparable against
    ``FactorPlan.device_ceiling_bytes``. Tests cross-check it against the
    ground truth (``jax.live_arrays()`` deltas sampled from ``on_step``).
    """

    peak_device_bytes: int = 0
    current_device_bytes: int = 0
    bytes_transferred: int = 0   # host<->device traffic, both directions
    panels: int = 0              # column panels factored
    tiles_updated: int = 0       # trailing (rows, b) update tiles

    def alloc(self, nbytes: int) -> None:
        self.current_device_bytes += nbytes
        self.bytes_transferred += nbytes
        self.peak_device_bytes = max(self.peak_device_bytes, self.current_device_bytes)

    def free(self, nbytes: int) -> None:
        self.current_device_bytes -= nbytes
        self.bytes_transferred += nbytes


def _put(stats: FactorStats, host_block: np.ndarray, dt) -> jax.Array:
    dev = jax.device_put(np.ascontiguousarray(np.asarray(host_block, dt)))
    dev.block_until_ready()
    stats.alloc(dev.nbytes)
    return dev


def _take(stats: FactorStats, dev: jax.Array) -> np.ndarray:
    """Copy a device buffer back to host and release it."""
    host = np.array(dev)  # forced copy — safe to delete the backing buffer
    stats.free(dev.nbytes)
    dev.delete()
    return host


def _drop(stats: FactorStats, dev: jax.Array) -> None:
    stats.free(dev.nbytes)
    dev.delete()


# ---------------------------------------------------------------------------
# Pallas tile kernels
# ---------------------------------------------------------------------------
# All three follow the kernel_matvec idioms: 2-D broadcasted_iota only (1-D
# iota is banned on TPU), fori_loop carries instead of in-place mutation,
# float32 (or float64 in interpret mode) math throughout the tile.

def _potrf_kernel(a_ref, o_ref):
    """In-VMEM unblocked Cholesky of one (b, b) tile: A = L L^T, emit L.

    Masked-column iteration: the loop carries the partial factor L and at
    column j forms  v = A[:, j] - L[:, :j] @ L[j, :j]^T  using ``where``
    masks built from 2-D iotas (no dynamic slicing inside the kernel), then
    writes column j as [0; d; v_below / d] with d = sqrt(v_j)."""
    A = a_ref[...]
    b = A.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)

    def body(j, L):
        pref = jnp.where(cols < j, L, 0.0)            # L[:, :j], zero-extended
        lj = jnp.sum(jnp.where(rows == j, pref, 0.0), axis=0,
                     keepdims=True)                   # row j of the prefix
        acol = jnp.sum(jnp.where(cols == j, A, 0.0), axis=1,
                       keepdims=True)                 # A[:, j] as (b, 1)
        v = acol - jnp.sum(pref * lj, axis=1, keepdims=True)
        d = jnp.sum(jnp.where(rows[:, :1] == j, v, 0.0))  # v[j]
        # A non-positive pivot means the tile is not SPD (insufficient
        # jitter); propagate NaN so the failure is as observable as the
        # in-core jnp.linalg.cholesky path's, rather than clamping to a
        # finite garbage factor.
        d = jnp.sqrt(jnp.where(d > 0, d, jnp.nan))
        colv = jnp.where(rows[:,:1] == j, d, jnp.where(rows[:,:1] > j, v / d, 0.0))
        return jnp.where(cols == j, colv, L)

    o_ref[...] = jax.lax.fori_loop(0, b, body, jnp.zeros_like(A))


def _trsm_kernel(l_ref, a_ref, o_ref):
    """One (bt, b) panel tile of  X = A L^{-T}  (i.e. solve X L^T = A).

    Forward substitution over columns with the same iota-mask carry trick:
    X[:, j] = (A[:, j] - X[:, :j] @ L[j, :j]^T) / L[j, j]."""
    L = l_ref[...]
    A = a_ref[...]
    b = L.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    xcols = jax.lax.broadcasted_iota(jnp.int32, A.shape, 1)

    def body(j, X):
        lj = jnp.sum(jnp.where(rows == j, jnp.where(cols < j, L, 0.0), 0.0),
                     axis=0, keepdims=True)           # L[j, :j] as (1, b)
        djj = jnp.sum(jnp.where((rows == j) & (cols == j), L, 0.0))
        acol = jnp.sum(jnp.where(xcols == j, A, 0.0), axis=1, keepdims=True)
        xpref = jnp.where(xcols < j, X, 0.0)
        v = (acol - jnp.sum(xpref * lj, axis=1, keepdims=True)) / djj
        return jnp.where(xcols == j, v, X)

    o_ref[...] = jax.lax.fori_loop(0, b, body, jnp.zeros_like(A))


def _update_kernel(c_ref, p_ref, q_ref, o_ref):
    """One (bt, b) tile of the trailing update  C - P Q^T  (SYRK/GEMM)."""
    o_ref[...] = c_ref[...] - jax.lax.dot_general(
        p_ref[...],
        q_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=c_ref.dtype,
    )


def _pad_identity(A: jax.Array, bp: int) -> jax.Array:
    """Pad a (b, b) SPD tile to (bp, bp) with an identity tail block, so its
    Cholesky factor is the original factor plus an identity tail."""
    b = A.shape[0]
    if bp == b:
        return A
    P = jnp.pad(A, ((0, bp - b), (0, bp - b)))
    r = jax.lax.broadcasted_iota(jnp.int32, (bp, bp), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (bp, bp), 1)
    return jnp.where((r == c) & (r >= b), jnp.ones((), P.dtype), P)


@partial(jax.jit, static_argnames=("interpret",))
def _pallas_potrf(A, *, interpret: bool):
    b = A.shape[0]
    bp = _round_up(b, LANE)
    Ap = _pad_identity(A, bp)
    L = pl.pallas_call(
        _potrf_kernel,
        out_shape=jax.ShapeDtypeStruct((bp, bp), A.dtype),
        interpret=interpret,
    )(Ap)
    return L[:b,:b]


@partial(jax.jit, static_argnames=("interpret",))
def _pallas_trsm(L, A, *, interpret: bool):
    b = L.shape[0]
    r = A.shape[0]
    bp = _round_up(b, LANE)
    bt = min(_round_up(r, SUBLANE), 1024)
    rp = _round_up(r, bt)
    Lp = _pad_identity(jnp.tril(L), bp)
    Ap = jnp.pad(A, ((0, rp - r), (0, bp - b)))
    X = pl.pallas_call(
        _trsm_kernel,
        grid=(rp // bt,),
        in_specs=[pl.BlockSpec((bp, bp), lambda i: (0, 0)),
                  pl.BlockSpec((bt, bp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, bp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, bp), A.dtype),
        interpret=interpret,
    )(Lp, Ap)
    return X[:r,:b]


@partial(jax.jit, static_argnames=("interpret",))
def _pallas_update(C, P, Q, *, interpret: bool):
    # The output width b (C's columns — ragged on the last block) and the
    # contraction width k (P/Q's columns — the FACTOR panel width) are
    # independent: in the trailing update of a ragged final block, k can
    # exceed b. Pad each to its own lane-aligned size or the contraction
    # silently truncates to the first bp columns.
    r, b = C.shape
    k = P.shape[1]
    bp = _round_up(b, LANE)
    kp = _round_up(k, LANE)
    bt = min(_round_up(r, SUBLANE), 1024)
    rp = _round_up(r, bt)
    Cp = jnp.pad(C, ((0, rp - r), (0, bp - b)))
    Pp = jnp.pad(P, ((0, rp - r), (0, kp - k)))
    Qp = jnp.pad(Q, ((0, bp - Q.shape[0]), (0, kp - k)))
    O = pl.pallas_call(
        _update_kernel,
        grid=(rp // bt,),
        in_specs=[pl.BlockSpec((bt, bp), lambda i: (i, 0)),
                  pl.BlockSpec((bt, kp), lambda i: (i, 0)),
                  pl.BlockSpec((bp, kp), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bt, bp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, bp), C.dtype),
        interpret=interpret,
    )(Cp, Pp, Qp)
    return O[:r,:b]


# ---------------------------------------------------------------------------
# jnp tile engine (BLAS-backed; default off-TPU)
# ---------------------------------------------------------------------------
@jax.jit
def _jnp_potrf(A):
    return jnp.linalg.cholesky(A)


@jax.jit
def _jnp_trsm(L, A):
    return jax.scipy.linalg.solve_triangular(L, A.T, lower=True).T


@jax.jit
def _jnp_update(C, P, Q):
    return C - jax.lax.dot_general(
        P, Q, (((1,), (1,)), ((), ())), preferred_element_type=C.dtype
    )


def _engine(tile_impl: str):
    impl = resolve_tile_impl(tile_impl)
    if impl == "jnp":
        return _jnp_potrf, _jnp_trsm, _jnp_update
    interp = _interpret()
    return (
        partial(_pallas_potrf, interpret=interp),
        partial(_pallas_trsm, interpret=interp),
        partial(_pallas_update, interpret=interp),
    )


def _host_compute_dtypes(K) -> tuple[np.dtype, jnp.dtype]:
    """(host working dtype, device tile dtype) for an input matrix.

    float32 floor always (the policy ``cholesky`` override); float64 tiles
    only when the input is float64 AND x64 is enabled — otherwise device
    math runs fp32 exactly like the in-core ``jnp.linalg.cholesky`` would,
    keeping blocked-vs-in-core parity an apples-to-apples comparison."""
    host_dt = np.float64 if np.dtype(K.dtype) == np.float64 else np.float32
    if host_dt == np.float64 and jax.config.jax_enable_x64:
        return host_dt, jnp.float64
    return host_dt, jnp.float32


# ---------------------------------------------------------------------------
# The host-blocked driver
# ---------------------------------------------------------------------------
def blocked_cholesky(
    K,
    block: int = 1024,
    *,
    tile_impl: str = "auto",
    stats: FactorStats | None = None,
    on_step=None,
) -> np.ndarray:
    """Factor a host-resident SPD matrix, returning the UPPER factor T
    (A = T^T T — the repo's ``chol(...).T`` convention) as host numpy.

    ``K`` is any (M, M) SPD array-like (numpy or jax; a jax input is copied
    to host once up front — callers who want true out-of-core behavior pass
    host numpy, as ``_shared_factor`` does). Jitter is the CALLER's job:
    this routine factors exactly what it is given.

    Device residency: at most one (rows, b) factor panel + one (rows, b)
    trailing tile (+ the update's output) live at once; every buffer is
    copied back and deleted before the next panel. ``stats`` (a
    :class:`FactorStats`) receives the self-accounted peak; ``on_step`` (a
    ``callable(stage: str, stats)``) fires at the residency high-water
    points so tests can sample ``jax.live_arrays()`` for the ground truth.
    """
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    stats = stats if stats is not None else FactorStats()
    step = on_step if on_step is not None else (lambda stage, s: None)
    potrf, trsm, update = _engine(tile_impl)
    host_dt, dev_dt = _host_compute_dtypes(K)

    W = np.array(K, dtype=host_dt, copy=True)
    if W.ndim != 2 or W.shape[0] != W.shape[1]:
        raise ValueError(f"expected a square matrix, got shape {W.shape}")
    M = W.shape[0]
    nb = -(-M // block)

    for k in range(nb):
        i0, i1 = k * block, min((k + 1) * block, M)
        stats.panels += 1

        # POTRF the (b, b) diagonal tile, TRSM the rows below it, and land
        # both back in W's lower triangle before touching the trailing
        # matrix — no factor bytes stay device-resident between phases.
        Akk = _put(stats, W[i0:i1, i0:i1], dev_dt)
        Lkk = potrf(Akk)
        Lkk.block_until_ready()
        stats.alloc(Lkk.nbytes)
        _drop(stats, Akk)
        if i1 < M:
            Ak = _put(stats, W[i1:, i0:i1], dev_dt)
            Lpanel = trsm(Lkk, Ak)
            Lpanel.block_until_ready()
            stats.alloc(Lpanel.nbytes)
            _drop(stats, Ak)
            step("panel", stats)
            W[i1:, i0:i1] = _take(stats, Lpanel)
        W[i0:i1, i0:i1] = _take(stats, Lkk)

        # Trailing update, one column block at a time: each step holds one
        # (rows, b) slice of the fresh factor panel, its (b, b) top, and
        # one (rows, b) trailing tile — the O(b * M) working set.
        for j in range(k + 1, nb):
            j0, j1 = j * block, min((j + 1) * block, M)
            P = _put(stats, W[j0:, i0:i1], dev_dt)
            Q = _put(stats, W[j0:j1, i0:i1], dev_dt)
            Cj = _put(stats, W[j0:, j0:j1], dev_dt)
            Cn = update(Cj, P, Q)
            Cn.block_until_ready()
            stats.alloc(Cn.nbytes)
            stats.tiles_updated += 1
            step("update", stats)
            _drop(stats, Cj)
            _drop(stats, P)
            _drop(stats, Q)
            W[j0:, j0:j1] = _take(stats, Cn)

    # W's lower triangle now holds L (A = L L^T); strict upper still holds
    # stale input. Emit the upper-convention factor T = L^T.
    return np.ascontiguousarray(np.tril(W).T)


def blocked_syrk_tt(
    T: np.ndarray, block: int = 1024, *, stats: FactorStats | None = None
) -> np.ndarray:
    """Host-blocked  T T^T  for an UPPER-triangular host factor T.

    The lambda-independent half of the preconditioner's second stage
    (``A = chol(T T^T / M + lam I).T``) needs the full (M, M) product; this
    computes it panel-by-panel under the same O(b * M) device-residency
    contract. Upper-triangularity is exploited: rows i of T are supported on
    columns k >= i, so the (i, j) block pair (i >= j) only contracts over
    k >= i0 — the contraction shrinks as the row panel descends.
    """
    stats = stats if stats is not None else FactorStats()
    dev_dt = _host_compute_dtypes(T)[1]
    M = T.shape[0]
    nb = -(-M // block)
    out = np.empty((M, M), dtype=T.dtype)

    for i in range(nb):
        i0, i1 = i * block, min((i + 1) * block, M)
        R = _put(stats, T[i0:i1, i0:], dev_dt)       # (b, M - i0) row panel
        for j in range(i + 1):
            j0, j1 = j * block, min((j + 1) * block, M)
            S = _put(stats, T[j0:j1, i0:], dev_dt)
            D = jax.lax.dot_general(
                R, S, (((1,), (1,)), ((), ())), preferred_element_type=dev_dt
            )
            D.block_until_ready()
            stats.alloc(D.nbytes)
            _drop(stats, S)
            Dh = _take(stats, D)
            out[i0:i1, j0:j1] = Dh
            if i != j:
                out[j0:j1, i0:i1] = Dh.T
        _drop(stats, R)
    return out
