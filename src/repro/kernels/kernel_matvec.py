"""Pallas TPU kernels for FALKON's O(nMt) hot loop.

Two primitives:

* ``kernel_matmul_pallas`` — ``out = K(A, B) @ V`` with the Gram tile
  ``K(A_i, B_j)`` computed on the fly in VMEM (pairwise precursors via one MXU
  matmul ``A_i B_j^T`` plus row/col norms on the VPU, then the registered
  kernel's elementwise map) and immediately contracted against ``V_j`` on the
  MXU. The (bm x bn) Gram tile never touches HBM.

* ``fused_sweep_pallas`` — the whole FALKON CG sweep
  ``w = K(X,C)^T (K(X,C) u + v)`` in ONE pass over the data: for each (i, j)
  grid tile the Gram tile ``K(X_i, C_j)`` is computed exactly once, staged in
  a VMEM row-strip scratch, used for the forward product ``t_i += K_ij u_j``,
  and — once the row strip is complete — re-read from VMEM for the transposed
  accumulation ``w_j += K_ij^T t_i`` into a persistent fp32 VMEM accumulator.
  Versus composing two ``kernel_matmul_pallas`` calls this halves kernel-tile
  evaluations and HBM round-trips per CG iteration: every Gram entry is
  evaluated once and never re-materialized.

Kernel math is NOT duplicated here: both kernels evaluate tiles through
``repro.core.kernels.tile_transform`` keyed by a declarative ``KernelSpec``,
so every kernel registered in ``core/kernels.py`` (gaussian, laplacian,
matern32, linear, polynomial, ...) runs on the Pallas path with no
per-backend kernel lists.

Grid conventions: (i over A/X row tiles, j over B/C tiles), j minor.
Accumulators are fp32 VMEM scratch initialised on the first visit and flushed
on the last — the standard Pallas reduction pattern. Operands may be bf16
(``precision='bf16'`` upstream — under the end-to-end policy X, C, u, v AND
the outputs/HBM spills are all bfloat16): the distance/dot matmuls feed the
MXU in the input dtype with ``preferred_element_type=float32``, i.e.
bf16-in/fp32-accumulate. With ``compensated=True`` each accumulator carries a
same-shape Kahan/two-sum compensation buffer (``_two_sum``), so the tile-loop
reduction error stays O(eps_fp32) independent of the grid size — the
guarantee that makes bf16 storage safe at large n/M. Tile sizes default to
multiples of the 128-wide MXU systolic dimensions; wrappers pad every operand
to tile multiples and mask padded rows with in-kernel iota masks (no mask
operands in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kernels import KernelSpec, tile_transform

Array = jax.Array

LANE = 128   # MXU/VREG lane width — last-dim tile alignment
SUBLANE = 8  # fp32 sublane granularity


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _as_spec(kind: str, scale: float, spec: KernelSpec | None) -> KernelSpec:
    """Back-compat shim: legacy (kind, scale) callers -> KernelSpec.

    Only the sigma-kernels are expressible through the legacy signature;
    kernels with more params (polynomial's degree/c) must come in as a spec —
    defaulting them silently would compute the wrong Gram values.
    """
    if spec is not None:
        return spec
    if kind in ("gaussian", "laplacian", "matern32"):
        return KernelSpec(kind, (("sigma", scale),))
    raise ValueError(
        f"legacy (kind, scale) interface supports only the sigma kernels; "
        f"pass spec=KernelSpec(...) for {kind!r}")


def sweep_block_dims(n: int, M: int, block_m: int, block_n: int) -> tuple[int, int]:
    """(bm, bn) the fused sweep actually tiles with — the single source of
    the rounding policy, used by ``fused_sweep_pallas`` itself and by the
    grid/count derivations below."""
    bm = min(_round_up(block_m, SUBLANE), _round_up(n, SUBLANE))
    bn = min(_round_up(block_n, LANE), _round_up(M, LANE))
    return bm, bn


def sweep_tile_grid(n: int, M: int, block_m: int, block_n: int) -> tuple[int, int]:
    """(nbi, nbj) tile grid the fused sweep runs over for these shapes —
    benchmarks and tests derive expected Gram-tile evaluation counts from
    this: one per tile."""
    bm, bn = sweep_block_dims(n, M, block_m, block_n)
    return -(-n // bm), -(-M // bn)


def _two_sum(acc: Array, comp: Array, delta: Array) -> tuple[Array, Array]:
    """Kahan/two-sum compensated ``acc += delta``; returns (acc', comp').

    ``comp`` carries the low-order bits lost by each fp32 add; folding it
    into the next delta bounds the whole reduction's error at O(eps_fp32)
    instead of O(steps * eps_fp32). Pure arithmetic — safe inside Pallas
    bodies and lax.scan carries alike.
    """
    y = delta - comp
    t = acc + y
    return t, (t - acc) - y


def _tile(a, b, spec: KernelSpec) -> Array:
    """K(a, b) tile: one MXU matmul + VPU elementwise, fp32 accumulate."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    a2 = jnp.sum(af * af, axis=-1, keepdims=True)              # (bm, 1) VPU
    b2 = jnp.sum(bf * bf, axis=-1, keepdims=True).T            # (1, bn) VPU
    ab = jax.lax.dot_general(                                   # (bm, bn) MXU
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    return tile_transform(ab, a2, b2, spec)


# ---------------------------------------------------------------------------
# kernel matmul: out = K(A, B) @ V
# ---------------------------------------------------------------------------
def _kernel_matmul_kernel(
    a_ref,
    b_ref,
    v_ref,
    *rest,
    spec: KernelSpec,
    n_valid: int,
    bn: int,
    nbj: int,
    has_add: bool,
    compensated: bool,
):
    """One (i, j) grid step: acc_i += K(A_i, B_j) @ V_j (+ add_i at init).

    With ``compensated`` the j-loop reduction runs through a Kahan carry
    buffer (``_two_sum``) so bf16-policy sweeps keep O(eps_fp32) summation
    error regardless of the tile count.
    """
    if compensated:
        *rest, comp_ref = rest
    if has_add:
        add_ref, o_ref, acc_ref = rest
    else:
        o_ref, acc_ref = rest
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        if has_add:
            acc_ref[...] = add_ref[...].astype(jnp.float32)
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)
        if compensated:
            comp_ref[...] = jnp.zeros_like(comp_ref)

    # mask padded B rows: global column index >= n_valid has no data
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    bmask = (col < n_valid).astype(jnp.float32)
    k = _tile(a_ref[...], b_ref[...], spec) * bmask
    v = v_ref[...].astype(jnp.float32)
    delta = jax.lax.dot_general(                               # (bm, p) MXU
        k, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    if compensated:
        acc_ref[...], comp_ref[...] = _two_sum(acc_ref[...], comp_ref[...], delta)
    else:
        acc_ref[...] += delta

    @pl.when(j == nbj - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def kernel_matmul_pallas(
    A: Array,
    B: Array,
    V: Array,
    *,
    kind: str = "gaussian",
    scale: float = 1.0,
    spec: KernelSpec | None = None,
    add: Array | None = None,
    block_m: int = 256,
    block_n: int = 512,
    compensated: bool = False,
    out_dtype=None,
    interpret: bool = True,
) -> Array:
    """out = K(A, B) @ V (+ add) with on-the-fly Gram tiles.

    A: (m, d), B: (n, d), V: (n, p) -> (m, p). All shapes may be ragged; the
    wrapper pads to tile multiples and masks padded B rows. ``add`` is an
    optional (m, p) additive term folded into the accumulator at init — the
    j-sharded sweep uses it to fuse ``t = K u + v`` into one pass instead of
    spilling ``K u`` and re-reading it for the add. ``compensated`` switches
    the j-loop reduction to Kahan/two-sum fp32 (the bf16 policy's
    accumulation contract). ``out_dtype`` overrides the output dtype (the
    flush cast out of the fp32 accumulator); by default it follows the
    operands' promotion — the j-sharded sweep passes the policy's storage
    dtype so ``t`` spills to HBM at half width, and the coefficient dtype
    for the final w. The accumulator itself is always fp32 VMEM scratch.
    Pass either a ``spec`` (preferred) or legacy ``kind``/``scale``.
    ``interpret=True`` runs the kernel body in Python (CPU validation); on
    TPU pass False.
    """
    spec = _as_spec(kind, scale, spec)
    m, d = A.shape
    n, _ = B.shape
    p = V.shape[1]
    if out_dtype is None:
        out_dtype = jnp.promote_types(A.dtype, V.dtype)

    bm = min(_round_up(block_m, SUBLANE), _round_up(m, SUBLANE))
    bn = min(_round_up(block_n, LANE), _round_up(n, LANE))
    mp = _round_up(m, bm)
    np_ = _round_up(n, bn)
    dp = _round_up(d, LANE)
    pp = _round_up(p, LANE)

    Ap = jnp.pad(A, ((0, mp - m), (0, dp - d)))
    Bp = jnp.pad(B, ((0, np_ - n), (0, dp - d)))
    Vp = jnp.pad(V, ((0, np_ - n), (0, pp - p)))

    nbi, nbj = mp // bm, np_ // bn

    has_add = add is not None
    in_specs = [
        pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),          # A_i
        pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),          # B_j
        pl.BlockSpec((bn, pp), lambda i, j: (j, 0)),          # V_j
    ]
    operands = [Ap, Bp, Vp]
    if has_add:
        in_specs.append(pl.BlockSpec((bm, pp), lambda i, j: (i, 0)))  # add_i
        operands.append(jnp.pad(add, ((0, mp - m), (0, pp - p))))

    scratch = [pltpu.VMEM((bm, pp), jnp.float32)]             # fp32 accum
    if compensated:
        scratch.append(pltpu.VMEM((bm, pp), jnp.float32))     # Kahan carry
    out = pl.pallas_call(
        functools.partial(_kernel_matmul_kernel, spec=spec, n_valid=n,
                          bn=bn, nbj=nbj, has_add=has_add,
                          compensated=compensated),
        grid=(nbi, nbj),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, pp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, pp), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    return out[:m,:p]


# ---------------------------------------------------------------------------
# fused sweep: w = K(X, C)^T (K(X, C) u + v) in ONE pass over X
# ---------------------------------------------------------------------------
def _fused_sweep_kernel(
    x_ref,
    c_ref,
    u_ref,
    *rest,
    spec: KernelSpec,
    has_v: bool,
    has_mask: bool,
    compensated: bool,
    n_valid: int,
    m_valid: int,
    bm: int,
    bn: int,
    nbi: int,
    nbj: int,
):
    """One (i, j) grid step of the single-pass sweep.

    Per step: the Gram tile K_ij is computed ONCE, staged into the row-strip
    scratch ``strip[j]``, and folded into ``t_i += K_ij u_j``. When the strip
    for row block i is complete (j == nbj-1), ``t_i`` gains ``v_i``, padded X
    rows are masked (both the wrapper's shape padding via the in-kernel iota
    and, with ``has_mask``, the caller's explicit row mask — streamed tail
    chunks padded to a fixed shape), and the strip is swept a second time
    FROM VMEM for ``w_j += K_ij^T t_i`` — no kernel re-evaluation, no HBM
    round-trip.

    With ``compensated`` both reductions (t over the j tiles, w over the i
    row blocks) run through Kahan carry buffers, keeping the summation error
    at O(eps_fp32) independent of the grid — the bf16 policy's accumulation
    contract.
    """
    if compensated:
        *rest, tc_ref, wc_ref = rest
    mask_ref = None
    if has_mask:
        if has_v:
            v_ref, mask_ref, *rest = rest
        else:
            mask_ref, *rest = rest
        o_ref, cnt_ref, strip_ref, t_ref, w_ref = rest
    elif has_v:
        v_ref, o_ref, cnt_ref, strip_ref, t_ref, w_ref = rest
    else:
        o_ref, cnt_ref, strip_ref, t_ref, w_ref = rest
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init_w():
        w_ref[...] = jnp.zeros_like(w_ref)
        cnt_ref[0, 0] = 0
        if compensated:
            wc_ref[...] = jnp.zeros_like(wc_ref)

    @pl.when(j == 0)
    def _init_t():
        t_ref[...] = jnp.zeros_like(t_ref)
        if compensated:
            tc_ref[...] = jnp.zeros_like(tc_ref)

    # K_ij evaluated exactly once per (i, j): count it.
    col = j * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    cmask = (col < m_valid).astype(jnp.float32)                # pad cols of C
    k = _tile(x_ref[...], c_ref[...], spec) * cmask            # (bm, bn)
    strip_ref[j] = k
    u = u_ref[...].astype(jnp.float32)                         # (bn, p)
    t_delta = jax.lax.dot_general(                             # (bm, p) MXU
        k, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    if compensated:
        t_ref[...], tc_ref[...] = _two_sum(t_ref[...], tc_ref[...], t_delta)
    else:
        t_ref[...] += t_delta
    cnt_ref[0, 0] += 1

    @pl.when(j == nbj - 1)
    def _accumulate():
        t = t_ref[...]
        if has_v:
            t = t + v_ref[...].astype(jnp.float32)
        row = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
        t = t * (row < n_valid).astype(jnp.float32)            # pad rows of X
        if has_mask:
            # caller-supplied row mask (lane-padded; column 0 is the mask):
            # zeroing t_i zeroes the masked rows' K^T t contribution EXACTLY
            t = t * mask_ref[...][:,:1]

        def body(jj, _):
            delta = jax.lax.dot_general(                       # (bn, p) MXU
                strip_ref[jj], t, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            if compensated:
                w_ref[jj], wc_ref[jj] = _two_sum(w_ref[jj], wc_ref[jj], delta)
            else:
                w_ref[jj] += delta
            return 0

        jax.lax.fori_loop(0, nbj, body, 0)

    @pl.when((i == nbi - 1) & (j == nbj - 1))
    def _flush():
        o_ref[...] = w_ref[...].astype(o_ref.dtype)


def fused_sweep_pallas(
    X: Array,
    C: Array,
    u: Array,
    v: Array | None,
    *,
    spec: KernelSpec,
    row_mask: Array | None = None,
    block_m: int = 256,
    block_n: int = 512,
    compensated: bool = False,
    interpret: bool = True,
    return_tile_count: bool = False,
) -> Array | tuple[Array, Array]:
    """w = K(X,C)^T (K(X,C) u + v) — one fused pass, each Gram tile once.

    X: (n, d), C: (M, d), u: (M, p), v: (n, p) or None -> (M, p).
    ``row_mask`` (n,), 0/1: rows with mask 0 contribute EXACTLY zero to w
    (their t_i is zeroed before the transposed product) — how callers sweep
    a fixed-shape chunk whose tail rows are padding (see
    ``repro.data.streaming``) without a shape-changing slice.

    VMEM residency per step: one (bm, d) X tile, one (bn, d) C tile, the
    row-strip scratch (nbj, bm, bn) and the fp32 accumulator (nbj, bn, p) —
    i.e. O(bm * M + M * p) scratch, the paper's O(M) working-set budget times
    the block height. ``compensated`` adds Kahan carry buffers beside the t/w
    accumulators (two-sum fp32 — the bf16 policy's accumulation contract; the
    planner's budget model counts them). Output dtype follows the operands
    (bf16 in -> bf16 out under the end-to-end policy). With
    ``return_tile_count=True`` also returns the number of Gram-tile
    evaluations the kernel performed (an int32 scalar; equals
    ceil(n/bm) * ceil(M/bn) — exactly one evaluation per tile, which is the
    fusion claim and is asserted by tests/test_kernel_ops.py).
    """
    n, d = X.shape
    M, _ = C.shape
    squeeze = u.ndim == 1
    u2 = u[:, None] if squeeze else u
    v2 = None if v is None else (v[:, None] if squeeze else v)
    p = u2.shape[1]
    out_dtype = jnp.promote_types(X.dtype, u.dtype)

    bm, bn = sweep_block_dims(n, M, block_m, block_n)
    npad = _round_up(n, bm)
    Mpad = _round_up(M, bn)
    dp = _round_up(d, LANE)
    pp = _round_up(p, LANE)
    nbi, nbj = npad // bm, Mpad // bn

    Xp = jnp.pad(X, ((0, npad - n), (0, dp - d)))
    Cp = jnp.pad(C, ((0, Mpad - M), (0, dp - d)))
    up = jnp.pad(u2, ((0, Mpad - M), (0, pp - p)))

    has_v = v2 is not None
    has_mask = row_mask is not None
    in_specs = [
        pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),          # X_i
        pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),          # C_j
        pl.BlockSpec((bn, pp), lambda i, j: (j, 0)),          # u_j
    ]
    operands = [Xp, Cp, up]
    if has_v:
        vp = jnp.pad(v2, ((0, npad - n), (0, pp - p)))
        in_specs.append(pl.BlockSpec((bm, pp), lambda i, j: (i, 0)))  # v_i
        operands.append(vp)
    if has_mask:
        # (n,) -> (npad, LANE) with the mask in column 0 (lane-aligned
        # operand; the kernel reads [:, :1])
        mk = row_mask.astype(jnp.float32).reshape(n, 1)
        operands.append(jnp.pad(mk, ((0, npad - n), (0, LANE - 1))))
        in_specs.append(pl.BlockSpec((bm, LANE), lambda i, j: (i, 0)))

    scratch = [
        pltpu.VMEM((nbj, bm, bn), jnp.float32),   # Gram row strip
        pltpu.VMEM((bm, pp), jnp.float32),        # t_i = K_i u + v_i
        pltpu.VMEM((nbj, bn, pp), jnp.float32),   # fp32 w accumulator
    ]
    if compensated:
        scratch += [
            pltpu.VMEM((bm, pp), jnp.float32),        # t Kahan carry
            pltpu.VMEM((nbj, bn, pp), jnp.float32),   # w Kahan carry
        ]
    out, cnt = pl.pallas_call(
        functools.partial(
            _fused_sweep_kernel, spec=spec, has_v=has_v, has_mask=has_mask,
            compensated=compensated,
            n_valid=n, m_valid=M, bm=bm, bn=bn, nbi=nbi, nbj=nbj),
        grid=(nbi, nbj),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((nbj, bn, pp), lambda i, j: (0, 0, 0)),   # w
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),                 # tile count
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbj, bn, pp), out_dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)

    w = out.reshape(Mpad, pp)[:M,:p]
    if squeeze:
        w = w[:, 0]
    if return_tile_count:
        return w, cnt[0, 0]
    return w


# ---------------------------------------------------------------------------
# j-sharded sweep: out-of-core M — Gram never resident, t spilled to HBM
# ---------------------------------------------------------------------------
def sharded_sweep_pallas(
    X: Array,
    C: Array,
    u: Array,
    v: Array | None,
    *,
    spec: KernelSpec,
    row_mask: Array | None = None,
    shard_m: int = 8192,
    block_m: int = 256,
    block_n: int = 512,
    compensated: bool = False,
    t_dtype=None,
    out_dtype=None,
    interpret: bool = True,
) -> Array:
    """w = K(X,C)^T (K(X,C) u + v) for M far beyond the fused kernel's reach.

    The fused single-pass sweep holds a (bm, Mpad) Gram row strip plus the
    (Mpad, p) accumulator in VMEM, which caps M near ~8k at default tiles.
    Past that a tile cannot wait in VMEM for the final ``t_i`` it needs for
    the transposed product, so each Gram entry must be evaluated twice — the
    out-of-core schedule of Meanti et al. (2020). This variant does exactly
    that, in two Pallas phases with only O(tile) VMEM state:

    1. **forward** — ``t = K(X, C) u + v`` in one pass streaming C through
       (bn, d) tiles, the v-add fused into the accumulator init (no extra
       HBM round-trip for ``K u``); ``t`` (n, p) spills to HBM.
    2. **transpose, j-major** — the center axis is partitioned into
       ``shard_m``-row shards; each shard runs its own Pallas pass computing
       ``w_j = K(C_j, X) t`` with partial ``w_j`` accumulated per (bm, p)
       C-tile in VMEM and flushed to HBM when the tile's row sweep ends.
       The final reduction is the concatenation of the shard outputs.

    Per-phase VMEM is O(bm*d + bn*d + bm*p + bn*p) — independent of M and n —
    so M scales to 10^5+; ``shard_m`` only bounds the per-``pallas_call`` HBM
    workspace (each shard pads its C rows to lane multiples) and is picked by
    the planner in ``repro.ops.base``. Cost: 2 Gram evaluations per tile vs
    the fused kernel's 1 — the price of not holding the strip. Under the bf16
    policy ``t_dtype`` (the policy's storage dtype) makes the HBM-spilled
    ``t`` — the dominant O(n*p) HBM round-trip of this path — move at half
    width, while ``out_dtype`` (the policy's coefficient dtype) keeps the
    final M-sized w full precision.
    """
    M = C.shape[0]
    squeeze = u.ndim == 1
    u2 = u[:, None] if squeeze else u
    v2 = None if v is None else (v[:, None] if squeeze else v)

    t = kernel_matmul_pallas(
        X,
        C,
        u2,
        spec=spec,
        add=v2,
        block_m=block_m,
        block_n=block_n,
        compensated=compensated,
        out_dtype=t_dtype,
        interpret=interpret,
    )
    if row_mask is not None:
        # zeroing masked rows of the HBM-spilled t zeroes their K^T t
        # contribution EXACTLY (the transpose phase only ever reads t)
        t = t * row_mask.astype(t.dtype)[:, None]

    shard = max(int(shard_m), 1)
    ws = [
        kernel_matmul_pallas(C[j0:min(j0 + shard, M)], X, t, spec=spec,
                             block_m=block_m, block_n=block_n,
                             compensated=compensated, out_dtype=out_dtype,
                             interpret=interpret)
        for j0 in range(0, M, shard)
    ]
    w = ws[0] if len(ws) == 1 else jnp.concatenate(ws, axis=0)
    return w[:, 0] if squeeze else w


# ---------------------------------------------------------------------------
# pairwise kernel: K(A, B) materialized (preconditioner's K_MM builder)
# ---------------------------------------------------------------------------
def _pairwise_kernel(a_ref, b_ref, o_ref, *, spec: KernelSpec):
    o_ref[...] = _tile(a_ref[...], b_ref[...], spec).astype(o_ref.dtype)


def pairwise_kernel_pallas(
    A: Array,
    B: Array,
    *,
    kind: str = "gaussian",
    scale: float = 1.0,
    spec: KernelSpec | None = None,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = True,
) -> Array:
    """Materialize K(A, B) tile-by-tile (used to build K_MM for the
    preconditioner). Grid (i, j) with one output tile per step."""
    spec = _as_spec(kind, scale, spec)
    m, d = A.shape
    n, _ = B.shape
    bm = min(_round_up(block_m, SUBLANE), _round_up(m, SUBLANE))
    bn = min(_round_up(block_n, LANE), _round_up(n, LANE))
    mp = _round_up(m, bm)
    np_ = _round_up(n, bn)
    dp = _round_up(d, LANE)
    Ap = jnp.pad(A, ((0, mp - m), (0, dp - d)))
    Bp = jnp.pad(B, ((0, np_ - n), (0, dp - d)))

    out = pl.pallas_call(
        functools.partial(_pairwise_kernel, spec=spec),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), A.dtype),
        interpret=interpret,
    )(Ap, Bp)
    return out[:m,:n]
