"""Pallas TPU kernels for FALKON's O(nMt) hot loop.

The primitive is a *kernel matmul*: ``out = K(A, B) @ V`` with the Gram tile
``K(A_i, B_j)`` computed on the fly in VMEM (pairwise squared distances via one
MXU matmul ``-2 A_i B_j^T`` plus row/col norms on the VPU, then the kernel's
elementwise map) and immediately contracted against ``V_j`` on the MXU. The
(bm x bn) Gram tile never touches HBM — this is the paper's "compute K_nM in
blocks" insight mapped onto the HBM->VMEM->MXU hierarchy.

A full FALKON sweep ``w = K_nM^T (K_nM u + v)`` is two kernel matmuls
(K(X,C) @ u then K(C,X) @ t, using K^T(X,C) = K(C,X)) — see ops.py.

Grid: (i over A-tiles, j over B-tiles), j minor. The output block (indexed by
i only) is revisited across j and accumulated in a fp32 VMEM scratch,
initialised at j == 0 and flushed at j == last — the standard Pallas reduction
pattern. Tile sizes default to (256, 512) rows — multiples of the 128-wide MXU
systolic dimensions; the wrapper pads every operand to tile multiples (zero
rows of B are harmless: their kernel value is masked via a validity mask).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

LANE = 128  # MXU/VREG lane width — last-dim tile alignment


def _kernel_elementwise(sq, kind: str, scale: float):
    if kind == "gaussian":
        return jnp.exp(-0.5 / (scale * scale) * sq)
    if kind == "laplacian":
        return jnp.exp(-jnp.sqrt(sq + 1e-12) / scale)
    if kind == "matern32":
        a = jnp.sqrt(3.0) * jnp.sqrt(sq + 1e-12) / scale
        return (1.0 + a) * jnp.exp(-a)
    raise ValueError(f"pallas path does not support kernel {kind!r}")


def _kernel_matmul_kernel(a_ref, b_ref, v_ref, bmask_ref, o_ref, acc_ref, *,
                          kind: str, scale: float, nbj: int):
    """One (i, j) grid step: acc_i += K(A_i, B_j) @ V_j."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)           # (bm, d)
    b = b_ref[...].astype(jnp.float32)           # (bn, d)
    v = v_ref[...].astype(jnp.float32)           # (bn, p)
    bmask = bmask_ref[...].astype(jnp.float32)   # (1, bn) 1=valid row of B

    a2 = jnp.sum(a * a, axis=-1, keepdims=True)               # (bm, 1) VPU
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T             # (1, bn) VPU
    ab = jax.lax.dot_general(                                  # (bm, bn) MXU
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    sq = jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)
    k = _kernel_elementwise(sq, kind, scale) * bmask           # mask padded B
    acc_ref[...] += jax.lax.dot_general(                       # (bm, p) MXU
        k, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == nbj - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def kernel_matmul_pallas(
    A: Array, B: Array, V: Array, *,
    kind: str = "gaussian", scale: float = 1.0,
    block_m: int = 256, block_n: int = 512,
    interpret: bool = True,
) -> Array:
    """out = K(A, B) @ V with on-the-fly Gram tiles.

    A: (m, d), B: (n, d), V: (n, p) -> (m, p). All shapes may be ragged; the
    wrapper pads to tile multiples and masks padded B rows. ``interpret=True``
    runs the kernel body in Python (CPU validation); on TPU pass False.
    """
    m, d = A.shape
    n, _ = B.shape
    p = V.shape[1]
    out_dtype = jnp.promote_types(A.dtype, V.dtype)

    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    dp = -(-d // LANE) * LANE
    pp = -(-p // LANE) * LANE

    Ap = jnp.pad(A, ((0, mp - m), (0, dp - d)))
    Bp = jnp.pad(B, ((0, np_ - n), (0, dp - d)))
    Vp = jnp.pad(V, ((0, np_ - n), (0, pp - p)))
    bmask = (jnp.arange(np_) < n).astype(A.dtype)[None, :]     # (1, np_)

    nbi, nbj = mp // bm, np_ // bn

    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        functools.partial(_kernel_matmul_kernel, kind=kind, scale=scale,
                          nbj=nbj),
        grid=(nbi, nbj),
        in_specs=[
            pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),      # A_i
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),      # B_j
            pl.BlockSpec((bn, pp), lambda i, j: (j, 0)),      # V_j
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),       # mask_j
        ],
        out_specs=pl.BlockSpec((bm, pp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, pp), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, pp), jnp.float32)],   # fp32 accum
        interpret=interpret,
    )(Ap, Bp, Vp, bmask)
    return out[:m, :p]


def _pairwise_kernel(a_ref, b_ref, o_ref, *, kind: str, scale: float):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    sq = jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)
    o_ref[...] = _kernel_elementwise(sq, kind, scale).astype(o_ref.dtype)


def pairwise_kernel_pallas(
    A: Array, B: Array, *, kind: str = "gaussian", scale: float = 1.0,
    block_m: int = 256, block_n: int = 256, interpret: bool = True,
) -> Array:
    """Materialize K(A, B) tile-by-tile (used to build K_MM for the
    preconditioner). Grid (i, j) with one output tile per step."""
    m, d = A.shape
    n, _ = B.shape
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    dp = -(-d // LANE) * LANE
    Ap = jnp.pad(A, ((0, mp - m), (0, dp - d)))
    Bp = jnp.pad(B, ((0, np_ - n), (0, dp - d)))

    out = pl.pallas_call(
        functools.partial(_pairwise_kernel, kind=kind, scale=scale),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, dp), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), A.dtype),
        interpret=interpret,
    )(Ap, Bp)
    return out[:m, :n]
