"""Trainer loop: fault tolerance, straggler detection, elastic restart.

Production behaviours implemented (and unit-tested):
* periodic async checkpointing (per-shard files, atomic rename);
* restart-from-latest on construction — crash/preemption recovery;
* preemption hook (SIGTERM-style flag) -> final blocking save;
* straggler detection: per-step wall-time EWMA + z-score log/callback, the
  single-controller analogue of dropping slow hosts;
* elastic restore: the checkpoint reloads onto a different mesh via
  load_checkpoint(shardings=...) — resuming 2-pod training on 1 pod.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Iterator

import jax
import numpy as np

from repro.checkpoint.checkpoint import (
    latest_step, load_checkpoint, save_checkpoint, step_dir
)
from repro.configs.base import ModelConfig
from .steps import TrainConfig, TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    async_ckpt: bool = True
    keep_last: int = 2
    straggler_zscore: float = 3.0
    straggler_warmup: int = 5


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainConfig,
        rcfg: TrainerConfig,
        *,
        mesh=None,
        rules=None,
        state: TrainState | None = None,
        straggler_cb: Callable[[int, float, float], None] | None = None,
    ):
        self.cfg, self.tcfg, self.rcfg = cfg, tcfg, rcfg
        self.mesh, self.rules = mesh, rules
        self.straggler_cb = straggler_cb
        self.straggler_events: list[tuple[int, float]] = []
        self._pending_save = None
        self.preempted = False

        step_fn = make_train_step(cfg, tcfg)
        if mesh is not None:
            from repro.distributed.mesh import use_rules
            def wrapped(state, batch):
                with use_rules(self.rules):
                    return step_fn(state, batch)
            self.step_fn = jax.jit(wrapped, donate_argnums=(0,))
        else:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0,))

        if state is not None:
            self.state = state
        else:
            self.state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
            last = latest_step(rcfg.ckpt_dir)
            if last is not None:
                self.restore(last)

    # -- fault tolerance --------------------------------------------------
    def save(self, blocking: bool | None = None):
        step = int(jax.device_get(self.state.step))
        path = step_dir(self.rcfg.ckpt_dir, step)
        os.makedirs(self.rcfg.ckpt_dir, exist_ok=True)
        blocking = (not self.rcfg.async_ckpt) if blocking is None else blocking
        self._wait_save()
        self._pending_save = save_checkpoint(path, self.state, step, blocking=blocking)
        self._gc()

    def _wait_save(self):
        if self._pending_save is not None:
            self._pending_save.join()
            self._pending_save = None

    def _gc(self):
        root = self.rcfg.ckpt_dir
        if not os.path.isdir(root):
            return
        steps = sorted(int(d.split("_")[-1]) for d in os.listdir(root)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.rcfg.keep_last]:
            import shutil
            shutil.rmtree(step_dir(root, s), ignore_errors=True)

    def restore(self, step: int | None = None, shardings=None):
        self._wait_save()
        step = step if step is not None else latest_step(self.rcfg.ckpt_dir)
        assert step is not None, "no checkpoint to restore"
        self.state, _ = load_checkpoint(
            step_dir(self.rcfg.ckpt_dir, step), self.state, shardings=shardings
        )
        return step

    def request_preemption(self):
        """SIGTERM handler target: finish the current step, save, stop."""
        self.preempted = True

    # -- loop --------------------------------------------------------------
    def fit(self, data: Iterator[dict], steps: int) -> list[dict]:
        history = []
        ewma_t, ewma_v = None, 0.0
        for i, batch in enumerate(data):
            if i >= steps or self.preempted:
                break
            batch = {k: v for k, v in batch.items() if k != "step"}
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            # straggler detection (per-step latency z-score)
            if i >= self.rcfg.straggler_warmup and ewma_t is not None:
                sd = max(np.sqrt(ewma_v), 1e-6)
                z = (dt - ewma_t) / sd
                if z > self.rcfg.straggler_zscore:
                    self.straggler_events.append((i, dt))
                    if self.straggler_cb:
                        self.straggler_cb(i, dt, z)
            ewma_t = dt if ewma_t is None else 0.9 * ewma_t + 0.1 * dt
            ewma_v = 0.9 * ewma_v + 0.1 * (dt - ewma_t) ** 2

            history.append({k: float(jax.device_get(v)) for k, v in metrics.items()})
            step = int(jax.device_get(self.state.step))
            if self.rcfg.ckpt_every and step % self.rcfg.ckpt_every == 0:
                self.save()
        if self.preempted:
            self.save(blocking=True)    # preemption-safe final save
        self._wait_save()
        return history
