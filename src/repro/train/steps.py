"""pjit-able train_step and serve_step builders.

``make_train_step(cfg)`` returns a pure (state, batch) -> (state, metrics)
function: loss -> grad -> (optional clip / int8-EF compression) -> optimizer.
``make_serve_step(cfg)`` returns (params, cache, batch) -> (logits, cache).
Both lower/compile against ShapeDtypeStructs — the dry-run objects.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.compression import compressed_grads, init_residuals
from repro.models import decode_step, loss_fn, model_params
from repro.optim.optimizers import (clip_by_global_norm, make_optimizer, warmup_cosine)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    clip_norm: float = 1.0
    grad_compression: bool = False     # int8 error-feedback
    microbatch: int = 0                # 0 = no grad accumulation


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    residuals: Any          # error-feedback (empty dict if compression off)
    step: jax.Array


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    params = model_params(key, cfg)
    opt = make_optimizer(cfg.optimizer)
    res = init_residuals(params) if tcfg.grad_compression else {}
    return TrainState(
        params=params,
        opt_state=opt.init(params),
        residuals=res,
        step=jnp.zeros((), jnp.int32),
    )


def train_state_structs(cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    """ShapeDtypeStruct view of the train state (dry-run, no allocation)."""
    return jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg, tcfg))


def make_train_step(
    cfg: ModelConfig, tcfg: TrainConfig, grad_shardings=None
) -> Callable:
    """grad_shardings: optional tree of NamedSharding matching params. The
    fp32 gradient-accumulation buffer MUST carry the param shardings —
    otherwise GSPMD replicates it and all-reduces full gradients every
    microbatch (measured: 10.5 TB/step/device on jamba-398B, SS Perf #1)."""
    opt = make_optimizer(cfg.optimizer)
    lr_fn = warmup_cosine(tcfg.learning_rate, tcfg.warmup_steps, tcfg.total_steps)

    def constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_shardings)

    def compute_grads(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            # gradient accumulation over the batch split (sequential scan):
            # same math, 1/microbatch the activation memory.
            nb = tcfg.microbatch
            B = batch["labels"].shape[0]
            assert B % nb == 0, (B, nb)
            mb = {k: v.reshape((nb, B // nb) + v.shape[1:]) for k, v in batch.items()}

            def acc_fn(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, cfg, mbatch
                )
                g_acc = constrain(jax.tree.map(lambda a, b: a + b / nb, g_acc, g))
                return (g_acc, l_acc + l / nb), None

            zero_g = constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (grads, loss), _ = jax.lax.scan(acc_fn, (zero_g, 0.0), mb)
            metrics = {"loss": loss}
            return loss, metrics, grads
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch
        )
        return loss, metrics, grads

    def train_step(state: TrainState, batch: dict):
        loss, metrics, grads = compute_grads(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        residuals = state.residuals
        if tcfg.grad_compression:
            grads, residuals = compressed_grads(grads, residuals)
        lr = lr_fn(state.step)
        new_params, new_opt = opt.update(grads, state.opt_state, state.params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(
            params=new_params,
            opt_state=new_opt,
            residuals=residuals,
            step=state.step + 1,
        ), metrics

    return train_step


def train_state_pspecs(cfg: ModelConfig, tcfg: TrainConfig, rules):
    """PartitionSpecs for the whole TrainState (opt state inherits the param
    sharding — ZeRO for free; adafactor's factored moments drop the reduced
    dim's spec entry)."""
    from jax.sharding import PartitionSpec as P
    from repro.models.model import model_pd
    from repro.models.params import PD, param_pspecs

    pd_tree = model_pd(cfg)
    pspecs = param_pspecs(pd_tree, rules)

    def _spec_for_axes(pd: PD, dims, axes):
        return rules.spec_for(dims, axes)

    if cfg.optimizer == "adamw":
        opt = {"mu": pspecs, "nu": pspecs, "step": P()}
    elif cfg.optimizer == "sgdm":
        opt = {"mu": pspecs, "step": P()}
    elif cfg.optimizer == "adafactor":
        def fac(pd):
            if len(pd.shape) >= 2:
                return {"vr": _spec_for_axes(pd, pd.shape[:-1], pd.axes[:-1]),
                        "vc": _spec_for_axes(pd, pd.shape[:-2] + pd.shape[-1:],
                                             pd.axes[:-2] + pd.axes[-1:])}
            return {"v": _spec_for_axes(pd, pd.shape, pd.axes)}
        opt = {
            "f": jax.tree.map(fac, pd_tree, is_leaf=lambda x: isinstance(x, PD)),
            "step": P(),
        }
    else:
        raise ValueError(cfg.optimizer)

    residuals = pspecs if tcfg.grad_compression else {}
    return TrainState(params=pspecs, opt_state=opt, residuals=residuals, step=P())


def batch_pspecs(cfg: ModelConfig, batch_structs: dict, rules):
    """Batch inputs shard over the data axes when the batch dim divides."""
    from jax.sharding import PartitionSpec as P
    out = {}
    for k, v in batch_structs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = rules.spec_for(v.shape, axes)
    return out


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, batch):
        return decode_step(params, cfg, cache, batch)
    return serve_step
