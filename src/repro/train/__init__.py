from .steps import (
    TrainConfig,
    TrainState,
    init_train_state,
    make_serve_step,
    make_train_step,
    train_state_structs,
)
from .trainer import Trainer, TrainerConfig
