"""Nystrom center selection (paper Appendix A).

Two sampling schemes:

* **uniform**: a uniformly random subset of M training points (Alg. 1 setting);
  D = I.
* **approximate leverage scores** (Def. 1): sample M indices with replacement
  with p_i proportional to approximate ridge leverage scores ``lhat_lambda(i)``,
  and build the Def. 2 reweighting diagonal
  ``D_jj = 1 / sqrt(n * p_{i_j} * count_j)``
  (the ``count`` factor matches Alg. 2's ``discrete_prob_sample``, which
  collapses duplicate draws into one center with multiplicity).

Leverage-score estimation: exact scores are
``l_lambda(i) = [K_nn (K_nn + lambda n I)^{-1}]_ii`` — O(n^3), test-only. The
scalable estimator uses a uniform pilot subset S of size M0 and the Nystrom/
Woodbury identity

    lhat_lambda(i) = k_{iS}^T (lambda n K_SS + K_Sn K_nS)^{-1} k_{iS}

which is the q-approximate estimator family of [Rudi et al. 2015; Alaoui &
Mahoney 2015] computable in O(n M0^2 + M0^3) time and O(M0^2) memory (blocked
over rows of K_nS).

The estimator factors into a lambda-INDEPENDENT pilot stage and a cheap
per-lambda stage, mirroring the preconditioner split:

* ``build_leverage_pilot``      — draw S, build K_SS and accumulate
                                  K_Sn K_nS over row blocks (the O(n M0^2)
                                  data pass; lambda never appears).
* ``leverage_scores_from_pilot`` — form G = lam n K_SS + K_Sn K_nS, factor
                                  it (O(M0^3)) and score the rows.

A lambda grid therefore pays for the pilot-Gram build once
(``approximate_leverage_scores_path``); ``approximate_leverage_scores`` is
the single-lambda composition of the two stages.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import KernelFn

Array = jax.Array


class NystromCenters(NamedTuple):
    centers: Array       # (M, d)
    indices: Array       # (M,) indices into X
    D: Array | None      # (M,) Def. 2 diagonal; None for uniform sampling


def uniform_centers(key: Array, X: Array, M: int) -> NystromCenters:
    n = X.shape[0]
    idx = jax.random.choice(key, n, shape=(M,), replace=False)
    return NystromCenters(centers=X[idx], indices=idx, D=None)


def exact_leverage_scores(X: Array, kernel: KernelFn, lam: float) -> Array:
    """Exact ridge leverage scores (O(n^3); for tests / tiny n only)."""
    n = X.shape[0]
    Knn = kernel(X, X)
    S = jnp.linalg.solve(Knn + lam * n * jnp.eye(n, dtype=Knn.dtype), Knn)
    return jnp.diagonal(S)


class LeveragePilot(NamedTuple):
    """The lambda-independent half of the leverage-score estimator."""
    S: Array          # (M0, d) pilot subset
    KSS: Array        # (M0, M0) pilot Gram
    KSnKnS: Array     # (M0, M0) accumulated K_Sn K_nS (the O(n M0^2) pass)
    indices: Array    # (M0,) pilot row indices into X
    n: int            # rows the pilot was built over


def _blocked_rows(X: Array, block_size: int) -> tuple[Array, Array]:
    """(nb, block, d) row blocks of X plus the (nb, block) validity mask."""
    n = X.shape[0]
    nb = -(-n // block_size)
    pad = nb * block_size - n
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    mask = jnp.pad(jnp.ones((n,), X.dtype), (0, pad)).reshape(nb, block_size)
    return Xp.reshape(nb, block_size, -1), mask


def build_leverage_pilot(
    key: Array,
    X: Array,
    kernel: KernelFn,
    *,
    pilot_size: int = 256,
    block_size: int = 4096,
) -> LeveragePilot:
    """Stage 1 — the pilot-Gram build: everything lambda never touches.

    One O(n M0^2) pass over the data accumulates K_Sn K_nS; a lambda grid
    reuses the result for every ridge value (see
    ``leverage_scores_from_pilot``).
    """
    n, _ = X.shape
    M0 = min(pilot_size, n)
    pilot_idx = jax.random.choice(key, n, shape=(M0,), replace=False)
    S = X[pilot_idx]
    KSS = kernel(S, S)

    # Accumulate K_Sn K_nS = sum over row-blocks of K_bS^T K_bS.
    Xb, mask = _blocked_rows(X, block_size)

    def acc(carry, inp):
        xb, mb = inp
        Kb = kernel(xb, S) * mb[:, None]
        return carry + Kb.T @ Kb, None

    KSnKnS, _ = jax.lax.scan(acc, jnp.zeros((M0, M0), X.dtype), (Xb, mask))
    return LeveragePilot(S=S, KSS=KSS, KSnKnS=KSnKnS, indices=pilot_idx, n=n)


def leverage_scores_from_pilot(
    pilot: LeveragePilot,
    X: Array,
    kernel: KernelFn,
    lam: float,
    *,
    block_size: int = 4096,
) -> Array:
    """Stage 2 — score the rows at one ridge value from a built pilot.

    Cost per lambda: one O(M0^3) Cholesky of G = lam n K_SS + K_Sn K_nS
    plus the blocked scoring pass — the pilot-Gram accumulation is NOT
    repeated.
    """
    M0 = pilot.S.shape[0]
    n = X.shape[0]
    G = lam * pilot.n * pilot.KSS + pilot.KSnKnS
    G = G + 1e-6 * jnp.trace(G) / M0 * jnp.eye(M0, dtype=G.dtype)
    cho = jax.scipy.linalg.cho_factor(G)
    S = pilot.S
    Xb, _ = _blocked_rows(X, block_size)

    def score_block(xb):
        KbS = kernel(xb, S)                       # (b, M0)
        sol = jax.scipy.linalg.cho_solve(cho, KbS.T)  # (M0, b)
        return jnp.sum(KbS.T * sol, axis=0)       # (b,)

    scores = jax.lax.map(score_block, Xb).reshape(-1)[:n]
    return jnp.maximum(scores, 1e-12)


def approximate_leverage_scores(
    key: Array,
    X: Array,
    kernel: KernelFn,
    lam: float,
    *,
    pilot_size: int = 256,
    block_size: int = 4096,
) -> Array:
    """Nystrom/Woodbury approximate ridge leverage scores, O(n M0^2).

    The single-lambda composition of ``build_leverage_pilot`` and
    ``leverage_scores_from_pilot``.
    """
    pilot = build_leverage_pilot(
        key, X, kernel, pilot_size=pilot_size, block_size=block_size
    )
    return leverage_scores_from_pilot(pilot, X, kernel, lam, block_size=block_size)


def approximate_leverage_scores_path(
    key: Array,
    X: Array,
    kernel: KernelFn,
    lams,
    *,
    pilot_size: int = 256,
    block_size: int = 4096,
) -> Array:
    """(L, n) leverage scores over a lambda grid from ONE pilot-Gram build.

    The O(n M0^2) accumulation runs once; each grid point pays only its
    G-Cholesky and scoring pass — the sampling-diagnostics twin of the
    shared-sweep path solve.
    """
    pilot = build_leverage_pilot(
        key, X, kernel, pilot_size=pilot_size, block_size=block_size
    )
    return jnp.stack([
        leverage_scores_from_pilot(pilot, X, kernel, float(lam),
                                   block_size=block_size)
        for lam in lams
    ])


def leverage_score_centers(
    key: Array,
    X: Array,
    M: int,
    scores: Array,
) -> NystromCenters:
    """Sample M centers ~ p_i = scores_i / sum(scores); build Def. 2 D.

    Follows Alg. 2's ``discrete_prob_sample``: duplicates are kept as repeated
    rows (static shape) and D_jj = 1/sqrt(n * p_{i_j}) with each draw counted
    once — for draws of the same index this is equivalent to the collapsed
    (count-weighted) form up to a unitary rotation of the coefficient space,
    and keeps everything shape-static for jit.
    """
    n = X.shape[0]
    p = scores / jnp.sum(scores)
    idx = jax.random.choice(key, n, shape=(M,), replace=True, p=p)
    # Def. 2 / Def. 6: G_M = (1/M) sum_j D_jj^2 K_xj (x) K_xj with
    # D_jj^2 = 1/(n p_j) — the 1/M lives in G_M, so D itself is 1/sqrt(n p).
    D = 1.0 / jnp.sqrt(n * p[idx])
    return NystromCenters(centers=X[idx], indices=idx, D=D.astype(X.dtype))


def select_centers(
    key: Array,
    X: Array,
    M: int,
    *,
    kernel: KernelFn | None = None,
    lam: float | None = None,
    scheme: str = "uniform",
    pilot_size: int = 256,
) -> NystromCenters:
    if scheme == "uniform":
        return uniform_centers(key, X, M)
    if scheme == "leverage":
        assert kernel is not None and lam is not None
        k1, k2 = jax.random.split(key)
        scores = approximate_leverage_scores(k1, X, kernel, lam, pilot_size=pilot_size)
        return leverage_score_centers(k2, X, M, scores)
    raise ValueError(f"unknown center-selection scheme {scheme!r}")
