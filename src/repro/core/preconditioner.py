"""FALKON preconditioner (paper Sect. 3 Eq. 13 and Appendix A Def. 3).

Full-rank path (Alg. 1):
    T = chol(K_MM + eps*M*I)        (upper triangular, K_MM = T^T T)
    A = chol(T T^T / M + lam * I)   (upper triangular)
    B = (1/sqrt(n)) T^{-1} A^{-1}

General path (Alg. 2 / Def. 3) adds the sampling-weight diagonal D (Def. 2, for
approximate-leverage-score sampling) and a rank-revealing step for singular
K_MM. We implement the eigendecomposition variant of Example 2 (simpler than
pivoted QR and jittable):
    D K_MM D = Q diag(s) Q^T,  T = diag(sqrt(s)) restricted to s > tol,
with Q (M, q) a partial isometry. T diagonal is a valid special case of
"triangular"; all Def. 3 needs is invertibility and Q T^T T Q^T = D K_MM D.

B is never materialized: we expose the linear maps FALKON needs (the B^T H B
composition happens in falkon.py), exactly like Alg. 1's nested triangular
solves.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

Array = jax.Array


def _bcast(d: Array, v: Array) -> Array:
    return d[(...,) + (None,) * (v.ndim - 1)]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Preconditioner:
    T: Array            # (q, q) upper triangular (diagonal in the eig path)
    A: Array            # (q, q) upper triangular
    Q: Array | None     # (M, q) partial isometry; None => identity (full rank)
    D: Array | None     # (M,) sampling-weight diagonal; None => ones
    n: Array            # number of training points (scalar)
    diag_T: bool = dataclasses.field(metadata=dict(static=True), default=False)

    @property
    def q(self) -> int:
        return self.T.shape[0]

    def _solve_T(self, v: Array, trans: bool = False) -> Array:
        if self.diag_T:
            return v / _bcast(jnp.diagonal(self.T), v)
        return solve_triangular(self.T, v, lower=False, trans=1 if trans else 0)

    # --- the three maps -------------------------------------------------
    def right(self, u: Array) -> Array:
        """gamma = D Q T^{-1} A^{-1} u : (q,...) -> (M,...).

        This is sqrt(n) * B u; the 1/sqrt(n) is folded into the matvec's 1/n
        exactly as Alg. 1 does.
        """
        v = solve_triangular(self.A, u, lower=False)
        v = self._solve_T(v)
        if self.Q is not None:
            v = self.Q @ v
        if self.D is not None:
            v = v * _bcast(self.D, v)
        return v

    def left(self, w: Array) -> Array:
        """A^{-T} T^{-T} Q^T D w : (M,...) -> (q,...)."""
        if self.D is not None:
            w = w * _bcast(self.D, w)
        if self.Q is not None:
            w = self.Q.T @ w
        w = self._solve_T(w, trans=True)
        return solve_triangular(self.A, w, lower=False, trans=1)

    def coeffs(self, beta: Array) -> Array:
        """alpha = D Q T^{-1} A^{-1} beta (Alg. 1's ``alpha = T\\(A\\beta)``)."""
        return self.right(beta)


def make_preconditioner(
    KMM: Array,
    lam: float,
    n: int,
    *,
    D: Array | None = None,
    jitter: float | None = None,
    rank_deficient: bool = False,
    rank_tol: float = 1e-7,
) -> Preconditioner:
    """Build the FALKON preconditioner from K_MM.

    Cost: 2 Cholesky factorizations + one triangular product = 4/3 M^3 flops
    (paper Sect. 3 "Computations"). ``D`` is the Def. 2 diagonal for
    leverage-score sampling (None for uniform sampling).
    """
    M = KMM.shape[0]
    dt = KMM.dtype
    if D is not None:
        KMM = KMM * D[:, None] * D[None, :]

    if rank_deficient:
        # Appendix A Example 2 (eigendecomposition). Static shapes: rank-q
        # truncation is expressed by zeroing the dropped columns of Q and
        # guarding the inverses, so q == M structurally.
        s, U = jnp.linalg.eigh(KMM)                       # ascending
        s = s[::-1]
        U = U[:, ::-1]
        keep = s > (rank_tol * jnp.maximum(s[0], 1e-30))
        s_safe = jnp.where(keep, s, 1.0)
        T = jnp.diag(jnp.sqrt(s_safe))
        Q = U * keep[None, :].astype(dt)
        A = jnp.linalg.cholesky(
            jnp.diag(jnp.where(keep, s_safe, 0.0)) / M + lam * jnp.eye(M, dtype=dt)
        ).T
        return Preconditioner(T=T, A=A, Q=Q, D=D, n=jnp.asarray(n, dt),
                              diag_T=True)

    eps = jitter if jitter is not None else float(jnp.finfo(dt).eps) * M
    T = jnp.linalg.cholesky(KMM + eps * jnp.eye(M, dtype=dt)).T   # upper
    A = jnp.linalg.cholesky(T @ T.T / M + lam * jnp.eye(M, dtype=dt)).T
    return Preconditioner(T=T, A=A, Q=None, D=D, n=jnp.asarray(n, dt),
                          diag_T=False)
