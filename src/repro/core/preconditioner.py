"""FALKON preconditioner (paper Sect. 3 Eq. 13 and Appendix A Def. 3).

Full-rank path (Alg. 1):
    T = chol(K_MM + eps*M*I)        (upper triangular, K_MM = T^T T)
    A = chol(T T^T / M + lam * I)   (upper triangular)
    B = (1/sqrt(n)) T^{-1} A^{-1}

General path (Alg. 2 / Def. 3) adds the sampling-weight diagonal D (Def. 2, for
approximate-leverage-score sampling) and a rank-revealing step for singular
K_MM. We implement the eigendecomposition variant of Example 2 (simpler than
pivoted QR and jittable):
    D K_MM D = Q diag(s) Q^T,  T = diag(sqrt(s)) restricted to s > tol,
with Q (M, q) a partial isometry. T diagonal is a valid special case of
"triangular"; all Def. 3 needs is invertibility and Q T^T T Q^T = D K_MM D.

B is never materialized: we expose the linear maps FALKON needs (the B^T H B
composition happens in falkon.py), exactly like Alg. 1's nested triangular
solves.

The factorization is split into two stages because only the second depends on
the regularization:

* **shared stage** (``_shared_factor``) — the O(M^3) work: one Cholesky (or
  eigendecomposition) of D K_MM D producing T/Q, plus the Gram of the factor
  ``T T^T`` that every lam-ridge reads. lam never appears.
* **lam stage** (``_lam_factor``) — ``A = chol(T T^T / M + lam I)``, a single
  cheap Cholesky per lam.

``make_preconditioner`` composes them for one lam;
``make_preconditioner_path`` runs the shared stage ONCE and vmaps the lam
stage over a grid of L lams, returning a :class:`PreconditionerPath` whose
``A`` is a batched (L, q, q) stack and whose maps act on (q, L*p) blocks —
L independent systems stacked along the column axis, sharing every
O(nM)-cost data sweep upstream (see falkon.py's path solver).

Factor-path routing (in-core vs blocked)
----------------------------------------
Every factor here is UPPER triangular by convention: ``T = chol(...).T``
with ``K = T^T T`` (jnp's Cholesky is lower; the transpose is taken at the
factorization, never at the solves). Both builders route each O(M^3)
Cholesky through ``repro.ops.plan_factor`` — the ``plan_sweep`` sibling for
the preconditioner stack:

* **incore** (dense factor fits ``REPRO_FACTOR_BUDGET_MB``, default 512 MB)
  — one ``jnp.linalg.cholesky`` on the device-resident matrix, exactly the
  historical path, bit-for-bit.
* **blocked** (dense factor exceeds the budget) — the tiled right-looking
  out-of-core path (``repro.kernels.blocked_cholesky``): the matrix is
  factored from HOST memory in (b, b) tiles with only O(b * M) panel bytes
  device-resident, lifting the M ceiling from "dense (M, M) fits HBM" to
  "dense (M, M) fits host RAM". A :class:`repro.ops.FactorPlanWarning`
  (carrying the full ``FactorPlan``) announces the fallback, mirroring
  ``SweepPlanWarning``. The finished factors still live on device for
  solve time — the remaining O(M^2) ceiling, documented in
  docs/architecture.md.

Routing honors the ``PrecisionPolicy`` ``cholesky`` override: tiles compute
in float32 at minimum regardless of the storage policy (bf16 factors
destabilize preconditioned CG — measured, see repro.ops.base), float64 when
the caller runs x64. The blocked path requires a CONCRETE K_MM (it round-
trips host memory): under a jit trace the plan silently falls back to
in-core, and the eig-based ``rank_deficient`` factorization refuses the
blocked route loudly (a dense (M, M) eigendecomposition cannot be tiled by
this scheme — see ``_shared_factor``).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import numpy as np

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

Array = jax.Array


def _bcast(d: Array, v: Array) -> Array:
    return d[(...,) + (None,) * (v.ndim - 1)]


def _solve_T(T: Array, diag_T: bool, v: Array, trans: bool = False) -> Array:
    """T^{-1} v (or T^{-T} v) — diagonal fast path for the eig factorization.

    Shared by the single-lam and path preconditioners: T is lam-independent,
    so the path applies it to the whole stacked column block in one solve.
    """
    if diag_T:
        return v / _bcast(jnp.diagonal(T), v)
    return solve_triangular(T, v, lower=False, trans=1 if trans else 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Preconditioner:
    T: Array            # (q, q) upper triangular (diagonal in the eig path)
    A: Array            # (q, q) upper triangular
    Q: Array | None     # (M, q) partial isometry; None => identity (full rank)
    D: Array | None     # (M,) sampling-weight diagonal; None => ones
    n: Array            # number of training points (scalar)
    diag_T: bool = dataclasses.field(metadata=dict(static=True), default=False)

    @property
    def q(self) -> int:
        return self.T.shape[0]

    def _solve_T(self, v: Array, trans: bool = False) -> Array:
        return _solve_T(self.T, self.diag_T, v, trans)

    # --- the three maps -------------------------------------------------
    def right(self, u: Array) -> Array:
        """gamma = D Q T^{-1} A^{-1} u : (q,...) -> (M,...).

        This is sqrt(n) * B u; the 1/sqrt(n) is folded into the matvec's 1/n
        exactly as Alg. 1 does.
        """
        v = solve_triangular(self.A, u, lower=False)
        v = self._solve_T(v)
        if self.Q is not None:
            v = self.Q @ v
        if self.D is not None:
            v = v * _bcast(self.D, v)
        return v

    def left(self, w: Array) -> Array:
        """A^{-T} T^{-T} Q^T D w : (M,...) -> (q,...)."""
        if self.D is not None:
            w = w * _bcast(self.D, w)
        if self.Q is not None:
            w = self.Q.T @ w
        w = self._solve_T(w, trans=True)
        return solve_triangular(self.A, w, lower=False, trans=1)

    def coeffs(self, beta: Array) -> Array:
        """alpha = D Q T^{-1} A^{-1} beta (Alg. 1's ``alpha = T\\(A\\beta)``)."""
        return self.right(beta)

    def beta_of_coeffs(self, alpha: Array) -> Array:
        """Inverse of ``coeffs``: beta = A T Q^T D^{-1} alpha, (M,...) -> (q,...).

        The warm-start map for ``partial_fit``: a deployed estimator stores
        alpha (the kernel-space coefficients), but the mini-batch iteration
        lives in the preconditioned space, so resuming from a served model
        means pulling alpha back through the factors. Triangular/diagonal
        MULTIPLIES, not solves — exact for the full-rank path
        (``coeffs(beta_of_coeffs(a)) == a``); in the rank-deficient eig path
        ``Q^T`` is the least-squares pullback onto the kept eigenspace, which
        is the only part of alpha the solver ever produced.
        """
        v = alpha
        if self.D is not None:
            v = v / _bcast(self.D, v)
        if self.Q is not None:
            v = self.Q.T @ v
        if self.diag_T:
            v = _bcast(jnp.diagonal(self.T), v) * v
        else:
            v = self.T @ v
        return self.A @ v

    def ridge(self, u: Array, lam) -> Array:
        """lam * A^{-T} A^{-1} u — the regularization term of W = B^T H B.

        Uses the T^{-T} Q^T D K_MM D Q T^{-1} = I identity (Lemma 2 /
        Eq. 19), exactly as the MATLAB code does.
        """
        v = solve_triangular(self.A, u, lower=False)
        return lam * solve_triangular(self.A, v, lower=False, trans=1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PreconditionerPath:
    """L preconditioners sharing T/Q/D, differing only in the lam-ridge A.

    The maps act on **stacked column blocks**: a (q, L*p) array whose column
    group ``[l*p:(l+1)*p]`` belongs to system l (lam = ``lams[l]``). The
    lam-independent part (T, Q, D — the expensive factors) applies to the
    whole block in one solve; only the cheap per-system A triangular solves
    are vmapped over the (L, q, q) stack. This is the seam that lets ONE
    O(nM) data sweep serve all L regularization values in the path solver.
    """

    T: Array            # (q, q) shared factor (diagonal in the eig path)
    A: Array            # (L, q, q) per-lam upper-triangular stack
    Q: Array | None     # (M, q) shared partial isometry
    D: Array | None     # (M,) shared sampling-weight diagonal
    lams: Array         # (L,) regularization grid, A[l] = chol(TT^T/M + lams[l] I)
    n: Array            # number of training points (scalar)
    diag_T: bool = dataclasses.field(metadata=dict(static=True), default=False)

    @property
    def q(self) -> int:
        return self.T.shape[0]

    @property
    def L(self) -> int:
        return self.A.shape[0]

    # --- stacked-block plumbing ----------------------------------------
    def _group(self, U: Array) -> Array:
        """(q, L*p) -> (L, q, p): split the column axis into systems."""
        q, cols = U.shape
        return U.reshape(q, self.L, cols // self.L).transpose(1, 0, 2)

    @staticmethod
    def _ungroup(G: Array) -> Array:
        """(L, q, p) -> (q, L*p): inverse of ``_group``."""
        L, q, p = G.shape
        return G.transpose(1, 0, 2).reshape(q, L * p)

    def solve_A(self, U: Array, trans: bool = False) -> Array:
        """Per-system A^{-1} (or A^{-T}) over the column groups of U."""
        tr = 1 if trans else 0
        solve = functools.partial(solve_triangular, lower=False, trans=tr)
        return self._ungroup(jax.vmap(solve)(self.A, self._group(U)))

    def col_lams(self, U: Array) -> Array:
        """lams broadcast to U's columns: lam_l repeated p times."""
        return jnp.repeat(self.lams, U.shape[1] // self.L)

    # --- the three maps, system-batched ---------------------------------
    def right(self, U: Array) -> Array:
        """gamma_l = D Q T^{-1} A_l^{-1} u_l, stacked: (q, L*p) -> (M, L*p)."""
        v = self.solve_A(U)
        v = _solve_T(self.T, self.diag_T, v)
        if self.Q is not None:
            v = self.Q @ v
        if self.D is not None:
            v = v * _bcast(self.D, v)
        return v

    def left(self, W: Array) -> Array:
        """A_l^{-T} T^{-T} Q^T D w_l, stacked: (M, L*p) -> (q, L*p)."""
        if self.D is not None:
            W = W * _bcast(self.D, W)
        if self.Q is not None:
            W = self.Q.T @ W
        W = _solve_T(self.T, self.diag_T, W, trans=True)
        return self.solve_A(W, trans=True)

    def coeffs(self, beta: Array) -> Array:
        """alpha_l = D Q T^{-1} A_l^{-1} beta_l, stacked over columns."""
        return self.right(beta)

    def ridge(self, U: Array, lams=None) -> Array:
        """lam_l * A_l^{-T} A_l^{-1} u_l per column group of U."""
        del lams  # the grid is part of the factorization; kept for the
        # _falkon_operator calling convention shared with Preconditioner
        v = self.solve_A(self.solve_A(U), trans=True)
        return v * self.col_lams(U)[None,:]

    def expand_rhs(self, w: Array) -> Array:
        """The lam-independent RHS ``w = K_nM^T y / n`` (M, p) expanded to
        the stacked (q, L*p) CG right-hand side.

        The shared D/Q/T^{-T} half is applied ONCE; only the per-system
        A_l^{-T} differs — the b-side twin of the shared data sweep.
        """
        if w.ndim == 1:
            w = w[:, None]
        if self.D is not None:
            w = w * _bcast(self.D, w)
        if self.Q is not None:
            w = self.Q.T @ w
        shared = _solve_T(self.T, self.diag_T, w, trans=True)      # (q, p)
        solve = functools.partial(solve_triangular, lower=False, trans=1)
        per = jax.vmap(lambda A: solve(A, shared))(self.A)         # (L, q, p)
        return self._ungroup(per)

    def split(self, stacked: Array) -> Array:
        """(rows, L*p) -> (L, rows, p): per-system views of a stacked block."""
        rows, cols = stacked.shape
        return stacked.reshape(rows, self.L, cols // self.L).transpose(1, 0, 2)

    def system(self, index: int) -> Preconditioner:
        """The single-lam :class:`Preconditioner` for system ``index``."""
        return Preconditioner(
            T=self.T, A=self.A[index], Q=self.Q, D=self.D, n=self.n, diag_T=self.diag_T
        )


# ---------------------------------------------------------------------------
# Factorization stages
# ---------------------------------------------------------------------------
def _resolve_factor_plan(KMM: Array, factor_plan, rank_deficient: bool):
    """Resolve the caller's ``factor_plan`` argument to a ``FactorPlan``.

    ``None`` auto-plans from the factor budget (``REPRO_FACTOR_BUDGET_MB``);
    a path name ("incore"/"blocked") forces that route; a ``FactorPlan`` is
    taken as-is. A traced K_MM always lands in-core (the blocked path
    round-trips host memory, which a trace cannot do); a blocked plan with
    ``rank_deficient=True`` raises (see ``_shared_factor``); a blocked plan
    on the normal path emits ``FactorPlanWarning``.
    """
    # Lazy import: repro.ops.__init__ constructs backends that reach into
    # repro.core, so a module-level import here would be a cycle.
    from repro.ops.base import FACTOR_PATHS, FactorPlan, FactorPlanWarning, plan_factor

    M = KMM.shape[0]
    itemsize = max(jnp.dtype(KMM.dtype).itemsize, 4)
    if isinstance(factor_plan, FactorPlan):
        plan = factor_plan
    elif factor_plan is None:
        plan = plan_factor(M, itemsize=itemsize)
    elif factor_plan in FACTOR_PATHS:
        # Force the named path by planning against a budget the dense
        # factor trivially fits (incore) or trivially exceeds (blocked).
        dense = M * M * itemsize
        plan = plan_factor(
            M,
            itemsize=itemsize,
            factor_budget=dense if factor_plan == "incore" else dense - 1,
        )
    else:
        raise ValueError(
            f"factor_plan must be None, a FactorPlan, or one of "
            f"{FACTOR_PATHS}; got {factor_plan!r}")

    if plan.path == "blocked":
        if isinstance(KMM, jax.core.Tracer):
            # Can't leave the device under a trace — quietly keep the
            # traced program on the historical in-core path.
            return plan_factor(M, itemsize=itemsize, factor_budget=M * M * itemsize)
        if rank_deficient:
            raise ValueError(
                "rank_deficient=True is not supported on the blocked factor "
                "path: the eig fallback needs a dense (M, M) "
                "eigendecomposition that this tiling cannot express. Use "
                "the in-core path (raise REPRO_FACTOR_BUDGET_MB or pass "
                "factor_plan='incore'), or drop rank_deficient.")
        warnings.warn(FactorPlanWarning(plan), stacklevel=3)
    return plan


def _shared_factor(
    KMM: Array,
    D: Array | None,
    jitter: float | None,
    rank_deficient: bool,
    rank_tol: float,
    plan=None,
) -> tuple[Array, Array | None, Array, bool]:
    """Stage 1 — everything lam never touches: (T, Q, TTt, diag_T).

    ``TTt`` is the (q, q) Gram of the factor (``T T^T`` for the Cholesky
    path, ``diag(kept s)`` for the eig path) that every lam-ridge Cholesky
    reads; computing it here means an L-point path pays for it once.

    ``plan`` (a resolved ``FactorPlan`` or None) selects the Cholesky
    route. On the blocked path the D-scaling, the jitter and both O(M^3)
    products (``chol`` and ``T T^T``) run against HOST-resident numpy via
    ``repro.kernels.blocked_cholesky`` — the device never holds more than
    O(plan.block * M) factor bytes; the in-core path is untouched (and the
    eig-based ``rank_deficient`` branch is in-core only — the resolver
    refuses blocked plans for it loudly).
    """
    M = KMM.shape[0]
    dt = KMM.dtype

    if plan is not None and plan.path == "blocked" and not rank_deficient:
        from repro.kernels.blocked_cholesky import blocked_cholesky, blocked_syrk_tt
        Kh = np.array(KMM)                     # host working copy
        if D is not None:
            Dh = np.array(D, dtype=Kh.dtype)
            Kh *= Dh[:, None]
            Kh *= Dh[None,:]
        eps = jitter if jitter is not None else float(jnp.finfo(dt).eps) * M
        Kh.flat[:: M + 1] += np.asarray(eps, Kh.dtype)
        Th = blocked_cholesky(Kh, plan.block)
        TTth = blocked_syrk_tt(Th, plan.block)
        return jnp.asarray(Th, dt), None, jnp.asarray(TTth, dt), False

    if D is not None:
        KMM = KMM * D[:, None] * D[None,:]

    if rank_deficient:
        # Appendix A Example 2 (eigendecomposition). Static shapes: rank-q
        # truncation is expressed by zeroing the dropped columns of Q and
        # guarding the inverses, so q == M structurally.
        s, U = jnp.linalg.eigh(KMM)                       # ascending
        s = s[::-1]
        U = U[:,::-1]
        keep = s > (rank_tol * jnp.maximum(s[0], 1e-30))
        s_safe = jnp.where(keep, s, 1.0)
        T = jnp.diag(jnp.sqrt(s_safe))
        Q = U * keep[None,:].astype(dt)
        TTt = jnp.diag(jnp.where(keep, s_safe, 0.0))
        return T, Q, TTt, True

    eps = jitter if jitter is not None else float(jnp.finfo(dt).eps) * M
    T = jnp.linalg.cholesky(KMM + eps * jnp.eye(M, dtype=dt)).T   # upper
    return T, None, T @ T.T, False


def _lam_factor(TTt: Array, lam, M: int, plan=None) -> Array:
    """Stage 2 — ``A = chol(T T^T / M + lam I)`` (upper): one cheap Cholesky
    per regularization value; vmapped over the grid by the path builder.

    "Cheap" is relative to the data sweeps, not to device memory: at the
    same (q, q) size as T it hits the same dense-factor wall, so a blocked
    ``plan`` routes it through the same out-of-core tiling (requires a
    concrete TTt and lam; traced inputs stay in-core).
    """
    if (plan is not None and plan.path == "blocked"
            and not isinstance(TTt, jax.core.Tracer)
            and not isinstance(lam, jax.core.Tracer)):
        from repro.kernels.blocked_cholesky import blocked_cholesky
        Bh = np.array(TTt)
        Bh /= M
        Bh.flat[:: Bh.shape[0] + 1] += np.asarray(float(lam), Bh.dtype)
        return jnp.asarray(blocked_cholesky(Bh, plan.block), TTt.dtype)
    eye = jnp.eye(TTt.shape[0], dtype=TTt.dtype)
    return jnp.linalg.cholesky(TTt / M + lam * eye).T


def make_preconditioner(
    KMM: Array,
    lam: float,
    n: int,
    *,
    D: Array | None = None,
    jitter: float | None = None,
    rank_deficient: bool = False,
    rank_tol: float = 1e-7,
    factor_plan=None,
) -> Preconditioner:
    """Build the FALKON preconditioner from K_MM.

    Cost: 2 Cholesky factorizations + one triangular product = 4/3 M^3 flops
    (paper Sect. 3 "Computations"). ``D`` is the Def. 2 diagonal for
    leverage-score sampling (None for uniform sampling).

    ``factor_plan`` routes the two Cholesky factorizations: ``None``
    auto-plans in-core vs blocked from the dense-factor budget
    (``REPRO_FACTOR_BUDGET_MB``), ``"incore"``/``"blocked"`` force a path,
    and a ``repro.ops.FactorPlan`` is used as-is. See the module docstring
    ("Factor-path routing") for the contract; results are path-independent
    to ~1e-5 relative (tested), not bit-identical.
    """
    M = KMM.shape[0]
    dt = KMM.dtype
    plan = _resolve_factor_plan(KMM, factor_plan, rank_deficient)
    T, Q, TTt, diag_T = _shared_factor(
        KMM, D, jitter, rank_deficient, rank_tol, plan=plan
    )
    A = _lam_factor(TTt, lam, M, plan=plan)
    return Preconditioner(T=T, A=A, Q=Q, D=D, n=jnp.asarray(n, dt), diag_T=diag_T)


def make_preconditioner_path(
    KMM: Array,
    lams,
    n: int,
    *,
    D: Array | None = None,
    jitter: float | None = None,
    rank_deficient: bool = False,
    rank_tol: float = 1e-7,
    factor_plan=None,
) -> PreconditionerPath:
    """One shared factorization, L cheap lam-ridge Cholesky's.

    ``lams`` is the regularization grid ((L,) array-like, each > 0). The
    O(M^3) shared stage runs once; the (L, q, q) ``A`` stack costs L * M^3/3
    on an M x M triangular Gram that is already resident — against L full
    ``make_preconditioner`` calls this saves L-1 Cholesky factorizations of
    K_MM itself, and against L full *fits* it is the enabler for sharing
    every O(nM) data sweep (see ``falkon_solve_path``).

    ``factor_plan`` routes every factorization exactly as in
    ``make_preconditioner``. One sizing note: a blocked path builds the L
    lam-ridge factors SEQUENTIALLY (a host-blocked loop cannot be vmapped),
    and the (L, q, q) stack itself is L dense factors on device — the stack,
    not the factorization, becomes the memory bound for large L * M^2.
    """
    M = KMM.shape[0]
    dt = KMM.dtype
    lams = jnp.asarray(lams, dt)
    if lams.ndim != 1 or lams.shape[0] < 1:
        raise ValueError(
            f"lams must be a non-empty 1-D grid, got shape " f"{lams.shape}"
        )
    if not isinstance(lams, jax.core.Tracer) and bool(jnp.any(lams <= 0.0)):
        # a non-positive ridge makes TT^T/M + lam I indefinite and the
        # batched Cholesky returns silent NaNs, not an error — fail here
        # (concrete grids only; traced grids keep the builder jittable)
        raise ValueError(
            f"every lam in the path must be > 0, got {tuple(map(float, lams))}"
        )
    plan = _resolve_factor_plan(KMM, factor_plan, rank_deficient)
    T, Q, TTt, diag_T = _shared_factor(
        KMM, D, jitter, rank_deficient, rank_tol, plan=plan
    )
    if plan.path == "blocked" and not isinstance(lams, jax.core.Tracer):
        # The host-blocked factorization cannot run under vmap; build the
        # (L, q, q) stack one out-of-core Cholesky at a time.
        A = jnp.stack([_lam_factor(TTt, lam, M, plan=plan) for lam in np.asarray(lams)])
    else:
        A = jax.vmap(lambda lam: _lam_factor(TTt, lam, M))(lams)
    return PreconditionerPath(
        T=T, A=A, Q=Q, D=D, lams=lams, n=jnp.asarray(n, dt), diag_T=diag_T
    )
