"""FALKON preconditioner (paper Sect. 3 Eq. 13 and Appendix A Def. 3).

Full-rank path (Alg. 1):
    T = chol(K_MM + eps*M*I)        (upper triangular, K_MM = T^T T)
    A = chol(T T^T / M + lam * I)   (upper triangular)
    B = (1/sqrt(n)) T^{-1} A^{-1}

General path (Alg. 2 / Def. 3) adds the sampling-weight diagonal D (Def. 2, for
approximate-leverage-score sampling) and a rank-revealing step for singular
K_MM. We implement the eigendecomposition variant of Example 2 (simpler than
pivoted QR and jittable):
    D K_MM D = Q diag(s) Q^T,  T = diag(sqrt(s)) restricted to s > tol,
with Q (M, q) a partial isometry. T diagonal is a valid special case of
"triangular"; all Def. 3 needs is invertibility and Q T^T T Q^T = D K_MM D.

B is never materialized: we expose the linear maps FALKON needs (the B^T H B
composition happens in falkon.py), exactly like Alg. 1's nested triangular
solves.

The factorization is split into two stages because only the second depends on
the regularization:

* **shared stage** (``_shared_factor``) — the O(M^3) work: one Cholesky (or
  eigendecomposition) of D K_MM D producing T/Q, plus the Gram of the factor
  ``T T^T`` that every lam-ridge reads. lam never appears.
* **lam stage** (``_lam_factor``) — ``A = chol(T T^T / M + lam I)``, a single
  cheap Cholesky per lam.

``make_preconditioner`` composes them for one lam;
``make_preconditioner_path`` runs the shared stage ONCE and vmaps the lam
stage over a grid of L lams, returning a :class:`PreconditionerPath` whose
``A`` is a batched (L, q, q) stack and whose maps act on (q, L*p) blocks —
L independent systems stacked along the column axis, sharing every
O(nM)-cost data sweep upstream (see falkon.py's path solver).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

Array = jax.Array


def _bcast(d: Array, v: Array) -> Array:
    return d[(...,) + (None,) * (v.ndim - 1)]


def _solve_T(T: Array, diag_T: bool, v: Array, trans: bool = False) -> Array:
    """T^{-1} v (or T^{-T} v) — diagonal fast path for the eig factorization.

    Shared by the single-lam and path preconditioners: T is lam-independent,
    so the path applies it to the whole stacked column block in one solve.
    """
    if diag_T:
        return v / _bcast(jnp.diagonal(T), v)
    return solve_triangular(T, v, lower=False, trans=1 if trans else 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Preconditioner:
    T: Array            # (q, q) upper triangular (diagonal in the eig path)
    A: Array            # (q, q) upper triangular
    Q: Array | None     # (M, q) partial isometry; None => identity (full rank)
    D: Array | None     # (M,) sampling-weight diagonal; None => ones
    n: Array            # number of training points (scalar)
    diag_T: bool = dataclasses.field(metadata=dict(static=True), default=False)

    @property
    def q(self) -> int:
        return self.T.shape[0]

    def _solve_T(self, v: Array, trans: bool = False) -> Array:
        return _solve_T(self.T, self.diag_T, v, trans)

    # --- the three maps -------------------------------------------------
    def right(self, u: Array) -> Array:
        """gamma = D Q T^{-1} A^{-1} u : (q,...) -> (M,...).

        This is sqrt(n) * B u; the 1/sqrt(n) is folded into the matvec's 1/n
        exactly as Alg. 1 does.
        """
        v = solve_triangular(self.A, u, lower=False)
        v = self._solve_T(v)
        if self.Q is not None:
            v = self.Q @ v
        if self.D is not None:
            v = v * _bcast(self.D, v)
        return v

    def left(self, w: Array) -> Array:
        """A^{-T} T^{-T} Q^T D w : (M,...) -> (q,...)."""
        if self.D is not None:
            w = w * _bcast(self.D, w)
        if self.Q is not None:
            w = self.Q.T @ w
        w = self._solve_T(w, trans=True)
        return solve_triangular(self.A, w, lower=False, trans=1)

    def coeffs(self, beta: Array) -> Array:
        """alpha = D Q T^{-1} A^{-1} beta (Alg. 1's ``alpha = T\\(A\\beta)``)."""
        return self.right(beta)

    def ridge(self, u: Array, lam) -> Array:
        """lam * A^{-T} A^{-1} u — the regularization term of W = B^T H B.

        Uses the T^{-T} Q^T D K_MM D Q T^{-1} = I identity (Lemma 2 /
        Eq. 19), exactly as the MATLAB code does.
        """
        v = solve_triangular(self.A, u, lower=False)
        return lam * solve_triangular(self.A, v, lower=False, trans=1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PreconditionerPath:
    """L preconditioners sharing T/Q/D, differing only in the lam-ridge A.

    The maps act on **stacked column blocks**: a (q, L*p) array whose column
    group ``[l*p:(l+1)*p]`` belongs to system l (lam = ``lams[l]``). The
    lam-independent part (T, Q, D — the expensive factors) applies to the
    whole block in one solve; only the cheap per-system A triangular solves
    are vmapped over the (L, q, q) stack. This is the seam that lets ONE
    O(nM) data sweep serve all L regularization values in the path solver.
    """

    T: Array            # (q, q) shared factor (diagonal in the eig path)
    A: Array            # (L, q, q) per-lam upper-triangular stack
    Q: Array | None     # (M, q) shared partial isometry
    D: Array | None     # (M,) shared sampling-weight diagonal
    lams: Array         # (L,) regularization grid, A[l] = chol(TT^T/M + lams[l] I)
    n: Array            # number of training points (scalar)
    diag_T: bool = dataclasses.field(metadata=dict(static=True), default=False)

    @property
    def q(self) -> int:
        return self.T.shape[0]

    @property
    def L(self) -> int:
        return self.A.shape[0]

    # --- stacked-block plumbing ----------------------------------------
    def _group(self, U: Array) -> Array:
        """(q, L*p) -> (L, q, p): split the column axis into systems."""
        q, cols = U.shape
        return U.reshape(q, self.L, cols // self.L).transpose(1, 0, 2)

    @staticmethod
    def _ungroup(G: Array) -> Array:
        """(L, q, p) -> (q, L*p): inverse of ``_group``."""
        L, q, p = G.shape
        return G.transpose(1, 0, 2).reshape(q, L * p)

    def solve_A(self, U: Array, trans: bool = False) -> Array:
        """Per-system A^{-1} (or A^{-T}) over the column groups of U."""
        tr = 1 if trans else 0
        solve = functools.partial(solve_triangular, lower=False, trans=tr)
        return self._ungroup(jax.vmap(solve)(self.A, self._group(U)))

    def col_lams(self, U: Array) -> Array:
        """lams broadcast to U's columns: lam_l repeated p times."""
        return jnp.repeat(self.lams, U.shape[1] // self.L)

    # --- the three maps, system-batched ---------------------------------
    def right(self, U: Array) -> Array:
        """gamma_l = D Q T^{-1} A_l^{-1} u_l, stacked: (q, L*p) -> (M, L*p)."""
        v = self.solve_A(U)
        v = _solve_T(self.T, self.diag_T, v)
        if self.Q is not None:
            v = self.Q @ v
        if self.D is not None:
            v = v * _bcast(self.D, v)
        return v

    def left(self, W: Array) -> Array:
        """A_l^{-T} T^{-T} Q^T D w_l, stacked: (M, L*p) -> (q, L*p)."""
        if self.D is not None:
            W = W * _bcast(self.D, W)
        if self.Q is not None:
            W = self.Q.T @ W
        W = _solve_T(self.T, self.diag_T, W, trans=True)
        return self.solve_A(W, trans=True)

    def coeffs(self, beta: Array) -> Array:
        """alpha_l = D Q T^{-1} A_l^{-1} beta_l, stacked over columns."""
        return self.right(beta)

    def ridge(self, U: Array, lams=None) -> Array:
        """lam_l * A_l^{-T} A_l^{-1} u_l per column group of U."""
        del lams  # the grid is part of the factorization; kept for the
        # _falkon_operator calling convention shared with Preconditioner
        v = self.solve_A(self.solve_A(U), trans=True)
        return v * self.col_lams(U)[None, :]

    def expand_rhs(self, w: Array) -> Array:
        """The lam-independent RHS ``w = K_nM^T y / n`` (M, p) expanded to
        the stacked (q, L*p) CG right-hand side.

        The shared D/Q/T^{-T} half is applied ONCE; only the per-system
        A_l^{-T} differs — the b-side twin of the shared data sweep.
        """
        if w.ndim == 1:
            w = w[:, None]
        if self.D is not None:
            w = w * _bcast(self.D, w)
        if self.Q is not None:
            w = self.Q.T @ w
        shared = _solve_T(self.T, self.diag_T, w, trans=True)      # (q, p)
        solve = functools.partial(solve_triangular, lower=False, trans=1)
        per = jax.vmap(lambda A: solve(A, shared))(self.A)         # (L, q, p)
        return self._ungroup(per)

    def split(self, stacked: Array) -> Array:
        """(rows, L*p) -> (L, rows, p): per-system views of a stacked block."""
        rows, cols = stacked.shape
        return stacked.reshape(rows, self.L, cols // self.L).transpose(1, 0, 2)

    def system(self, index: int) -> Preconditioner:
        """The single-lam :class:`Preconditioner` for system ``index``."""
        return Preconditioner(T=self.T, A=self.A[index], Q=self.Q, D=self.D,
                              n=self.n, diag_T=self.diag_T)


# ---------------------------------------------------------------------------
# Factorization stages
# ---------------------------------------------------------------------------
def _shared_factor(
    KMM: Array,
    D: Array | None,
    jitter: float | None,
    rank_deficient: bool,
    rank_tol: float,
) -> tuple[Array, Array | None, Array, bool]:
    """Stage 1 — everything lam never touches: (T, Q, TTt, diag_T).

    ``TTt`` is the (q, q) Gram of the factor (``T T^T`` for the Cholesky
    path, ``diag(kept s)`` for the eig path) that every lam-ridge Cholesky
    reads; computing it here means an L-point path pays for it once.
    """
    M = KMM.shape[0]
    dt = KMM.dtype
    if D is not None:
        KMM = KMM * D[:, None] * D[None, :]

    if rank_deficient:
        # Appendix A Example 2 (eigendecomposition). Static shapes: rank-q
        # truncation is expressed by zeroing the dropped columns of Q and
        # guarding the inverses, so q == M structurally.
        s, U = jnp.linalg.eigh(KMM)                       # ascending
        s = s[::-1]
        U = U[:, ::-1]
        keep = s > (rank_tol * jnp.maximum(s[0], 1e-30))
        s_safe = jnp.where(keep, s, 1.0)
        T = jnp.diag(jnp.sqrt(s_safe))
        Q = U * keep[None, :].astype(dt)
        TTt = jnp.diag(jnp.where(keep, s_safe, 0.0))
        return T, Q, TTt, True

    eps = jitter if jitter is not None else float(jnp.finfo(dt).eps) * M
    T = jnp.linalg.cholesky(KMM + eps * jnp.eye(M, dtype=dt)).T   # upper
    return T, None, T @ T.T, False


def _lam_factor(TTt: Array, lam, M: int) -> Array:
    """Stage 2 — ``A = chol(T T^T / M + lam I)`` (upper): one cheap Cholesky
    per regularization value; vmapped over the grid by the path builder."""
    eye = jnp.eye(TTt.shape[0], dtype=TTt.dtype)
    return jnp.linalg.cholesky(TTt / M + lam * eye).T


def make_preconditioner(
    KMM: Array,
    lam: float,
    n: int,
    *,
    D: Array | None = None,
    jitter: float | None = None,
    rank_deficient: bool = False,
    rank_tol: float = 1e-7,
) -> Preconditioner:
    """Build the FALKON preconditioner from K_MM.

    Cost: 2 Cholesky factorizations + one triangular product = 4/3 M^3 flops
    (paper Sect. 3 "Computations"). ``D`` is the Def. 2 diagonal for
    leverage-score sampling (None for uniform sampling).
    """
    M = KMM.shape[0]
    dt = KMM.dtype
    T, Q, TTt, diag_T = _shared_factor(KMM, D, jitter, rank_deficient,
                                       rank_tol)
    A = _lam_factor(TTt, lam, M)
    return Preconditioner(T=T, A=A, Q=Q, D=D, n=jnp.asarray(n, dt),
                          diag_T=diag_T)


def make_preconditioner_path(
    KMM: Array,
    lams,
    n: int,
    *,
    D: Array | None = None,
    jitter: float | None = None,
    rank_deficient: bool = False,
    rank_tol: float = 1e-7,
) -> PreconditionerPath:
    """One shared factorization, L cheap lam-ridge Cholesky's.

    ``lams`` is the regularization grid ((L,) array-like, each > 0). The
    O(M^3) shared stage runs once; the (L, q, q) ``A`` stack costs L * M^3/3
    on an M x M triangular Gram that is already resident — against L full
    ``make_preconditioner`` calls this saves L-1 Cholesky factorizations of
    K_MM itself, and against L full *fits* it is the enabler for sharing
    every O(nM) data sweep (see ``falkon_solve_path``).
    """
    M = KMM.shape[0]
    dt = KMM.dtype
    lams = jnp.asarray(lams, dt)
    if lams.ndim != 1 or lams.shape[0] < 1:
        raise ValueError(f"lams must be a non-empty 1-D grid, got shape "
                         f"{lams.shape}")
    if not isinstance(lams, jax.core.Tracer) and bool(jnp.any(lams <= 0.0)):
        # a non-positive ridge makes TT^T/M + lam I indefinite and the
        # batched Cholesky returns silent NaNs, not an error — fail here
        # (concrete grids only; traced grids keep the builder jittable)
        raise ValueError(
            f"every lam in the path must be > 0, got {tuple(map(float, lams))}")
    T, Q, TTt, diag_T = _shared_factor(KMM, D, jitter, rank_deficient,
                                       rank_tol)
    A = jax.vmap(lambda lam: _lam_factor(TTt, lam, M))(lams)
    return PreconditionerPath(T=T, A=A, Q=Q, D=D, lams=lams,
                              n=jnp.asarray(n, dt), diag_T=diag_T)
