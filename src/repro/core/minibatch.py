"""Mini-batch FALKON with delayed projections — past the one-sweep-per-step wall.

Exact FALKON pays one full O(nM) data sweep per CG iteration; that sweep IS
the paper's complexity budget, and it is also the wall: at large enough n
even a single pass per update is too expensive. "Fast training of large
kernel models with delayed projections" (PAPERS.md) shows the fix — run the
PRECONDITIONED iteration stochastically over mini-batches and only project
back through the preconditioner every few steps. This module implements that
update rule on the existing `KernelOps` seam:

* **One chunk-sized sweep per stochastic step.** A step over chunk
  ``(X_c, y_c)`` costs exactly ``ops.sweep(X_c, C, gamma, -y_c)`` =
  ``K_cM^T (K_cM gamma - y_c)`` — the v-term trick folds the residual into
  the same fused pass, so the per-step cost is ONE chunk sweep, not a full
  pass and not two chunk passes (`CountingOps`-pinned in the benchmark).
  Ragged chunks ride the `row_mask` zero-contribution contract: pad rows
  add exactly zero to the accumulator and are excluded from the row count
  that normalizes the gradient, so the stochastic gradient is exact over
  the valid rows.
* **Delayed projection.** The expensive part of the preconditioned operator
  is not the triangular solves (O(M^2), invisible next to O(nM) sweeps at
  production chunk sizes) — it is that the textbook iteration re-projects
  ``gamma = right(beta)`` after EVERY step. Here gamma is held fixed
  (deliberately stale) for ``project_every`` chunks while chunk sweeps
  accumulate; one projection then applies the preconditioned gradient
  ``g = left(acc)/rows + lam * ridge(beta)``, a heavy-ball update, tail
  averaging, and a single gamma refresh. ``project_every=1`` degenerates to
  per-chunk preconditioned SGD; ``project_every * chunk_rows >= n`` to full
  preconditioned gradient descent (the gradient is then exact — the
  fixed-point property `partial_fit` tests pin).
* **State is a pytree.** `MinibatchState` carries beta / velocity / the
  tail-average / the sweep accumulator / gamma, so the in-core driver is
  one nested `lax.scan` (epochs -> projection periods -> chunks) and the
  streaming driver is the same update functions host-driven over a
  `ChunkSource` (epoch reshuffling via `repro.data.ShuffledChunkSource`).
* **Step size is preconditioning's reward.** W = B^T H B has cond O(1)
  (paper Lemma 5 / Thm 2), so a fixed step near 1/lam_max(W) converges
  geometrically; ``step_size=None`` estimates lam_max by power iteration on
  a pilot chunk (``power_iters`` extra chunk-sized sweeps, Python-loop eager
  so instrumentation counts them) and takes ``step_safety / lam_max``.

Per-column convergence masking reuses the CG core's helpers (`col_dot`,
`active_columns` from `repro.core.cg`): a converged column of a multi-rhs
block stops taking noisy stochastic steps while the rest keep training.

`falkon_fit_minibatch` / `falkon_fit_minibatch_streaming` in
`repro.core.falkon` compose these drivers with the standard select ->
gram -> precondition pipeline (the preconditioner is factored ONCE, through
the same `FactorPlan` in-core/blocked routing as every other fit, and
reused across all steps); `FalkonEstimator.partial_fit` warm-starts them
from a deployed alpha via `Preconditioner.beta_of_coeffs`.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .cg import active_columns, col_dot
from .preconditioner import Preconditioner

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MinibatchConfig:
    """Knobs of the delayed-projection update rule.

    ``chunk_rows`` rows per stochastic step; ``project_every`` steps between
    projections (the delay); ``epochs`` passes over the data. ``step_size``
    of None auto-estimates ``step_safety / lam_max(W)`` by ``power_iters``
    pilot-chunk power iterations. ``momentum`` is the heavy-ball
    coefficient; ``avg_start`` the fraction of projections after which tail
    averaging begins (averaging from the start would drag the warmup
    transient into the solution). ``tol`` freezes a column once its
    projected-gradient norm drops below ``tol`` times its first value.
    ``shuffle`` reshuffles the chunk/row order every epoch (a fresh
    permutation in-core, a `ShuffledChunkSource` pass under streaming).
    """

    chunk_rows: int = 2048
    project_every: int = 4
    epochs: int = 2
    step_size: float | None = None
    step_safety: float = 0.95
    power_iters: int = 8
    momentum: float = 0.8
    avg_start: float = 0.9
    tol: float = 0.0
    shuffle: bool = True

    def __post_init__(self):
        if self.chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be positive, got {self.chunk_rows}")
        if self.project_every <= 0:
            raise ValueError(
                f"project_every must be positive, got {self.project_every}"
            )
        if self.epochs <= 0:
            raise ValueError(f"epochs must be positive, got {self.epochs}")
        if self.step_size is not None and not self.step_size > 0.0:
            raise ValueError(
                f"step_size must be positive (or None to auto-estimate), "
                f"got {self.step_size}"
            )
        if not 0.0 < self.step_safety <= 2.0:
            raise ValueError(
                f"step_safety must be in (0, 2] (gradient descent diverges "
                f"past 2/lam_max), got {self.step_safety}"
            )
        if self.power_iters <= 0:
            raise ValueError(f"power_iters must be positive, got {self.power_iters}")
        if not 0.0 <= self.momentum < 1.0:
            raise ValueError(f"momentum must be in [0, 1), got {self.momentum}")
        if not 0.0 <= self.avg_start <= 1.0:
            raise ValueError(f"avg_start must be in [0, 1], got {self.avg_start}")
        if self.tol < 0.0:
            raise ValueError(f"tol must be >= 0, got {self.tol}")


class MinibatchState(NamedTuple):
    """The delayed-projection iteration state — a pytree, so the whole loop
    lax.scans in-core and the same functions drive the streaming host loop.

    ``beta`` lives in the preconditioned space (like the CG iterate);
    ``gamma = right(beta)`` is the kernel-space coefficient vector the chunk
    sweeps read — refreshed only at projections, deliberately stale in
    between. ``acc``/``acc_rows`` accumulate the chunk sweeps (and their
    valid-row counts) since the last projection. ``g0_sq`` is the first
    projection's per-column gradient norm^2, the reference the relative
    ``tol`` masks against (negative until the first projection sets it).
    """

    beta: Array  # (q,) or (q, p) preconditioned iterate
    velocity: Array  # heavy-ball momentum buffer, like beta
    beta_bar: Array  # tail average of beta, like beta
    num_avg: Array  # scalar f32: projections averaged so far
    gamma: Array  # (M,...) = right(beta), refreshed at projections
    acc: Array  # (M,...) sum of chunk sweeps at the stale gamma
    acc_rows: Array  # scalar f32: valid rows behind ``acc``
    g0_sq: Array  # per-column reference ||g||^2 for tol masking
    step: Array  # int32 chunk steps taken
    projections: Array  # int32 projections applied


class MinibatchResult(NamedTuple):
    """What a mini-batch solve returns alongside the estimator."""

    state: MinibatchState
    alpha: Array  # coeffs(solution): tail-averaged beta when averaging ran
    grad_norms: Array  # (projections,) or (projections, p) per-column ||g||
    step_size: Array  # the step size actually used (auto-estimated or given)
    pilot_sweeps: int  # chunk-sized sweeps spent estimating the step size
    rows_swept: float  # total rows through sweeps (pads + pilot included)


def minibatch_init(precond: Preconditioner, beta0: Array) -> MinibatchState:
    """Fresh state at ``beta0`` (zeros for a cold start, or
    ``precond.beta_of_coeffs(alpha)`` to warm-start from a deployed model)."""
    gamma = precond.right(beta0)
    f32 = jnp.float32
    return MinibatchState(
        beta=beta0,
        velocity=jnp.zeros_like(beta0),
        beta_bar=jnp.zeros_like(beta0),
        num_avg=jnp.zeros((), f32),
        gamma=gamma,
        acc=jnp.zeros_like(gamma),
        acc_rows=jnp.zeros((), f32),
        g0_sq=-jnp.ones(beta0.shape[1:], f32),
        step=jnp.zeros((), jnp.int32),
        projections=jnp.zeros((), jnp.int32),
    )


def minibatch_step(
    ops,
    centers: Array,
    state: MinibatchState,
    xc: Array,
    yc: Array,
    row_mask: Array | None = None,
) -> MinibatchState:
    """One stochastic step == ONE chunk-sized sweep (the pinned invariant).

    ``sweep(X_c, C, gamma, -y_c) = K_cM^T (K_cM gamma - y_c)`` — the fused
    v-term computes the chunk's residual inside the same pass that applies
    the kernel, so there is no separate apply. The result is only
    ACCUMULATED here; all O(M^2) preconditioner work waits for the
    projection. ``row_mask`` rows at 0 contribute exactly zero and are
    excluded from the normalizing row count (the streaming pad contract).
    """
    wc = ops.sweep(xc, centers, state.gamma, -yc, row_mask=row_mask)
    if row_mask is None:
        rows = jnp.asarray(float(xc.shape[0]), jnp.float32)
    else:
        rows = jnp.sum(row_mask).astype(jnp.float32)
    return state._replace(
        acc=state.acc + wc.astype(state.acc.dtype),
        acc_rows=state.acc_rows + rows,
        step=state.step + 1,
    )


def minibatch_project(
    precond: Preconditioner,
    lam,
    state: MinibatchState,
    *,
    step_size,
    momentum: float,
    avg_after: int,
    tol: float,
) -> tuple[MinibatchState, Array]:
    """The delayed projection: turn the accumulated sweeps into one update.

    ``g = left(acc)/rows + ridge(beta, lam)`` is exactly the preconditioned
    operator residual ``W beta - b`` evaluated on the rows behind ``acc``
    (when a period covers the whole dataset this is the full-batch gradient
    — the degenerate case equals preconditioned gradient descent). Then a
    heavy-ball step, per-column tol masking via the CG helpers, tail
    averaging once ``projections >= avg_after``, and the single gamma
    refresh that ends the staleness window. Returns (state, per-column
    ||g||) — the gradient-norm history is the solver's residual trace.
    """
    denom = jnp.maximum(state.acc_rows, 1.0)
    g = precond.left(state.acc) / denom + precond.ridge(state.beta, lam)
    rs = col_dot(g, g)
    ref = jnp.where(state.g0_sq < 0.0, rs, state.g0_sq)
    active = active_columns(rs, (tol * tol) * ref)

    vel_new = momentum * state.velocity - step_size * g
    beta_new = state.beta + vel_new
    beta = jnp.where(active, beta_new, state.beta)
    velocity = jnp.where(active, vel_new, state.velocity)

    take = (state.projections >= avg_after).astype(jnp.float32)
    num = state.num_avg + take
    beta_bar = jnp.where(
        take > 0.0,
        (state.beta_bar * state.num_avg + beta) / jnp.maximum(num, 1.0),
        state.beta_bar,
    )
    new_state = state._replace(
        beta=beta,
        velocity=velocity,
        beta_bar=beta_bar,
        num_avg=num,
        gamma=precond.right(beta),
        acc=jnp.zeros_like(state.acc),
        acc_rows=jnp.zeros_like(state.acc_rows),
        g0_sq=ref,
        projections=state.projections + 1,
    )
    return new_state, jnp.sqrt(rs)


def minibatch_solution(state: MinibatchState) -> Array:
    """The iterate to read out: the tail average when averaging ran, else
    the last beta (short runs whose avg window never opened)."""
    return jnp.where(state.num_avg > 0.0, state.beta_bar, state.beta)


def estimate_step_size(
    ops,
    centers: Array,
    precond: Preconditioner,
    lam,
    xc: Array,
    row_mask: Array | None,
    *,
    iters: int = 8,
    safety: float = 0.95,
) -> Array:
    """``safety / lam_max(W_pilot)`` by power iteration on ONE pilot chunk.

    ``W_pilot`` is the same preconditioned operator the projection descends,
    with the data term subsampled to the pilot chunk — preconditioning makes
    lam_max(W) ~ 1 + lam-scale (cond O(1), paper Lemma 5), so a chunk-sized
    estimate is plenty. Cost: ``iters`` chunk-sized sweeps, run as an EAGER
    Python loop so `CountingOps` sees every one (the benchmark's sweep
    accounting stays exact). lam_max is read off the last iterate's norm
    growth, so no extra sweep is spent on a final Rayleigh quotient.
    """
    if row_mask is None:
        rows = jnp.asarray(float(xc.shape[0]), jnp.float32)
    else:
        rows = jnp.maximum(jnp.sum(row_mask).astype(jnp.float32), 1.0)

    def w_pilot(u):
        w = ops.sweep(xc, centers, precond.right(u), None, row_mask=row_mask)
        return precond.left(w) / rows + precond.ridge(u, lam)

    q = precond.q
    v = jnp.ones((q,), centers.dtype) / jnp.sqrt(float(q))
    lam_max = jnp.asarray(1.0, centers.dtype)
    for _ in range(iters):
        w = w_pilot(v)
        lam_max = jnp.maximum(jnp.linalg.norm(w), 1e-30)
        v = w / lam_max
    return jnp.asarray(safety, centers.dtype) / lam_max


def _pad_to(a: Array, rows: int) -> Array:
    return jnp.pad(a, ((0, rows - a.shape[0]),) + ((0, 0),) * (a.ndim - 1))


def minibatch_solve(
    X: Array,
    y: Array,
    centers: Array,
    precond: Preconditioner,
    lam,
    mb: MinibatchConfig,
    *,
    ops,
    key: Array,
    beta0: Array | None = None,
) -> MinibatchResult:
    """In-core driver: the whole epoch loop is nested ``lax.scan``s.

    X/y are zero-padded to a whole number of projection periods and the pad
    rows masked out (exactly zero contribution, excluded from the gradient
    normalization), so every chunk of every epoch shares one static sweep
    shape. Each epoch draws a fresh row permutation (``mb.shuffle``; pad
    rows travel with their mask entries). Scan nesting is epochs ->
    projection periods (project at period end — no lax.cond in the hot
    body) -> chunks.
    """
    n = X.shape[0]
    if y.shape[0] != n:
        raise ValueError(f"X has {n} rows but y has {y.shape[0]}")
    c = min(mb.chunk_rows, n)
    k = max(1, min(mb.project_every, -(-n // c)))
    period = k * c
    periods = -(-n // period)
    n_pad = periods * period

    X_pad = _pad_to(X, n_pad)
    y_pad = _pad_to(y, n_pad)
    mask = (jnp.arange(n_pad) < n).astype(jnp.float32)

    if beta0 is None:
        beta0 = jnp.zeros((precond.q,) + y.shape[1:], X.dtype)
    state0 = minibatch_init(precond, beta0)

    pilot_sweeps = 0
    if mb.step_size is None:
        eta = estimate_step_size(
            ops,
            centers,
            precond,
            lam,
            X_pad[:c],
            mask[:c],
            iters=mb.power_iters,
            safety=mb.step_safety,
        )
        pilot_sweeps = mb.power_iters
    else:
        eta = jnp.asarray(mb.step_size, X.dtype)

    total_proj = mb.epochs * periods
    avg_after = int(mb.avg_start * total_proj)

    def chunk_body(state, chunk):
        xcc, ycc, mcc = chunk
        return minibatch_step(ops, centers, state, xcc, ycc, row_mask=mcc), None

    def period_body(state, blk):
        state, _ = jax.lax.scan(chunk_body, state, blk)
        state, gnorm = minibatch_project(
            precond,
            lam,
            state,
            step_size=eta,
            momentum=mb.momentum,
            avg_after=avg_after,
            tol=mb.tol,
        )
        return state, gnorm

    def epoch_body(state, epoch_key):
        if mb.shuffle:
            perm = jax.random.permutation(epoch_key, n_pad)
        else:
            perm = jnp.arange(n_pad)
        xe = X_pad[perm].reshape((periods, k, c) + X.shape[1:])
        ye = y_pad[perm].reshape((periods, k, c) + y.shape[1:])
        me = mask[perm].reshape(periods, k, c)
        return jax.lax.scan(period_body, state, (xe, ye, me))

    state, gnorms = jax.lax.scan(epoch_body, state0, jax.random.split(key, mb.epochs))
    grad_norms = gnorms.reshape((total_proj,) + gnorms.shape[2:])
    beta = minibatch_solution(state)
    return MinibatchResult(
        state=state,
        alpha=precond.coeffs(beta),
        grad_norms=grad_norms,
        step_size=eta,
        pilot_sweeps=pilot_sweeps,
        rows_swept=float(mb.epochs * n_pad + pilot_sweeps * c),
    )


def minibatch_solve_stream(
    loader,
    centers: Array,
    precond: Preconditioner,
    lam,
    mb: MinibatchConfig,
    *,
    ops,
    out_dim: tuple = (),
    beta0: Array | None = None,
    jit_update: bool = True,
) -> MinibatchResult:
    """Streaming driver: the same update functions, host-driven over chunks.

    ``loader`` is a re-iterable of (X_chunk, y_chunk) device pairs (a
    `StreamingLoader`; wrap the source in `repro.data.ShuffledChunkSource`
    for epoch reshuffling — `falkon_fit_minibatch_streaming` does). Ragged
    tails are padded to the loader's declared ``chunk_rows`` under the
    `row_mask` contract so every step shares one compiled sweep shape. The
    per-chunk cost invariant is host-visible here: with ``jit_update=False``
    every step is an eager `ops.sweep` call, which is how the benchmark's
    `CountingOps` proves one-chunk-sweep-per-step EXACTLY (the jitted
    default trades that visibility for compile-once speed).
    """
    n = loader.n_rows
    chunk_rows = loader.chunk_rows
    if not chunk_rows:
        raise ValueError(
            "minibatch_solve_stream needs the loader's source to declare "
            "chunk_rows (the one compiled sweep shape every step shares)"
        )
    num_chunks = -(-n // chunk_rows)
    k = max(1, min(mb.project_every, num_chunks))
    proj_per_epoch = -(-num_chunks // k)
    total_proj = mb.epochs * proj_per_epoch
    avg_after = int(mb.avg_start * total_proj)

    if beta0 is None:
        beta0 = jnp.zeros((precond.q,) + tuple(out_dim), centers.dtype)
    state = minibatch_init(precond, beta0)

    def step_fn(state, xc, yc, mask):
        return minibatch_step(ops, centers, state, xc, yc, row_mask=mask)

    def project_fn(state, eta):
        return minibatch_project(
            precond,
            lam,
            state,
            step_size=eta,
            momentum=mb.momentum,
            avg_after=avg_after,
            tol=mb.tol,
        )

    if jit_update:
        step_fn = jax.jit(step_fn)
        project_fn = jax.jit(project_fn)

    full_mask = jnp.ones((chunk_rows,), jnp.float32)

    def padded(xc, yc):
        nc = xc.shape[0]
        if nc == chunk_rows:
            return xc, yc, full_mask
        return (
            _pad_to(xc, chunk_rows),
            _pad_to(yc, chunk_rows),
            (jnp.arange(chunk_rows) < nc).astype(jnp.float32),
        )

    pilot_sweeps = 0
    if mb.step_size is None:
        for xc, yc in loader:
            if yc is None:
                raise ValueError("minibatch_solve_stream needs targets in the source")
            xp, _, mp = padded(xc, yc)
            eta = estimate_step_size(
                ops,
                centers,
                precond,
                lam,
                xp,
                mp,
                iters=mb.power_iters,
                safety=mb.step_safety,
            )
            pilot_sweeps = mb.power_iters
            break
    else:
        eta = jnp.asarray(mb.step_size, centers.dtype)

    gnorms = []
    rows_swept = float(pilot_sweeps * chunk_rows)
    for _ in range(mb.epochs):
        in_period = 0
        for xc, yc in loader:
            if yc is None:
                raise ValueError("minibatch_solve_stream needs targets in the source")
            xp, yp, mp = padded(xc, yc)
            state = step_fn(state, xp, yp, mp)
            rows_swept += float(chunk_rows)
            in_period += 1
            if in_period == k:
                state, gn = project_fn(state, eta)
                gnorms.append(gn)
                in_period = 0
        if in_period:
            state, gn = project_fn(state, eta)
            gnorms.append(gn)

    beta = minibatch_solution(state)
    return MinibatchResult(
        state=state,
        alpha=precond.coeffs(beta),
        grad_norms=jnp.stack(gnorms),
        step_size=eta,
        pilot_sweeps=pilot_sweeps,
        rows_swept=rows_swept,
    )
