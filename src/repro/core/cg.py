"""Conjugate gradient for the FALKON preconditioned system.

Matches the paper's Alg. 2 ``conjgrad``: plain CG (the system W = B^T H B is
symmetric positive definite by construction, Lemma 5), fixed iteration count so
the whole solve jits into one XLA program, with an optional residual tolerance
implemented as a masked no-op (keeps the program shape static, which is what we
need for pjit/shard_map and for the dry-run).

Supports multiple right-hand sides (b of shape (q,) or (q, p)) — multiclass
problems (TIMIT / IMAGENET in the paper) solve all one-vs-all systems in one CG
run; the per-column scalars are kept separate. The lam-path solver stacks L
INDEPENDENT regularization systems along the same column axis (see
``falkon_solve_path``): because every scalar of the recurrence is per-column,
a (q, L*p) block is exactly L*p independent CG runs that share each matvec —
per-system convergence masking falls out of the per-column masking for free.

Both drivers — the in-core ``lax.scan`` one (``conjugate_gradient``) and the
host-loop one for streaming matvecs (``conjugate_gradient_host``) — are thin
shells over one shared core (``_cg_solve``): same initialization, same masked
update (``_masked_cg_update``), same residual bookkeeping, so the in-core and
out-of-core solves cannot numerically diverge and any capability added to the
update (multi-rhs, lam-path stacking, reduced-storage iterates) reaches both
for free. They differ ONLY in the loop: the scanned driver keeps the program
shape static (converged columns become masked no-ops), the host driver may
``break`` early once every column has converged — each skipped iteration is
a full data pass saved — which truncates ``residual_norms`` to
``iterations + 1`` entries (a pinned contract, see tests/test_cg_drivers.py).

``storage_dtype`` (the bf16 end-to-end policy's knob, threaded from
``PrecisionPolicy.storage`` by ``falkon_solve``) stores the CG iterates
x/r/p at reduced width — they are the (q, p) vectors every sweep reads and
writes — while ALL scalars (alpha, beta, rs, residual norms) and the update
arithmetic stay float32: the recurrence is computed full-precision and only
the iterates are rounded back to storage. ``storage_dtype=None`` (default)
is byte-for-byte the pre-policy fp32 path.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class CGResult(NamedTuple):
    x: Array
    residual_norms: Array  # (t+1,) or (t+1, p): ||r||_2 after each iteration
    iterations: Array      # scalar int: iterations actually applied (tol-aware)


def col_dot(u, v):
    """Per-column inner products: (q,) -> scalar, (q, p) -> (p,).

    The reduction every per-column scalar of the CG recurrence is built
    from; the mini-batch solver reuses it for its per-projection gradient
    norms so the two solvers share one definition of "column magnitude".
    """
    return jnp.sum(u * v, axis=0)


_col_dot = col_dot  # internal alias kept for call-site symmetry below


def active_columns(rs, tol_sq):
    """The per-column "still iterating" mask both solvers share.

    A column whose squared residual/gradient norm ``rs`` has dropped to
    ``tol_sq`` (floored at 1e-30 so a tol of 0 still masks exact zeros,
    whose rs/denom ratios would otherwise overflow) is DONE: CG turns its
    update into a masked no-op (``_masked_cg_update``), and the mini-batch
    projection freezes its beta/velocity the same way — converged columns
    of a multi-rhs block must not keep taking noisy stochastic steps.
    """
    return rs > jnp.maximum(tol_sq, 1e-30)


def _masked_cg_update(x, r, p, rs, Ap, tol_sq, storage=None):
    """One CG update with PER-COLUMN convergence masking.

    Once a column's residual hits fp32 noise, rs/denom can overflow and
    poison every later iterate of that column (observed on one-vs-all
    systems with rare classes) — converged columns become masked no-ops.
    Shared by the scanned (``conjugate_gradient``) and host-loop
    (``conjugate_gradient_host``) drivers so the in-core and streaming
    solves cannot numerically diverge. Returns the updated
    (x, r, p, rs, active) with ``active`` the pre-update mask.

    With ``storage`` set the incoming iterates are promoted to float32, the
    whole update (alpha/beta/norm scalars included) is computed in float32,
    and only the outgoing x/r/p are rounded back to ``storage``.
    """
    if storage is not None:
        f32 = jnp.float32
        x, r, p, Ap = (a.astype(f32) for a in (x, r, p, Ap))
        rs = rs.astype(f32)
    active = active_columns(rs, tol_sq)
    denom = _col_dot(p, Ap)
    a = jnp.where(active & (denom > 1e-38), rs / jnp.maximum(denom, 1e-38), 0.0)
    x_new = x + a * p
    r_new = r - a * Ap
    rs_new = _col_dot(r_new, r_new)
    beta = jnp.where(active, rs_new / jnp.maximum(rs, 1e-38), 0.0)
    p_new = r_new + beta * p
    sel = lambda new, old: jnp.where(active, new, old)
    x, r, p, rs = (sel(x_new, x), sel(r_new, r), sel(p_new, p), sel(rs_new, rs))
    if storage is not None:
        x, r, p = (a.astype(storage) for a in (x, r, p))
    return x, r, p, rs, active


def _cg_init(matvec, b, x0, storage):
    """Shared iterate/residual initialization for both drivers."""
    if x0 is None:
        x = jnp.zeros_like(b)
        r = b
    else:
        x = x0
        r = b - matvec(x0)
    p = r
    if storage is not None:
        x, r, p = (a.astype(storage) for a in (x, r, p))
        rs = _col_dot(r.astype(b.dtype), r.astype(b.dtype))
    else:
        rs = _col_dot(r, r)
    return x, r, p, rs


def _scan_driver(matvec, state, t, tol_sq, storage, res0):
    """Fixed-length ``lax.scan`` loop — one static XLA program; converged
    columns become masked no-ops (the dry-run wants the full-t shape)."""
    def step(carry, _):
        x, r, p, rs, it = carry
        Ap = matvec(p)
        x, r, p, rs, active = _masked_cg_update(
            x, r, p, rs, Ap, tol_sq, storage=storage
        )
        carry = (x, r, p, rs, it + jnp.any(active).astype(jnp.int32))
        return carry, jnp.sqrt(jnp.maximum(rs, 0.0))

    (x, r, p, rs, it), res_hist = jax.lax.scan(
        step, state + (jnp.asarray(0, jnp.int32),), None, length=t
    )
    return CGResult(
        x=x, residual_norms=jnp.concatenate([res0, res_hist], axis=0), iterations=it
    )


def _host_driver(matvec, state, t, tol_sq, storage, res0):
    """Python-level loop for host-streaming matvecs; stops early once every
    column has converged (each skipped iteration is a full data pass), so
    ``residual_norms`` is truncated to ``iterations + 1`` entries."""
    x, r, p, rs = state
    residuals = [res0]
    it = 0
    for _ in range(t):
        if not bool(jnp.any(active_columns(rs, tol_sq))):
            break  # every column converged — skip the remaining data passes
        Ap = matvec(p)
        x, r, p, rs, _ = _masked_cg_update(x, r, p, rs, Ap, tol_sq, storage=storage)
        residuals.append(jnp.sqrt(jnp.maximum(rs, 0.0))[None])
        it += 1
    return CGResult(
        x=x,
        residual_norms=jnp.concatenate(residuals, axis=0),
        iterations=jnp.asarray(it, jnp.int32),
    )


def _cg_solve(matvec, b, t, tol, x0, storage_dtype, driver):
    """The one CG core both public drivers share: initialization, tolerance
    scaling and the ||b|| history head are computed identically, then the
    ``driver`` runs the shared masked update in its loop style."""
    storage = None if storage_dtype is None else jnp.dtype(storage_dtype)
    state = _cg_init(matvec, b, x0, storage)
    b_norm_sq = jnp.maximum(_col_dot(b, b), 1e-38)
    tol_sq = (tol * tol) * b_norm_sq
    # ||b|| leads the history; [None] gives the (1,)/(1, p) leading entry
    # for single- and multi-rhs alike.
    res0 = jnp.sqrt(jnp.maximum(_col_dot(b, b), 0.0))[None]
    return driver(matvec, state, t, tol_sq, storage, res0)


def conjugate_gradient(
    matvec: Callable[[Array], Array],
    b: Array,
    t: int,
    *,
    tol: float = 0.0,
    x0: Array | None = None,
    storage_dtype=None,
) -> CGResult:
    """Run ``t`` CG iterations on ``matvec(x) = b``.

    When ``tol > 0`` iterations whose residual norm has already dropped below
    ``tol * ||b||`` become masked no-ops (identical output, static shape).
    ``storage_dtype`` stores the iterates x/r/p at reduced width (bf16
    policy) while scalars and update arithmetic stay float32; None is the
    unchanged full-precision path.
    """
    return _cg_solve(matvec, b, t, tol, x0, storage_dtype, _scan_driver)


def conjugate_gradient_host(
    matvec: Callable[[Array], Array],
    b: Array,
    t: int,
    *,
    tol: float = 0.0,
    x0: Array | None = None,
    storage_dtype=None,
) -> CGResult:
    """Python-loop twin of ``conjugate_gradient`` for host-streaming matvecs.

    The streaming sweep is a host loop over data chunks (one full pass per
    CG iteration), which cannot be traced inside ``lax.scan`` — so the CG
    recurrence itself runs at the Python level via the same shared core and
    masking math (and the same ``storage_dtype`` contract) as the scanned
    version. Unlike the scanned version it may stop early once every column
    has converged (there is no static-shape program to preserve
    out-of-core); ``residual_norms`` then has ``iterations + 1`` entries
    instead of ``t + 1``.
    """
    return _cg_solve(matvec, b, t, tol, x0, storage_dtype, _host_driver)
