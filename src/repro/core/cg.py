"""Conjugate gradient for the FALKON preconditioned system.

Matches the paper's Alg. 2 ``conjgrad``: plain CG (the system W = B^T H B is
symmetric positive definite by construction, Lemma 5), fixed iteration count so
the whole solve jits into one XLA program, with an optional residual tolerance
implemented as a masked no-op (keeps the program shape static, which is what we
need for pjit/shard_map and for the dry-run).

Supports multiple right-hand sides (b of shape (q,) or (q, p)) — multiclass
problems (TIMIT / IMAGENET in the paper) solve all one-vs-all systems in one CG
run; the per-column scalars are kept separate.

``storage_dtype`` (the bf16 end-to-end policy's knob, threaded from
``PrecisionPolicy.storage`` by ``falkon_solve``) stores the CG iterates
x/r/p at reduced width — they are the (q, p) vectors every sweep reads and
writes — while ALL scalars (alpha, beta, rs, residual norms) and the update
arithmetic stay float32: the recurrence is computed full-precision and only
the iterates are rounded back to storage. ``storage_dtype=None`` (default)
is byte-for-byte the pre-policy fp32 path.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class CGResult(NamedTuple):
    x: Array
    residual_norms: Array  # (t+1,) or (t+1, p): ||r||_2 after each iteration
    iterations: Array      # scalar int: iterations actually applied (tol-aware)


def _col_dot(u, v):
    return jnp.sum(u * v, axis=0)  # per-column inner products


def _masked_cg_update(x, r, p, rs, Ap, tol_sq, storage=None):
    """One CG update with PER-COLUMN convergence masking.

    Once a column's residual hits fp32 noise, rs/denom can overflow and
    poison every later iterate of that column (observed on one-vs-all
    systems with rare classes) — converged columns become masked no-ops.
    Shared by the scanned (``conjugate_gradient``) and host-loop
    (``conjugate_gradient_host``) drivers so the in-core and streaming
    solves cannot numerically diverge. Returns the updated
    (x, r, p, rs, active) with ``active`` the pre-update mask.

    With ``storage`` set the incoming iterates are promoted to float32, the
    whole update (alpha/beta/norm scalars included) is computed in float32,
    and only the outgoing x/r/p are rounded back to ``storage``.
    """
    if storage is not None:
        f32 = jnp.float32
        x, r, p, Ap = (a.astype(f32) for a in (x, r, p, Ap))
        rs = rs.astype(f32)
    active = rs > jnp.maximum(tol_sq, 1e-30)
    denom = _col_dot(p, Ap)
    a = jnp.where(active & (denom > 1e-38),
                  rs / jnp.maximum(denom, 1e-38), 0.0)
    x_new = x + a * p
    r_new = r - a * Ap
    rs_new = _col_dot(r_new, r_new)
    beta = jnp.where(active, rs_new / jnp.maximum(rs, 1e-38), 0.0)
    p_new = r_new + beta * p
    sel = lambda new, old: jnp.where(active, new, old)
    x, r, p, rs = (sel(x_new, x), sel(r_new, r), sel(p_new, p),
                   sel(rs_new, rs))
    if storage is not None:
        x, r, p = (a.astype(storage) for a in (x, r, p))
    return x, r, p, rs, active


def conjugate_gradient(
    matvec: Callable[[Array], Array],
    b: Array,
    t: int,
    *,
    tol: float = 0.0,
    x0: Array | None = None,
    storage_dtype=None,
) -> CGResult:
    """Run ``t`` CG iterations on ``matvec(x) = b``.

    When ``tol > 0`` iterations whose residual norm has already dropped below
    ``tol * ||b||`` become masked no-ops (identical output, static shape).
    ``storage_dtype`` stores the iterates x/r/p at reduced width (bf16
    policy) while scalars and update arithmetic stay float32; None is the
    unchanged full-precision path.
    """
    storage = None if storage_dtype is None else jnp.dtype(storage_dtype)
    if x0 is None:
        x = jnp.zeros_like(b)
        r = b
    else:
        x = x0
        r = b - matvec(x0)
    p = r
    if storage is not None:
        x, r, p = (a.astype(storage) for a in (x, r, p))

    rs = _col_dot(r.astype(b.dtype), r.astype(b.dtype)) if storage is not None \
        else _col_dot(r, r)
    b_norm_sq = jnp.maximum(_col_dot(b, b), 1e-38)
    tol_sq = (tol * tol) * b_norm_sq

    def step(carry, _):
        x, r, p, rs, it = carry
        Ap = matvec(p)
        # masked no-op once converged (keeps shapes static — the dry-run
        # wants the full-t program)
        x, r, p, rs, active = _masked_cg_update(x, r, p, rs, Ap, tol_sq,
                                                storage=storage)
        carry = (x, r, p, rs, it + jnp.any(active).astype(jnp.int32))
        return carry, jnp.sqrt(jnp.maximum(rs, 0.0))

    (x, r, p, rs, it), res_hist = jax.lax.scan(
        step, (x, r, p, rs, jnp.asarray(0, jnp.int32)), None, length=t
    )
    res0 = jnp.sqrt(jnp.maximum(_col_dot(b, b), 0.0))[None] if b.ndim > 1 else \
        jnp.sqrt(jnp.maximum(_col_dot(b, b), 0.0))[None]
    residuals = jnp.concatenate([res0, res_hist], axis=0)
    return CGResult(x=x, residual_norms=residuals, iterations=it)


def conjugate_gradient_host(
    matvec: Callable[[Array], Array],
    b: Array,
    t: int,
    *,
    tol: float = 0.0,
    x0: Array | None = None,
    storage_dtype=None,
) -> CGResult:
    """Python-loop twin of ``conjugate_gradient`` for host-streaming matvecs.

    The streaming sweep is a host loop over data chunks (one full pass per
    CG iteration), which cannot be traced inside ``lax.scan`` — so the CG
    recurrence itself runs at the Python level, with the same per-column
    masking math (and the same ``storage_dtype`` contract) as the scanned
    version. Unlike the scanned version it may stop early once every column
    has converged (there is no static-shape program to preserve
    out-of-core).
    """
    storage = None if storage_dtype is None else jnp.dtype(storage_dtype)
    if x0 is None:
        x = jnp.zeros_like(b)
        r = b
    else:
        x = x0
        r = b - matvec(x0)
    p = r
    if storage is not None:
        x, r, p = (a.astype(storage) for a in (x, r, p))

    rs = _col_dot(r.astype(b.dtype), r.astype(b.dtype)) if storage is not None \
        else _col_dot(r, r)
    b_norm_sq = jnp.maximum(_col_dot(b, b), 1e-38)
    tol_sq = (tol * tol) * b_norm_sq
    residuals = [jnp.sqrt(jnp.maximum(b_norm_sq, 0.0))[None]
                 if b.ndim > 1 else jnp.sqrt(jnp.maximum(b_norm_sq, 0.0))]
    it = 0

    for _ in range(t):
        if not bool(jnp.any(rs > jnp.maximum(tol_sq, 1e-30))):
            break  # every column converged — skip the remaining data passes
        Ap = matvec(p)
        x, r, p, rs, _ = _masked_cg_update(x, r, p, rs, Ap, tol_sq,
                                           storage=storage)
        res = jnp.sqrt(jnp.maximum(rs, 0.0))
        residuals.append(res[None] if b.ndim > 1 else res)
        it += 1

    if b.ndim > 1:
        res_hist = jnp.concatenate(residuals, axis=0)
    else:
        res_hist = jnp.stack(residuals, axis=0)
    return CGResult(x=x, residual_norms=res_hist,
                    iterations=jnp.asarray(it, jnp.int32))
