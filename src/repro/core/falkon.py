"""FALKON solver (paper Alg. 1 / Alg. 2) — composable JAX module.

Single-device path mirrors Alg. 1 line by line; the distributed path shards the
data sweep over the mesh data axes (see matvec.py) — the preconditioner and the
(q,)-sized CG state are replicated (they are O(M^2)/O(M), the paper's memory
budget).

All kernel work flows through a pluggable ``KernelOps`` backend
(``repro.ops``): ``FalkonConfig.ops_impl`` selects it ("jnp" reference or
"pallas" fused single-pass sweep) and ``FalkonConfig.precision`` names the
``PrecisionPolicy`` — "fp32", or "bf16" for END-TO-END bfloat16 storage
(X/C/u/v/t, the CG iterates, the streamed chunks) with compensated fp32
accumulation; the Gram block and preconditioner Cholesky stay fp32 by
per-buffer override. ``matvec_impl`` is kept as a deprecated alias of
``ops_impl``.

The solve is fully jittable: ``falkon_solve`` is a pure function of
(X, y, centers, preconditioner) so it can be lowered/compiled for the dry-run
like any train_step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.ops import KernelOps, get_ops

from .cg import conjugate_gradient, conjugate_gradient_host
from .kernels import KernelFn, make_kernel
from .matvec import make_distributed_matvec
from .nystrom import select_centers
from .preconditioner import Preconditioner, make_preconditioner

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FalkonConfig:
    kernel: str = "gaussian"
    kernel_params: tuple = (("sigma", 1.0),)
    lam: float = 1e-6
    num_centers: int = 1024
    iterations: int = 20
    center_selection: str = "uniform"      # "uniform" | "leverage"
    pilot_size: int = 256                  # leverage-score pilot subset
    block_size: int = 2048
    jitter: float | None = None
    rank_deficient: bool = False
    ops_impl: str = "jnp"                  # KernelOps backend: "jnp" | "pallas"
    precision: str = "fp32"                # PrecisionPolicy name: "fp32" |
                                           # "bf16" (end-to-end bf16 storage,
                                           # compensated fp32 accumulation)
    matvec_impl: str | None = None         # deprecated alias of ops_impl
    tol: float = 0.0
    dtype: str = "float32"

    @property
    def impl(self) -> str:
        """Resolved backend name (honors the deprecated ``matvec_impl``)."""
        return self.matvec_impl if self.matvec_impl is not None else self.ops_impl

    def make_kernel(self) -> KernelFn:
        return make_kernel(self.kernel, **dict(self.kernel_params))

    def make_ops(self, kernel: KernelFn | None = None) -> KernelOps:
        return get_ops(self.impl, kernel if kernel is not None
                       else self.make_kernel(),
                       block_size=self.block_size, precision=self.precision)


class FalkonState(NamedTuple):
    """Everything needed to run / resume the iterative solve."""
    centers: Array
    precond: Preconditioner
    beta: Array
    alpha: Array
    residual_norms: Array
    cond_estimate: Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FalkonEstimator:
    centers: Array
    alpha: Array
    kernel: KernelFn
    block_size: int = dataclasses.field(metadata=dict(static=True), default=2048)
    ops_impl: str = dataclasses.field(metadata=dict(static=True), default="jnp")
    precision: str = dataclasses.field(metadata=dict(static=True), default="fp32")

    def _ops(self) -> KernelOps:
        return get_ops(self.ops_impl, self.kernel, block_size=self.block_size,
                       precision=self.precision)

    def predict(self, X: Array) -> Array:
        return self._ops().apply(X, self.centers, self.alpha)

    @functools.cached_property
    def _jitted_ops(self):
        # cached on the instance (writes __dict__ directly, so frozen is
        # fine): repeat predict_stream calls reuse the same jit wrappers
        # and therefore the same XLA compile cache per chunk shape.
        from repro.data.streaming import JittedOps
        return JittedOps(self._ops())

    def predict_stream(self, loader) -> Array:
        """Predict over a ``StreamingLoader``/iterable of (X_chunk, _) pairs
        — X need never be device-resident at once (see repro.data.streaming).
        """
        from repro.data.streaming import streaming_apply
        return streaming_apply(self._jitted_ops, loader, self.centers,
                               self.alpha)

    def __call__(self, X: Array) -> Array:
        return self.predict(X)


# ----------------------------------------------------------------------------
# Pure solve (jittable)
# ----------------------------------------------------------------------------
def _cg_storage(ops: KernelOps | None):
    """The CG iterate storage dtype the backend's precision policy implies.

    Under the bf16 end-to-end policy the CG vectors x/r/p — the (q, p)
    buffers every sweep reads — are stored bfloat16 with all scalars fp32
    (see repro.core.cg); the fp32 policy returns None, i.e. the unchanged
    full-precision recurrence.
    """
    pol = getattr(ops, "policy", None)
    if pol is None or pol.storage == "float32":
        return None
    return pol.storage


def _falkon_operator(
    matvec: Callable,
    precond: Preconditioner,
    lam: float,
    n: int,
) -> Callable[[Array], Array]:
    """W(u) = B^T H B u via Alg. 1's nested-solve composition.

    W u = left( KnM^T(KnM gamma)/n ) + lam * A^{-T} A^{-1} u,
    gamma = right(u). The lam-term uses the T^{-T} Q^T D K_MM D Q T^{-1} = I
    identity (Lemma 2 / Eq. 19), exactly as the MATLAB code does.
    """
    from jax.scipy.linalg import solve_triangular

    def W(u: Array) -> Array:
        gamma = precond.right(u)
        w = matvec(gamma) / n                     # K_nM^T K_nM gamma / n
        out = precond.left(w)
        Ainv_u = solve_triangular(precond.A, u, lower=False)
        out = out + lam * solve_triangular(precond.A, Ainv_u, lower=False, trans=1)
        return out

    return W


def falkon_solve(
    X: Array,
    y: Array,
    centers: Array,
    precond: Preconditioner,
    kernel: KernelFn,
    lam: float,
    t: int,
    *,
    block_size: int = 2048,
    ops_impl: str = "jnp",
    precision: str = "fp32",
    matvec_impl: str | None = None,
    tol: float = 0.0,
    dist_matvec: Callable | None = None,
    estimate_cond: bool = True,
    ops: KernelOps | None = None,
) -> FalkonState:
    """Run t preconditioned-CG iterations; return coefficients + diagnostics.

    The per-iteration sweep runs on ``ops`` if given, else on the KernelOps
    backend named by ``ops_impl`` (``matvec_impl`` is a deprecated alias) —
    unless a ``dist_matvec`` (already backend-bound, see
    ``make_distributed_matvec``) is supplied.
    """
    n = X.shape[0]
    if ops is None:
        impl = matvec_impl if matvec_impl is not None else ops_impl
        ops = get_ops(impl, kernel, block_size=block_size, precision=precision)

    if dist_matvec is None:
        def matvec(g):
            return ops.sweep(X, centers, g, None)
        def rhs_sweep():
            zeros = jnp.zeros((centers.shape[0],) + y.shape[1:], X.dtype)
            return ops.sweep(X, centers, zeros, y)
    else:
        zeros_u = jnp.zeros((centers.shape[0],) + y.shape[1:], X.dtype)
        matvec = lambda g: dist_matvec(X, centers, g, jnp.zeros_like(y))
        rhs_sweep = lambda: dist_matvec(X, centers, zeros_u, y)

    W = _falkon_operator(matvec, precond, lam, n)
    b = precond.left(rhs_sweep() / n)             # r = B^T z / n (Alg. 1)

    cg = conjugate_gradient(W, b, t, tol=tol,
                            storage_dtype=_cg_storage(ops))
    alpha = precond.coeffs(cg.x)

    if not estimate_cond:
        return FalkonState(centers=centers, precond=precond, beta=cg.x,
                           alpha=alpha, residual_norms=cg.residual_norms,
                           cond_estimate=jnp.zeros((), X.dtype))

    # Power-iteration estimate of cond(W) — cheap diagnostic for Thm 2.
    def power(mv, q, iters=12):
        v = jnp.ones((q,), b.dtype) / jnp.sqrt(q)
        def step(v, _):
            w = mv(v)
            return w / jnp.maximum(jnp.linalg.norm(w), 1e-30), None
        v, _ = jax.lax.scan(step, v, None, length=iters)
        return jnp.vdot(v, mv(v))

    q = precond.q
    lam_max = power(lambda v: W(v.reshape((q,) + (1,) * (b.ndim - 1))).reshape(q), q)
    lam_min = lam_max - power(
        lambda v: lam_max * v - W(v.reshape((q,) + (1,) * (b.ndim - 1))).reshape(q), q
    )
    cond = jnp.abs(lam_max) / jnp.maximum(jnp.abs(lam_min), 1e-30)

    return FalkonState(centers=centers, precond=precond, beta=cg.x, alpha=alpha,
                       residual_norms=cg.residual_norms, cond_estimate=cond)


# ----------------------------------------------------------------------------
# User-facing fit
# ----------------------------------------------------------------------------
def falkon_fit(
    key: Array,
    X: Array,
    y: Array,
    config: FalkonConfig,
    *,
    mesh: Mesh | None = None,
    data_axes: tuple[str, ...] = ("data",),
) -> tuple[FalkonEstimator, FalkonState]:
    """Select centers, build the preconditioner, run the solve.

    With ``mesh`` given, X/y are swept shard-locally over ``data_axes`` and
    reduced with one psum per CG iteration (see DESIGN.md §6). The K_MM Gram
    block, every CG sweep and the returned estimator's predict path all run
    on the backend named by ``config.ops_impl``.
    """
    kernel = config.make_kernel()
    ops = config.make_ops(kernel)
    dt = jnp.dtype(config.dtype)
    X = X.astype(dt)
    y = y.astype(dt)
    n = X.shape[0]
    M = min(config.num_centers, n)

    sel = select_centers(key, X, M, kernel=kernel, lam=config.lam,
                         scheme=config.center_selection,
                         pilot_size=config.pilot_size)
    KMM = ops.gram(sel.centers, sel.centers)
    precond = make_preconditioner(
        KMM, config.lam, n, D=sel.D, jitter=config.jitter,
        rank_deficient=config.rank_deficient,
    )

    dist = None
    if mesh is not None:
        dist = make_distributed_matvec(mesh, data_axes, kernel,
                                       block_size=config.block_size,
                                       impl=config.impl,
                                       precision=config.precision)

    state = falkon_solve(
        X, y, sel.centers, precond, kernel, config.lam, config.iterations,
        block_size=config.block_size, tol=config.tol, dist_matvec=dist,
        ops=ops,
    )
    est = FalkonEstimator(centers=sel.centers, alpha=state.alpha, kernel=kernel,
                          block_size=config.block_size, ops_impl=config.impl,
                          precision=config.precision)
    return est, state


# ----------------------------------------------------------------------------
# Out-of-core fit: X streamed from the host, never device-resident at once
# ----------------------------------------------------------------------------
def falkon_solve_streaming(
    loader,
    centers: Array,
    precond: Preconditioner,
    lam: float,
    t: int,
    *,
    ops: KernelOps,
    out_dim: tuple = (),
    tol: float = 0.0,
) -> FalkonState:
    """``falkon_solve`` with every data sweep streamed through ``loader``.

    ``loader`` is a re-iterable of (X_chunk, y_chunk) device pairs (see
    ``repro.data.StreamingLoader``); one CG iteration = one full pass over
    the stream, chunk sweeps accumulated on the device — O(chunk + M^2)
    device memory for any n. The CG recurrence runs at the Python level
    (``conjugate_gradient_host``): a host loop cannot live inside lax.scan,
    which also means per-chunk sweeps still jit/cache by chunk shape while
    the solve itself is not one fused XLA program. ``out_dim`` is y's
    trailing shape: () for single-output, (p,) for multi-rhs.
    """
    from repro.data.streaming import JittedOps, streaming_sweep

    n = loader.n_rows
    M = centers.shape[0]
    jops = JittedOps(ops)  # chunks of one shape compile once, not per call

    def matvec(g):
        return streaming_sweep(jops, loader, centers, g, use_targets=False)

    def rhs_sweep():
        zeros = jnp.zeros((M,) + tuple(out_dim), centers.dtype)
        return streaming_sweep(jops, loader, centers, zeros, use_targets=True)

    W = _falkon_operator(matvec, precond, lam, n)
    b = precond.left(rhs_sweep() / n)
    cg = conjugate_gradient_host(W, b, t, tol=tol,
                                 storage_dtype=_cg_storage(ops))
    alpha = precond.coeffs(cg.x)
    return FalkonState(centers=centers, precond=precond, beta=cg.x,
                       alpha=alpha, residual_norms=cg.residual_norms,
                       cond_estimate=jnp.zeros((), b.dtype))


def falkon_fit_streaming(
    key: Array,
    source,
    config: FalkonConfig,
    *,
    prefetch: int | None = None,
    centers: Array | None = None,
) -> tuple[FalkonEstimator, FalkonState]:
    """Fit FALKON from a ``ChunkSource`` without materializing X on device.

    Centers are sampled uniformly in one host-side pass (exact, not
    reservoir-approximate — n_rows is known), the M x M preconditioner is
    built in-core (the paper's memory budget), then every CG sweep streams
    the chunks through a double-buffered host->device loader. Only
    ``center_selection="uniform"`` is supported out-of-core: leverage-score
    sampling needs a pilot Gram pass that is not chunk-additive.
    ``centers`` overrides sampling (used by parity tests). ``prefetch``
    defaults to 2 chunks in flight on real accelerators and to synchronous
    transfers on CPU, where an overlap thread only contends with compute.
    """
    from repro.data.streaming import StreamingLoader, streaming_uniform_centers

    if prefetch is None:
        prefetch = 0 if jax.default_backend() == "cpu" else 2

    if config.center_selection != "uniform" and centers is None:
        raise ValueError(
            "streaming fit supports center_selection='uniform' only "
            f"(got {config.center_selection!r})")

    kernel = config.make_kernel()
    ops = config.make_ops(kernel)
    dt = jnp.dtype(config.dtype)
    n = source.n_rows
    M = min(config.num_centers, n)

    if centers is None:
        centers, _ = streaming_uniform_centers(key, source, M)
    centers = jnp.asarray(centers, dt)
    KMM = ops.gram(centers, centers)
    precond = make_preconditioner(
        KMM, config.lam, n, D=None, jitter=config.jitter,
        rank_deficient=config.rank_deficient,
    )

    # Under the bf16 policy the host->device chunk transfer itself runs at
    # storage width — half the PCIe/DMA traffic of an fp32 stream; the
    # backend would only re-quantize an fp32 chunk on arrival anyway.
    pol = getattr(ops, "policy", None)
    loader_dt = (jnp.dtype(pol.storage)
                 if pol is not None and pol.storage != "float32" else dt)
    loader = StreamingLoader(source, prefetch=prefetch, dtype=loader_dt)
    # y's trailing shape from one peeked chunk (hosts only, no transfer)
    out_dim: tuple = ()
    for _, yc in source.chunks():
        if yc is None:
            raise ValueError("streaming fit needs targets in the source")
        out_dim = tuple(yc.shape[1:])
        break

    state = falkon_solve_streaming(
        loader, centers, precond, config.lam, config.iterations,
        ops=ops, out_dim=out_dim, tol=config.tol,
    )
    est = FalkonEstimator(centers=centers, alpha=state.alpha, kernel=kernel,
                          block_size=config.block_size, ops_impl=config.impl,
                          precision=config.precision)
    return est, state
