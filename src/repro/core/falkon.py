"""FALKON solver (paper Alg. 1 / Alg. 2) — composable JAX module.

Single-device path mirrors Alg. 1 line by line; the distributed path shards
the data sweep over the mesh data axes — the preconditioner and the
(q,)-sized CG state are replicated (they are O(M^2)/O(M), the paper's memory
budget). Distribution is a *backend*, not solver logic:
``FalkonConfig(mesh=..., data_axes=...)`` makes ``make_ops`` wrap the named
backend in :class:`repro.ops.DistributedOps` (shard-local sweeps, one (M, p)
psum per iteration), and every fit variant below — in-core, lam-path,
streaming — inherits the sharding with no mesh-specific code of its own.

All kernel work flows through a pluggable ``KernelOps`` backend
(``repro.ops``): ``FalkonConfig.ops_impl`` selects it ("jnp" reference or
"pallas" fused single-pass sweep) and ``FalkonConfig.precision`` names the
``PrecisionPolicy`` — "fp32", or "bf16" for END-TO-END bfloat16 storage
(X/C/v, the CG iterates, the streamed chunks) with compensated fp32
accumulation; the Gram block and preconditioner Cholesky stay fp32 by
per-buffer override. ``matvec_impl`` is kept as a deprecated alias of
``ops_impl`` (using it warns).

The fit is an explicit five-stage pipeline — select -> gram -> precondition
-> solve -> wrap — with each stage a named function, so variants compose
from the same parts instead of re-inlining them: ``falkon_fit`` (in-core),
``falkon_fit_streaming`` (host-streamed X) and ``falkon_fit_path`` (the
lam-path solver) differ only in which solve stage they run.

**The lam path.** FALKON's entire per-iteration cost is the O(nM) data sweep
``K_nM^T (K_nM gamma)``, which never reads lam — only the preconditioner's
cheap A factor and the lam-ridge term do. ``falkon_fit_path`` exploits this:
L regularization systems are stacked along the CG column axis ((q, L*p)
iterates), the shared sweep runs ONCE per iteration at width L*p, and the
per-system A-solves/ridge are vmapped over a batched (L, q, q) A stack
(``make_preconditioner_path``). Model selection over L lams therefore costs
~1 fit of data passes instead of L — the workflow the Falkon library paper
(Meanti et al. 2020) identifies as dominating practice.

The solve is fully jittable: ``falkon_solve`` is a pure function of
(X, y, centers, preconditioner) so it can be lowered/compiled for the dry-run
like any train_step.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.ops import (
    CachePlanWarning, DistributedOps, KernelCache, KernelOps, available_ops,
    data_shards, get_ops, plan_cache, resolve_precision
)

from .cg import conjugate_gradient, conjugate_gradient_host
from .kernels import KernelFn, make_kernel
from .minibatch import (
    MinibatchConfig, MinibatchResult, minibatch_solve, minibatch_solve_stream
)
from .nystrom import NystromCenters, select_centers
from .preconditioner import (
    Preconditioner, PreconditionerPath, make_preconditioner, make_preconditioner_path
)

Array = jax.Array

CENTER_SELECTIONS = ("uniform", "leverage")

# knm_cache modes: "off" recomputes K_nM every sweep (the seed behavior,
# bit-identical); "auto" lets plan_cache route by the memory budgets;
# "device"/"host" force a residency tier (refusing, not spilling, when the
# forced tier is unavailable — e.g. host under a mesh).
KNM_CACHE_MODES = ("off", "auto", "device", "host")

_MATVEC_IMPL_DEPRECATION = (
    "matvec_impl is a deprecated alias of ops_impl (renamed in the KernelOps "
    "refactor); pass ops_impl instead"
)


@dataclasses.dataclass(frozen=True)
class FalkonConfig:
    kernel: str = "gaussian"
    kernel_params: tuple = (("sigma", 1.0),)
    lam: float = 1e-6
    num_centers: int = 1024
    iterations: int = 20
    center_selection: str = "uniform"      # "uniform" | "leverage"
    pilot_size: int = 256                  # leverage-score pilot subset
    block_size: int = 2048
    jitter: float | None = None
    rank_deficient: bool = False
    ops_impl: str = "jnp"                  # KernelOps backend: "jnp" | "pallas"
    precision: str = "fp32"                # PrecisionPolicy name: "fp32" |
                                           # "bf16" (end-to-end bf16 storage,
                                           # compensated fp32 accumulation)
    matvec_impl: str | None = None         # deprecated alias of ops_impl
    tol: float = 0.0
    dtype: str = "float32"
    estimate_cond: bool = True             # power-iteration cond(W) diagnostic
    knm_cache: str = "off"                 # materialized-K_nM cache: "off" |
                                           # "auto" | "device" | "host" (see
                                           # repro.ops.KernelCache)
    mesh: Mesh | None = None               # data-parallel mesh (None = single
                                           # device); make_ops wraps the
                                           # backend in DistributedOps
    data_axes: tuple[str, ...] = ("data",)  # mesh axes the rows shard over

    def __post_init__(self):
        """Fail on an unknown backend/policy/scheme at CONFIG time, naming
        the options — not deep inside ``get_ops`` at solve time."""
        if self.matvec_impl is not None:
            warnings.warn(_MATVEC_IMPL_DEPRECATION, DeprecationWarning, stacklevel=3)
        if self.impl not in available_ops():
            raise ValueError(
                f"unknown ops_impl {self.impl!r}; registered KernelOps "
                f"backends: {available_ops()}")
        resolve_precision(self.precision)  # raises naming the known policies
        if self.knm_cache not in KNM_CACHE_MODES:
            raise ValueError(
                f"unknown knm_cache {self.knm_cache!r}; "
                f"supported: {KNM_CACHE_MODES}")
        if self.center_selection not in CENTER_SELECTIONS:
            raise ValueError(
                f"unknown center_selection {self.center_selection!r}; "
                f"supported: {CENTER_SELECTIONS}")
        if self.mesh is not None:
            missing = [a for a in self.data_axes if a not in self.mesh.shape]
            if missing:
                raise ValueError(
                    f"data_axes {missing} not in mesh axes " f"{tuple(self.mesh.shape)}"
                )

    @property
    def impl(self) -> str:
        """Resolved backend name (honors the deprecated ``matvec_impl``)."""
        return self.matvec_impl if self.matvec_impl is not None else self.ops_impl

    def make_kernel(self) -> KernelFn:
        return make_kernel(self.kernel, **dict(self.kernel_params))

    def make_ops(self, kernel: KernelFn | None = None) -> KernelOps:
        """The backend every stage of a fit runs on — wrapped in
        :class:`DistributedOps` when a ``mesh`` is configured, so sharding
        is decided here once and inherited by every fit/predict path."""
        ops = get_ops(
            self.impl,
            kernel if kernel is not None else self.make_kernel(),
            block_size=self.block_size,
            precision=self.precision,
        )
        if self.mesh is not None:
            ops = DistributedOps(ops, self.mesh, self.data_axes)
        return ops


class FalkonState(NamedTuple):
    """Everything needed to run / resume the iterative solve."""
    centers: Array
    precond: Preconditioner
    beta: Array
    alpha: Array
    residual_norms: Array
    cond_estimate: Array


class FalkonPathState(NamedTuple):
    """The lam-path twin of :class:`FalkonState`: one CG run, L systems."""
    centers: Array
    precond: PreconditionerPath
    beta: Array            # (q, L*p) stacked CG solution
    alphas: Array          # (L, M) or (L, M, p): per-lam coefficients
    residual_norms: Array  # (t+1, L*p) per-column residual history
    lams: Array            # (L,) the regularization grid


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FalkonEstimator:
    centers: Array
    alpha: Array
    kernel: KernelFn
    block_size: int = dataclasses.field(metadata=dict(static=True), default=2048)
    ops_impl: str = dataclasses.field(metadata=dict(static=True), default="jnp")
    precision: str = dataclasses.field(metadata=dict(static=True), default="fp32")
    # Fit-time state the incremental path needs: the factored preconditioner
    # and its lam. None on estimators built before PR 8 / by hand — predict
    # works regardless; partial_fit refuses with guidance.
    precond: Preconditioner | None = None
    lam: float | None = dataclasses.field(metadata=dict(static=True), default=None)

    @functools.cached_property
    def _ops(self) -> KernelOps:
        # cached on the instance (cached_property writes __dict__ directly,
        # so the frozen dataclass is fine — same trick as _jitted_ops): the
        # backend + resolved precision policy are built ONCE, not rebuilt
        # via get_ops on every predict() call. Both predict paths and the
        # serving layer route through this one object.
        return get_ops(
            self.ops_impl,
            self.kernel,
            block_size=self.block_size,
            precision=self.precision,
        )

    def build_knm_cache(self, X: Array, *, tier: str | None = None) -> KernelCache:
        """Materialize K(X, centers) once for REPEATED scoring of the same X.

        The serving twin of the fit-time cache: re-scoring a fixed
        evaluation set (a val fold every partial_fit, a dashboard panel, a
        lam-path model-selection grid) pays the kernel once, and every
        later ``predict(X, cache=...)`` is one GEMM. The cache is also kept
        on the estimator (``__dict__``, same trick as ``_ops`` — the frozen
        dataclass is fine), so plain ``predict(X)`` with the SAME X object
        hits it automatically; any other X falls back to recompute. ``tier``
        forces residency; None auto-routes via ``plan_cache``. Raises if
        the plan routes "off" — a scoring set too big for both budgets
        should stream (``predict_stream``), not cache.
        """
        X = jnp.asarray(X, self.centers.dtype)
        plan = plan_cache(
            int(X.shape[0]), int(self.centers.shape[0]),
            policy=self._ops.policy, tier=tier,
        )
        cache = KernelCache(self._ops, X, self.centers, plan=plan)
        self.__dict__["_knm_cache"] = cache
        return cache

    def predict(self, X: Array, *, cache: KernelCache | None = None) -> Array:
        """Score X — from the cache's stored tiles when one covers exactly
        this (X, centers) pair, else by a fresh kernel apply.

        An EXPLICIT ``cache`` must serve: a stale (``invalidate()``-d),
        foreign-centers or wrong-X cache raises rather than silently
        recomputing — the refusal ``swap_model`` relies on. The implicitly
        stored one (``build_knm_cache``) is only a fast path and is skipped
        when it doesn't match.
        """
        if cache is None:
            held = self.__dict__.get("_knm_cache")
            if (held is not None and held.matches(self.centers)
                    and X is held.X):
                cache = held
            else:
                return self._ops.apply(X, self.centers, self.alpha)
        cache.check_serves(self.centers, int(X.shape[0]), X=X)
        return cache.apply(self.alpha)

    @functools.cached_property
    def _jitted_ops(self):
        # jit wrappers over the cached ops: repeat predict_stream calls
        # reuse the same XLA compile cache per chunk shape.
        from repro.data.streaming import JittedOps
        return JittedOps(self._ops)

    def predict_stream(self, loader, *, cache: KernelCache | None = None) -> Array:
        """Predict over a ``StreamingLoader``/iterable of (X_chunk, _) pairs
        — X need never be device-resident at once (see repro.data.streaming).

        With a ``cache`` (built over the loader's rows, in order), the
        stream is not read at all: the stored tiles already ARE the kernel
        entries, so the whole prediction is the cache's GEMM apply. The
        cache must serve this model (stale/foreign raises) and cover the
        loader's exact row count.
        """
        from repro.data.streaming import streaming_apply
        if cache is not None:
            cache.check_serves(self.centers, getattr(loader, "n_rows", None))
            return cache.apply(self.alpha)
        return streaming_apply(self._jitted_ops, loader, self.centers, self.alpha)

    def partial_fit(
        self,
        X_tail: Array,
        y_tail: Array,
        minibatch: "MinibatchConfig | None" = None,
        *,
        key: Array | None = None,
    ) -> "FalkonEstimator":
        """Refresh the model from a data tail WITHOUT a full refit.

        The production scenario the exact solver can't touch: a serving
        model absorbing a live-traffic tail. Everything O(M^3)/O(nM) that a
        refit would redo is REUSED — the Nystrom centers, the factored
        preconditioner (its ``FactorPlan`` routing was decided at fit time)
        and the deployed alpha, pulled back to the preconditioned space via
        ``Preconditioner.beta_of_coeffs`` as the warm start. The tail then
        trains with the delayed-projection mini-batch rule at chunk-sweep
        cost per step.

        Returns a NEW estimator (this class is a frozen pytree): same
        centers object, same alpha shape/dtype — so a serving tier that
        swaps it behind compiled applies sees ZERO retraces by construction
        (asserted via the serve trace counter in tests/test_minibatch.py).
        """
        if self.precond is None or self.lam is None:
            raise ValueError(
                "partial_fit needs the fit-time preconditioner, but this "
                "estimator does not carry one (it was built by hand or by a "
                "pre-partial_fit fit). Refit with falkon_fit / "
                "falkon_fit_minibatch / falkon_fit_streaming, which attach "
                "precond and lam to the estimator."
            )
        mb = minibatch if minibatch is not None else MinibatchConfig()
        if key is None:
            key = jax.random.PRNGKey(0)
        dt = self.centers.dtype
        X_tail = jnp.asarray(X_tail, dt)
        y_tail = jnp.asarray(y_tail, dt)
        want = (self.precond.q,) + y_tail.shape[1:]
        beta0 = self.precond.beta_of_coeffs(self.alpha)
        if beta0.shape != want:
            raise ValueError(
                f"y_tail implies a {want} iterate but the deployed alpha "
                f"warm-starts a {beta0.shape} one — the tail's output width "
                f"must match the fitted model's"
            )
        result = minibatch_solve(
            X_tail,
            y_tail,
            self.centers,
            self.precond,
            self.lam,
            mb,
            ops=self._ops,
            key=key,
            beta0=beta0.astype(dt),
        )
        alpha = result.alpha.astype(self.alpha.dtype)
        return dataclasses.replace(self, alpha=alpha)

    def __call__(self, X: Array) -> Array:
        return self.predict(X)


class FalkonPathResult(NamedTuple):
    """Per-lam estimators + the shared-solve state + validation selection."""
    estimators: tuple[FalkonEstimator, ...]
    state: FalkonPathState
    lams: tuple[float, ...]
    val_scores: Array | None   # (L,) validation MSE per lam (None: no val set)
    best_index: int | None     # argmin of val_scores (None: no val set)

    @property
    def best(self) -> FalkonEstimator | None:
        """The validation-selected estimator (None without a val set)."""
        return None if self.best_index is None else self.estimators[self.best_index]


# ----------------------------------------------------------------------------
# Pure solve (jittable)
# ----------------------------------------------------------------------------
def _cg_storage(ops: KernelOps | None):
    """The CG iterate storage dtype the backend's precision policy implies.

    Under the bf16 end-to-end policy the CG vectors x/r/p — the (q, p)
    buffers every sweep reads — are stored bfloat16 with all scalars fp32
    (see repro.core.cg); the fp32 policy returns None, i.e. the unchanged
    full-precision recurrence.
    """
    pol = getattr(ops, "policy", None)
    if pol is None or pol.storage == "float32":
        return None
    return pol.storage


def _falkon_operator(
    matvec: Callable,
    precond: "Preconditioner | PreconditionerPath",
    lam,
    n: int,
) -> Callable[[Array], Array]:
    """W(u) = B^T H B u via Alg. 1's nested-solve composition.

    W u = left( KnM^T(KnM gamma)/n ) + lam-ridge(u), gamma = right(u), with
    the lam-term delegated to the preconditioner's ``ridge`` (the
    T^{-T} Q^T D K_MM D Q T^{-1} = I identity, Lemma 2 / Eq. 19, exactly as
    the MATLAB code does). With a :class:`PreconditionerPath` the SAME
    composition runs on the stacked (q, L*p) block: ``right``/``left`` apply
    the per-system A-solves to each column group while the matvec — the
    one O(nM) cost — is a single lam-independent sweep of width L*p.
    """
    def W(u: Array) -> Array:
        gamma = precond.right(u)
        w = matvec(gamma) / n                     # K_nM^T K_nM gamma / n
        return precond.left(w) + precond.ridge(u, lam)

    return W


def falkon_solve(
    X: Array,
    y: Array,
    centers: Array,
    precond: Preconditioner,
    kernel: KernelFn,
    lam: float,
    t: int,
    *,
    block_size: int = 2048,
    ops_impl: str = "jnp",
    precision: str = "fp32",
    matvec_impl: str | None = None,
    tol: float = 0.0,
    estimate_cond: bool = True,
    ops: KernelOps | None = None,
    cache: KernelCache | None = None,
) -> FalkonState:
    """Run t preconditioned-CG iterations; return coefficients + diagnostics.

    The per-iteration sweep runs on ``ops`` if given, else on the KernelOps
    backend named by ``ops_impl`` (``matvec_impl`` is a deprecated alias —
    using it warns). Distribution is an ``ops`` concern: pass a
    :class:`repro.ops.DistributedOps` (or fit via
    ``FalkonConfig(mesh=...)``) and every sweep below shards over the mesh
    with one (M, p) psum per call — this replaced the retired
    ``dist_matvec``/``make_distributed_matvec`` wrapper.

    With a ``cache`` (a :class:`repro.ops.KernelCache` over exactly this
    (X, centers) pair — ``falkon_fit`` builds one when
    ``config.knm_cache != "off"``), the RHS sweep, every CG matvec AND the
    ``estimate_cond`` power-iteration sweeps consume the stored entries as
    GEMMs: zero kernel evaluations after the one materialization pass. A
    host-tier cache streams tiles through a Python loop, so the CG
    recurrence drops to the host driver (same contract as the streaming
    fits) — device tier keeps the fully-scanned in-core driver.
    """
    n = X.shape[0]
    if ops is None:
        if matvec_impl is not None:
            warnings.warn(_MATVEC_IMPL_DEPRECATION, DeprecationWarning, stacklevel=2)
        impl = matvec_impl if matvec_impl is not None else ops_impl
        ops = get_ops(impl, kernel, block_size=block_size, precision=precision)

    if cache is not None:
        cache.check_serves(centers, n)

        def matvec(g):
            return cache.sweep(g)

        def rhs_sweep():
            zeros = jnp.zeros((centers.shape[0],) + y.shape[1:], X.dtype)
            return cache.sweep(zeros, y)
    else:
        def matvec(g):
            return ops.sweep(X, centers, g, None)

        def rhs_sweep():
            zeros = jnp.zeros((centers.shape[0],) + y.shape[1:], X.dtype)
            return ops.sweep(X, centers, zeros, y)

    W = _falkon_operator(matvec, precond, lam, n)
    b = precond.left(rhs_sweep() / n)             # r = B^T z / n (Alg. 1)

    host = cache is not None and cache.tier == "host"
    driver = conjugate_gradient_host if host else conjugate_gradient
    cg = driver(W, b, t, tol=tol, storage_dtype=_cg_storage(ops))
    alpha = precond.coeffs(cg.x)

    if not estimate_cond:
        return FalkonState(
            centers=centers,
            precond=precond,
            beta=cg.x,
            alpha=alpha,
            residual_norms=cg.residual_norms,
            cond_estimate=jnp.zeros((), X.dtype),
        )

    # Power-iteration estimate of cond(W) — cheap diagnostic for Thm 2.
    # Its ~26 width-1 sweeps go through the SAME matvec closure as CG, so a
    # cache serves them as GEMMs too (a host-tier cache cannot trace its
    # tile loop under lax.scan — unroll the recurrence at the host level).
    def power(mv, q, iters=12):
        v = jnp.ones((q,), b.dtype) / jnp.sqrt(q)
        if host:
            for _ in range(iters):
                w = mv(v)
                v = w / jnp.maximum(jnp.linalg.norm(w), 1e-30)
        else:
            def step(v, _):
                w = mv(v)
                return w / jnp.maximum(jnp.linalg.norm(w), 1e-30), None
            v, _ = jax.lax.scan(step, v, None, length=iters)
        return jnp.vdot(v, mv(v))

    q = precond.q
    lam_max = power(lambda v: W(v.reshape((q,) + (1,) * (b.ndim - 1))).reshape(q), q)
    lam_min = lam_max - power(
        lambda v: lam_max * v - W(v.reshape((q,) + (1,) * (b.ndim - 1))).reshape(q), q
    )
    cond = jnp.abs(lam_max) / jnp.maximum(jnp.abs(lam_min), 1e-30)

    return FalkonState(
        centers=centers,
        precond=precond,
        beta=cg.x,
        alpha=alpha,
        residual_norms=cg.residual_norms,
        cond_estimate=cond,
    )


def _solve_path_core(
    matvec: Callable,
    rhs_sweep: Callable,
    precond: PreconditionerPath,
    n: int,
    t: int,
    *,
    tol: float,
    storage,
    host: bool,
):
    """The shared lam-path solve: ONE RHS sweep + t stacked-matvec CG
    iterations serve all L systems; returns (CGResult, (M, L*p) alphas)."""
    w0 = rhs_sweep() / n                  # K_nM^T y / n — lam-independent
    b = precond.expand_rhs(w0)            # (q, L*p): per-system A^{-T} only
    W = _falkon_operator(matvec, precond, None, n)
    driver = conjugate_gradient_host if host else conjugate_gradient
    cg = driver(W, b, t, tol=tol, storage_dtype=storage)
    return cg, precond.coeffs(cg.x)


def falkon_solve_path(
    X: Array,
    y: Array,
    centers: Array,
    precond: PreconditionerPath,
    t: int,
    *,
    ops: KernelOps,
    tol: float = 0.0,
    cache: KernelCache | None = None,
) -> FalkonPathState:
    """Solve the FALKON system for every lam in ``precond.lams`` at the data
    cost of ONE solve.

    Per CG iteration: a single ``ops.sweep`` of column width L*p (the
    planner routes the widened block — see ``KernelOps.plan(systems=)``)
    instead of L sweeps of width p; the per-system work is O(q^2 L p)
    triangular solves, invisible next to the O(n M) sweep. Per-column
    convergence masking in the CG core doubles as per-SYSTEM masking: a
    small-lam system that needs all t iterations does not force extra
    arithmetic on an already-converged large-lam one.

    A ``cache`` compounds with the path's sharing: the L systems already
    share each sweep, and with stored entries that ONE stacked sweep per
    iteration is a GEMM — a single kernel pass covers the entire lam grid.
    """
    n = X.shape[0]
    M = centers.shape[0]

    if cache is not None:
        cache.check_serves(centers, n)

        def matvec(G):
            return cache.sweep(G)

        def rhs_sweep():
            zeros = jnp.zeros((M,) + y.shape[1:], X.dtype)
            return cache.sweep(zeros, y)
    else:
        def matvec(G):
            return ops.sweep(X, centers, G, None)

        def rhs_sweep():
            zeros = jnp.zeros((M,) + y.shape[1:], X.dtype)
            return ops.sweep(X, centers, zeros, y)

    host = cache is not None and cache.tier == "host"
    cg, alpha_flat = _solve_path_core(
        matvec, rhs_sweep, precond, n, t, tol=tol, storage=_cg_storage(ops), host=host
    )
    alphas = precond.split(alpha_flat)            # (L, M, p)
    if y.ndim == 1:
        alphas = alphas[..., 0]
    return FalkonPathState(
        centers=centers,
        precond=precond,
        beta=cg.x,
        alphas=alphas,
        residual_norms=cg.residual_norms,
        lams=precond.lams,
    )


# ----------------------------------------------------------------------------
# The fit pipeline: select -> gram -> precondition -> solve -> wrap
# ----------------------------------------------------------------------------
def _stage_select(
    key: Array,
    X: Array,
    config: FalkonConfig,
    kernel: KernelFn,
    *,
    lam: float | None = None,
) -> NystromCenters:
    """Stage 1 — Nystrom center selection. ``lam`` overrides ``config.lam``
    for leverage scoring (the path fit scores at a grid-reference lam)."""
    M = min(config.num_centers, X.shape[0])
    return select_centers(
        key,
        X,
        M,
        kernel=kernel,
        lam=config.lam if lam is None else lam,
        scheme=config.center_selection,
        pilot_size=config.pilot_size,
    )


def _stage_gram(ops: KernelOps, centers: Array) -> Array:
    """Stage 2 — the M x M Gram block (the paper's memory budget)."""
    return ops.gram(centers, centers)


def _stage_cache(
    ops: KernelOps,
    X: Array,
    centers: Array,
    config: FalkonConfig,
) -> KernelCache | None:
    """Stage 2.5 — the optional materialized-K_nM cache.

    ``knm_cache="auto"`` routes by :func:`repro.ops.plan_cache` (per-shard
    device/host budgets, ``REPRO_KNM_BUDGET_MB`` / ``REPRO_KNM_HOST_BUDGET_MB``)
    and warns with a structured :class:`CachePlanWarning` whenever the
    routing falls off the device tier — silently switching a fit between
    GEMM-served and streamed/recompute sweeps is exactly the surprise the
    sweep/factor planners refuse elsewhere. ``"device"``/``"host"`` force a
    tier (a forced host tier under a mesh raises in ``KernelCache``); an
    ``"off"`` route returns None and the fit takes the recompute path,
    bit-identical to the seed.
    """
    if config.knm_cache == "off":
        return None
    shards = data_shards(ops)
    tier = None if config.knm_cache == "auto" else config.knm_cache
    plan = plan_cache(
        int(X.shape[0]),
        int(centers.shape[0]),
        policy=getattr(ops, "policy", None),
        shards=shards,
        tier=tier,
    )
    if tier is None and plan.tier == "host" and shards > 1:
        # each shard's row block either fits HBM or the fit recomputes;
        # there is no per-shard host-streaming story (see KernelCache)
        plan = dataclasses.replace(
            plan, tier="off",
            reason=f"host tier unsupported under {shards}-way row sharding",
        )
    if tier is None and plan.tier != "device":
        warnings.warn(CachePlanWarning(plan), stacklevel=3)
    if plan.tier == "off":
        return None
    return KernelCache(ops, X, centers, plan=plan)


def _stage_precondition(
    KMM: Array,
    lam,
    n: int,
    config: FalkonConfig,
    *,
    D: Array | None = None,
) -> "Preconditioner | PreconditionerPath":
    """Stage 3 — factorization. A scalar ``lam`` builds the single
    :class:`Preconditioner`; a grid builds the batched
    :class:`PreconditionerPath` (shared T/Q/D, (L, q, q) A stack)."""
    build = make_preconditioner if jnp.ndim(lam) == 0 else make_preconditioner_path
    return build(
        KMM, lam, n, D=D, jitter=config.jitter, rank_deficient=config.rank_deficient
    )


def _resolve_ops(
    config: FalkonConfig,
    kernel: KernelFn,
    ops: KernelOps | None,
) -> KernelOps:
    """The one place every fit variant resolves its backend.

    ``ops=None`` builds from the config (mesh-wrapped when configured). An
    explicit ``ops`` — the instrumentation seam, e.g. ``CountingOps`` — is
    wrapped in :class:`DistributedOps` when the config names a mesh and the
    caller has not already distributed it, so counting facades compose with
    sharding on either side. "Already distributed" is decided by walking the
    whole facade chain (``.inner`` / ``.ops`` delegation attributes), not
    just the outermost wrapper: ``CountingOps(DistributedOps(...))`` must
    not get a second ``shard_map`` over the same mesh axes.
    """
    if ops is None:
        return config.make_ops(kernel)
    if config.mesh is not None and not _wraps_distributed(ops):
        return DistributedOps(ops, config.mesh, config.data_axes)
    return ops


def _wraps_distributed(ops: KernelOps) -> bool:
    """True if ``ops`` is, or anywhere down its facade chain wraps, a
    :class:`DistributedOps`."""
    seen: set[int] = set()
    o: object | None = ops
    while o is not None and id(o) not in seen:
        if isinstance(o, DistributedOps):
            return True
        seen.add(id(o))
        o = getattr(o, "inner", None) or getattr(o, "ops", None)
    return False


def _stage_wrap(
    centers: Array,
    alpha: Array,
    kernel: KernelFn,
    config: FalkonConfig,
    *,
    precond: Preconditioner | None = None,
    lam: float | None = None,
) -> FalkonEstimator:
    """Stage 5 — bind coefficients + backend knobs into the estimator.

    ``precond``/``lam`` attach the fit-time factorization so the estimator
    can ``partial_fit`` later; every fit variant passes them (the path fit
    passes each system's single-lam view). Omitting them still yields a
    fully serving-capable estimator."""
    return FalkonEstimator(
        centers=centers,
        alpha=alpha,
        kernel=kernel,
        block_size=config.block_size,
        ops_impl=config.impl,
        precision=config.precision,
        precond=precond,
        lam=None if lam is None else float(lam),
    )


def falkon_fit(
    key: Array,
    X: Array,
    y: Array,
    config: FalkonConfig,
    *,
    mesh: Mesh | None = None,
    data_axes: tuple[str, ...] = ("data",),
    ops: KernelOps | None = None,
) -> tuple[FalkonEstimator, FalkonState]:
    """Select centers, build the preconditioner, run the solve.

    With a mesh (``config.mesh``, or the ``mesh=``/``data_axes=`` kwargs,
    which override the config), every sweep runs shard-locally over the data
    axes and is reduced with one (M, p) psum per CG iteration — the backend
    is wrapped in :class:`repro.ops.DistributedOps`, so the fused/two-pass/
    j-sharded planner and the precision policy apply per shard unchanged.
    The K_MM Gram block, every CG sweep and the returned estimator's predict
    path all run on the backend named by ``config.ops_impl`` — or on ``ops``
    when given (the instrumentation seam: e.g. ``repro.ops.CountingOps``).
    """
    if mesh is not None:
        config = dataclasses.replace(config, mesh=mesh, data_axes=tuple(data_axes))
    kernel = config.make_kernel()
    ops = _resolve_ops(config, kernel, ops)
    dt = jnp.dtype(config.dtype)
    X = X.astype(dt)
    y = y.astype(dt)
    n = X.shape[0]

    sel = _stage_select(key, X, config, kernel)
    cache = _stage_cache(ops, X, sel.centers, config)
    KMM = _stage_gram(ops, sel.centers)
    precond = _stage_precondition(KMM, config.lam, n, config, D=sel.D)

    state = falkon_solve(
        X,
        y,
        sel.centers,
        precond,
        kernel,
        config.lam,
        config.iterations,
        block_size=config.block_size,
        tol=config.tol,
        estimate_cond=config.estimate_cond,
        ops=ops,
        cache=cache,
    )
    est = _stage_wrap(
        sel.centers, state.alpha, kernel, config, precond=precond, lam=config.lam
    )
    return est, state


def _score_path(
    ops: KernelOps,
    centers: Array,
    alphas: Array,
    X_val: Array,
    y_val: Array,
) -> tuple[Array, int]:
    """Validation MSE per lam with ONE stacked apply over the val set.

    ``alphas`` is the (L, M[, p]) stack; the predictions for every lam come
    from a single ``ops.apply`` of column width L*p — the same
    one-data-pass-serves-all-lams trick as the training sweep.
    """
    L = alphas.shape[0]
    M = alphas.shape[1]
    p = alphas.shape[2] if alphas.ndim > 2 else 1
    flat = alphas.reshape(L, M, p).transpose(1, 0, 2).reshape(M, L * p)
    preds = ops.apply(X_val, centers, flat)            # (n_val, L*p)
    preds = preds.reshape(X_val.shape[0], L, p)
    yv = y_val.reshape(y_val.shape[0], 1, p).astype(preds.dtype)
    scores = jnp.mean((preds - yv) ** 2, axis=(0, 2))  # (L,)
    return scores, int(jnp.argmin(scores))


def _check_lams(lams) -> tuple[float, ...]:
    vals = tuple(float(l) for l in lams)
    if not vals:
        raise ValueError("lams must be a non-empty grid of regularizers")
    if any(l <= 0.0 for l in vals):
        raise ValueError(f"every lam in the path must be > 0, got {vals}")
    return vals


def falkon_fit_path(
    key: Array,
    X: Array,
    y: Array,
    config: FalkonConfig,
    lams,
    *,
    X_val: Array | None = None,
    y_val: Array | None = None,
    ops: KernelOps | None = None,
) -> FalkonPathResult:
    """Fit the FULL regularization path in ~one fit's worth of data sweeps.

    Runs the same select -> gram -> precondition -> solve -> wrap pipeline
    as ``falkon_fit``, but stage 3 builds the batched
    :class:`PreconditionerPath` (one chol(K_MM), L cheap A-Cholesky's) and
    stage 4 runs ``falkon_solve_path``: every O(nM) data sweep carries all L
    systems stacked along the column axis, so the whole path costs
    ``iterations + 1`` sweeps — the same count as ONE ``falkon_fit`` —
    instead of ``L * (iterations + 1)``. ``config.lam`` is ignored; the
    grid ``lams`` replaces it.

    Centers (and, under ``center_selection="leverage"``, the sampling
    diagonal D) are SHARED across the path — a requirement, not a
    shortcut: a common K_nM is what makes the sweep lam-independent.
    Leverage scores are taken at the grid's geometric-mean lam; any fixed
    sampling distribution yields a valid Nystrom model for every lam (the
    lam enters only the ridge).

    With ``X_val``/``y_val`` given, every estimator is scored (one stacked
    apply over the val set) and ``result.best`` is the argmin-MSE model.
    """
    lam_vals = _check_lams(lams)
    kernel = config.make_kernel()
    ops = _resolve_ops(config, kernel, ops)
    dt = jnp.dtype(config.dtype)
    X = X.astype(dt)
    y = y.astype(dt)
    n = X.shape[0]

    # geometric-mean reference lam for (leverage) center selection
    log_mean = sum(jnp.log(jnp.asarray(l)) for l in lam_vals) / len(lam_vals)
    lam_ref = float(jnp.exp(log_mean))
    sel = _stage_select(key, X, config, kernel, lam=lam_ref)
    cache = _stage_cache(ops, X, sel.centers, config)
    KMM = _stage_gram(ops, sel.centers)
    precond = _stage_precondition(KMM, jnp.asarray(lam_vals, dt), n, config, D=sel.D)

    state = falkon_solve_path(
        X, y, sel.centers, precond, config.iterations, ops=ops, tol=config.tol,
        cache=cache,
    )
    ests = tuple(_stage_wrap(sel.centers, state.alphas[i], kernel, config,
                             precond=precond.system(i), lam=lam_vals[i])
                 for i in range(len(lam_vals)))

    val_scores = best = None
    if (X_val is None) != (y_val is None):
        raise ValueError("X_val and y_val must be given together")
    if X_val is not None:
        val_scores, best = _score_path(
            ops, sel.centers, state.alphas, X_val.astype(dt), y_val.astype(dt)
        )
    return FalkonPathResult(
        estimators=ests,
        state=state,
        lams=lam_vals,
        val_scores=val_scores,
        best_index=best,
    )


# ----------------------------------------------------------------------------
# Out-of-core fit: X streamed from the host, never device-resident at once
# ----------------------------------------------------------------------------
def falkon_solve_streaming(
    loader,
    centers: Array,
    precond: Preconditioner,
    lam: float,
    t: int,
    *,
    ops: KernelOps,
    out_dim: tuple = (),
    tol: float = 0.0,
) -> FalkonState:
    """``falkon_solve`` with every data sweep streamed through ``loader``.

    ``loader`` is a re-iterable of (X_chunk, y_chunk) device pairs (see
    ``repro.data.StreamingLoader``); one CG iteration = one full pass over
    the stream, chunk sweeps accumulated on the device — O(chunk + M^2)
    device memory for any n. The CG recurrence runs at the Python level
    (``conjugate_gradient_host``): a host loop cannot live inside lax.scan,
    which also means per-chunk sweeps still jit/cache by chunk shape while
    the solve itself is not one fused XLA program. ``out_dim`` is y's
    trailing shape: () for single-output, (p,) for multi-rhs.
    """
    from repro.data.streaming import JittedOps, streaming_sweep

    n = loader.n_rows
    M = centers.shape[0]
    jops = JittedOps(ops)  # chunks of one shape compile once, not per call

    def matvec(g):
        return streaming_sweep(jops, loader, centers, g, use_targets=False)

    def rhs_sweep():
        zeros = jnp.zeros((M,) + tuple(out_dim), centers.dtype)
        return streaming_sweep(jops, loader, centers, zeros, use_targets=True)

    W = _falkon_operator(matvec, precond, lam, n)
    b = precond.left(rhs_sweep() / n)
    cg = conjugate_gradient_host(W, b, t, tol=tol, storage_dtype=_cg_storage(ops))
    alpha = precond.coeffs(cg.x)
    return FalkonState(
        centers=centers,
        precond=precond,
        beta=cg.x,
        alpha=alpha,
        residual_norms=cg.residual_norms,
        cond_estimate=jnp.zeros((), b.dtype),
    )


def falkon_solve_path_streaming(
    loader,
    centers: Array,
    precond: PreconditionerPath,
    t: int,
    *,
    ops: KernelOps,
    out_dim: tuple = (),
    tol: float = 0.0,
) -> FalkonPathState:
    """``falkon_solve_path`` with every stacked sweep streamed from the host.

    One full pass over the stream per CG iteration serves all L systems —
    out-of-core n and the lam path compose: the per-chunk sweep just
    carries an (M, L*p) coefficient block instead of (M, p). The host CG
    driver's early stop applies when EVERY system/column has converged (each
    skipped iteration saves a whole pass over the data).
    """
    from repro.data.streaming import JittedOps, streaming_sweep

    n = loader.n_rows
    M = centers.shape[0]
    jops = JittedOps(ops)

    def matvec(G):
        return streaming_sweep(jops, loader, centers, G, use_targets=False)

    def rhs_sweep():
        zeros = jnp.zeros((M,) + tuple(out_dim), centers.dtype)
        return streaming_sweep(jops, loader, centers, zeros, use_targets=True)

    cg, alpha_flat = _solve_path_core(
        matvec, rhs_sweep, precond, n, t, tol=tol, storage=_cg_storage(ops), host=True
    )
    alphas = precond.split(alpha_flat)
    if not tuple(out_dim):
        alphas = alphas[..., 0]
    return FalkonPathState(
        centers=centers,
        precond=precond,
        beta=cg.x,
        alphas=alphas,
        residual_norms=cg.residual_norms,
        lams=precond.lams,
    )


def _streaming_setup(
    key: Array,
    source,
    config: FalkonConfig,
    *,
    prefetch: int | None,
    centers: Array | None,
    ops: KernelOps | None = None,
):
    """Shared front half of the streaming fits: centers, loader, out_dim.

    Centers are sampled uniformly in one host-side pass (exact, not
    reservoir-approximate — n_rows is known). Only
    ``center_selection="uniform"`` is supported out-of-core: leverage-score
    sampling needs a pilot Gram pass that is not chunk-additive.
    """
    from repro.data.streaming import (
        StreamingLoader, default_prefetch, streaming_uniform_centers
    )

    if prefetch is None:
        prefetch = default_prefetch()

    if config.knm_cache != "off":
        raise ValueError(
            "streaming fits do not support knm_cache (got "
            f"{config.knm_cache!r}): the point of streaming X is that "
            "O(n*M) state never materializes — cache the kernel with an "
            "in-core fit, or set knm_cache='off'")
    if config.center_selection != "uniform" and centers is None:
        raise ValueError(
            "streaming fit supports center_selection='uniform' only "
            f"(got {config.center_selection!r})")

    kernel = config.make_kernel()
    ops = _resolve_ops(config, kernel, ops)
    dt = jnp.dtype(config.dtype)
    n = source.n_rows
    M = min(config.num_centers, n)

    if centers is None:
        centers, _ = streaming_uniform_centers(key, source, M)
    centers = jnp.asarray(centers, dt)

    # Under the bf16 policy the host->device chunk transfer itself runs at
    # storage width — half the PCIe/DMA traffic of an fp32 stream; the
    # backend would only re-quantize an fp32 chunk on arrival anyway.
    pol = getattr(ops, "policy", None)
    loader_dt = (
        jnp.dtype(pol.storage) if pol is not None and pol.storage != "float32" else dt
    )
    loader = StreamingLoader(source, prefetch=prefetch, dtype=loader_dt)
    # y's trailing shape from one peeked chunk (hosts only, no transfer)
    out_dim: tuple = ()
    for _, yc in source.chunks():
        if yc is None:
            raise ValueError("streaming fit needs targets in the source")
        out_dim = tuple(yc.shape[1:])
        break
    return kernel, ops, centers, loader, out_dim, n


def falkon_fit_streaming(
    key: Array,
    source,
    config: FalkonConfig,
    *,
    prefetch: int | None = None,
    centers: Array | None = None,
    ops: KernelOps | None = None,
) -> tuple[FalkonEstimator, FalkonState]:
    """Fit FALKON from a ``ChunkSource`` without materializing X on device.

    Same pipeline as ``falkon_fit`` with the select and solve stages swapped
    for their streaming variants: uniform centers from one host-side pass,
    the M x M preconditioner built in-core (the paper's memory budget), then
    every CG sweep streams the chunks through a double-buffered host->device
    loader. ``centers`` overrides sampling (used by parity tests); ``ops``
    overrides the backend (the instrumentation seam — a ``CountingOps``
    under the jitted streaming facade counts XLA compiles, which is how
    tests pin the one-compile-per-fit contract for ragged tail chunks).
    ``prefetch`` defaults to 2 chunks in flight on real accelerators and to
    synchronous transfers on CPU, where an overlap thread only contends with
    compute.
    """
    kernel, ops, centers, loader, out_dim, n = _streaming_setup(
        key, source, config, prefetch=prefetch, centers=centers, ops=ops
    )
    KMM = _stage_gram(ops, centers)
    precond = _stage_precondition(KMM, config.lam, n, config)

    state = falkon_solve_streaming(
        loader,
        centers,
        precond,
        config.lam,
        config.iterations,
        ops=ops,
        out_dim=out_dim,
        tol=config.tol,
    )
    est = _stage_wrap(
        centers, state.alpha, kernel, config, precond=precond, lam=config.lam
    )
    return est, state


def falkon_fit_path_streaming(
    key: Array,
    source,
    config: FalkonConfig,
    lams,
    *,
    prefetch: int | None = None,
    centers: Array | None = None,
    ops: KernelOps | None = None,
) -> FalkonPathResult:
    """``falkon_fit_path`` for a host-streamed ``ChunkSource``.

    The whole L-lam path costs the stream passes of ONE fit: per CG
    iteration one pass over the chunks, each chunk sweep carrying the
    stacked (M, L*p) block. Validation scoring is not built in (the val set
    would need its own stream); score the returned estimators with
    ``FalkonEstimator.predict_stream``.
    """
    lam_vals = _check_lams(lams)
    kernel, ops, centers, loader, out_dim, n = _streaming_setup(
        key, source, config, prefetch=prefetch, centers=centers, ops=ops
    )
    dt = jnp.dtype(config.dtype)
    KMM = _stage_gram(ops, centers)
    precond = _stage_precondition(KMM, jnp.asarray(lam_vals, dt), n, config)

    state = falkon_solve_path_streaming(
        loader,
        centers,
        precond,
        config.iterations,
        ops=ops,
        out_dim=out_dim,
        tol=config.tol,
    )
    ests = tuple(_stage_wrap(centers, state.alphas[i], kernel, config,
                             precond=precond.system(i), lam=lam_vals[i])
                 for i in range(len(lam_vals)))
    return FalkonPathResult(
        estimators=ests, state=state, lams=lam_vals, val_scores=None, best_index=None
    )


# ----------------------------------------------------------------------------
# Mini-batch fit: delayed-projection stochastic solve (see core/minibatch.py)
# ----------------------------------------------------------------------------
def falkon_fit_minibatch(
    key: Array,
    X: Array,
    y: Array,
    config: FalkonConfig,
    minibatch: MinibatchConfig | None = None,
    *,
    centers: Array | None = None,
    ops: KernelOps | None = None,
    beta0: Array | None = None,
) -> tuple[FalkonEstimator, MinibatchResult]:
    """Fit by stochastic preconditioned sweeps with delayed projections.

    Same select -> gram -> precondition pipeline as ``falkon_fit`` — the
    preconditioner is factored ONCE (through the same ``FactorPlan``
    in-core/blocked routing) and reused by every projection — but the solve
    stage is the mini-batch driver: per step one chunk-sized sweep (not a
    full O(nM) pass), a projection every ``minibatch.project_every`` steps,
    epoch reshuffling, tail averaging. ``config.iterations``/``config.tol``
    are CG knobs and are ignored here; the budget lives in ``minibatch``
    (``epochs`` x ``chunk_rows`` x ``project_every``). ``centers`` overrides
    selection (parity tests / shared-center comparisons), ``ops`` is the
    instrumentation seam, ``beta0`` warm-starts (what ``partial_fit``
    passes). Prefer this over full CG when epochs-to-target-MSE x n is
    smaller than (iterations + 1) x n — see README's step-cost model.
    """
    mb = minibatch if minibatch is not None else MinibatchConfig()
    if config.knm_cache != "off":
        raise ValueError(
            "the mini-batch solver does not support knm_cache (got "
            f"{config.knm_cache!r}): each step sweeps a fresh shuffled "
            "chunk, so there is no fixed tile set to materialize — use "
            "falkon_fit for cached sweeps, or set knm_cache='off'")
    kernel = config.make_kernel()
    ops = _resolve_ops(config, kernel, ops)
    dt = jnp.dtype(config.dtype)
    X = X.astype(dt)
    y = y.astype(dt)
    n = X.shape[0]

    key_sel, key_shuffle = jax.random.split(key)
    if centers is None:
        sel = _stage_select(key_sel, X, config, kernel)
        centers_arr, D = sel.centers, sel.D
    else:
        centers_arr, D = jnp.asarray(centers, dt), None
    KMM = _stage_gram(ops, centers_arr)
    precond = _stage_precondition(KMM, config.lam, n, config, D=D)

    result = minibatch_solve(
        X,
        y,
        centers_arr,
        precond,
        config.lam,
        mb,
        ops=ops,
        key=key_shuffle,
        beta0=beta0,
    )
    est = _stage_wrap(
        centers_arr, result.alpha, kernel, config, precond=precond, lam=config.lam
    )
    return est, result


def falkon_fit_minibatch_streaming(
    key: Array,
    source,
    config: FalkonConfig,
    minibatch: MinibatchConfig | None = None,
    *,
    prefetch: int | None = None,
    centers: Array | None = None,
    ops: KernelOps | None = None,
    beta0: Array | None = None,
) -> tuple[FalkonEstimator, MinibatchResult]:
    """``falkon_fit_minibatch`` for a host-streamed ``ChunkSource``.

    The out-of-core twin: the same front half as ``falkon_fit_streaming``
    (uniform centers in one host pass, in-core M x M preconditioner), then
    the host-driven mini-batch loop. With ``minibatch.shuffle`` the source
    is wrapped in :class:`repro.data.ShuffledChunkSource`, whose every pass
    (= every epoch) draws a fresh windowed shuffle of the chunk order plus
    in-chunk row shuffles — epoch reshuffling without materializing n rows.
    Unlike full streaming CG (one full pass per iteration), each update here
    costs ``project_every`` chunk transfers + sweeps.
    """
    mb = minibatch if minibatch is not None else MinibatchConfig()
    if mb.shuffle:
        from repro.data.streaming import ShuffledChunkSource

        seed = int(jax.random.randint(jax.random.fold_in(key, 7), (), 0, 2**31 - 1))
        source = ShuffledChunkSource(source, seed=seed)
    kernel, ops, centers, loader, out_dim, n = _streaming_setup(
        key, source, config, prefetch=prefetch, centers=centers, ops=ops
    )
    KMM = _stage_gram(ops, centers)
    precond = _stage_precondition(KMM, config.lam, n, config)

    result = minibatch_solve_stream(
        loader,
        centers,
        precond,
        config.lam,
        mb,
        ops=ops,
        out_dim=out_dim,
        beta0=beta0,
    )
    est = _stage_wrap(
        centers, result.alpha, kernel, config, precond=precond, lam=config.lam
    )
    return est, result
