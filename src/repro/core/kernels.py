"""Kernel functions for FALKON.

Each kernel is a small dataclass with ``__call__(X, Y) -> (n, m)`` returning the
Gram block K(X, Y). All kernels are positive definite, bounded (kappa^2 = K(x,x)
finite) per the paper's standing assumption, and written so the pairwise block is
a single MXU-friendly matmul plus cheap elementwise work.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Protocol

import jax
import jax.numpy as jnp

Array = jax.Array


def _sqdist(X: Array, Y: Array) -> Array:
    """Pairwise squared euclidean distances, (n, d) x (m, d) -> (n, m).

    Computed as ||x||^2 + ||y||^2 - 2 x.y so the dominant cost is one matmul
    (the form the Pallas kernel mirrors). Clamped at 0 for numerical safety.
    """
    xx = jnp.sum(X * X, axis=-1, keepdims=True)            # (n, 1)
    yy = jnp.sum(Y * Y, axis=-1, keepdims=True).T          # (1, m)
    xy = X @ Y.T                                           # (n, m)  MXU
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


class KernelFn(Protocol):
    def __call__(self, X: Array, Y: Array) -> Array: ...

    @property
    def kappa_sq(self) -> float: ...


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GaussianKernel:
    """K(x, y) = exp(-||x - y||^2 / (2 sigma^2)).  kappa^2 = 1."""

    sigma: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        g = 0.5 / (self.sigma * self.sigma)
        return jnp.exp(-g * _sqdist(X, Y))

    @property
    def kappa_sq(self) -> float:
        return 1.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LaplacianKernel:
    """K(x, y) = exp(-||x - y|| / sigma).  kappa^2 = 1."""

    sigma: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        d = jnp.sqrt(_sqdist(X, Y) + 1e-12)
        return jnp.exp(-d / self.sigma)

    @property
    def kappa_sq(self) -> float:
        return 1.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Matern32Kernel:
    """Matern nu=3/2: (1 + sqrt(3) r / sigma) exp(-sqrt(3) r / sigma)."""

    sigma: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        r = jnp.sqrt(_sqdist(X, Y) + 1e-12)
        a = jnp.sqrt(3.0) * r / self.sigma
        return (1.0 + a) * jnp.exp(-a)

    @property
    def kappa_sq(self) -> float:
        return 1.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LinearKernel:
    """K(x, y) = x.y / scale^2 (used for the YELP sparse-3gram experiment)."""

    scale: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        return (X @ Y.T) / (self.scale * self.scale)

    @property
    def kappa_sq(self) -> float:  # bounded only on bounded domains; nominal
        return 1.0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PolynomialKernel:
    """K(x, y) = (x.y / scale^2 + c)^degree."""

    degree: int = dataclasses.field(metadata=dict(static=True), default=2)
    c: float = dataclasses.field(metadata=dict(static=True), default=1.0)
    scale: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        return ((X @ Y.T) / (self.scale * self.scale) + self.c) ** self.degree

    @property
    def kappa_sq(self) -> float:
        return 1.0


_REGISTRY = {
    "gaussian": GaussianKernel,
    "laplacian": LaplacianKernel,
    "matern32": Matern32Kernel,
    "linear": LinearKernel,
    "polynomial": PolynomialKernel,
}


def make_kernel(name: str, **kwargs) -> KernelFn:
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)
