"""Kernel functions for FALKON, plus the declarative kernel-spec registry.

Each kernel is a small dataclass with ``__call__(X, Y) -> (n, m)`` returning the
Gram block K(X, Y). All kernels are positive definite, bounded (kappa^2 = K(x,x)
finite) per the paper's standing assumption, and written so the pairwise block is
a single MXU-friendly matmul plus cheap elementwise work.

Every kernel registered here carries a declarative :class:`KernelSpec`
(``kind`` string + static params tuple). The spec — not the Python class — is
what crosses the backend boundary: the ``repro.ops`` backends (jnp reference,
Pallas fused) and the Pallas kernel bodies all evaluate kernels through
:func:`tile_transform`, a pure function of the matmul precursors

    ab = A @ B^T,   a2 = ||a_i||^2,   b2 = ||b_j||^2

keyed by ``spec.kind``. This makes ``core/kernels.py`` the single source of
truth for kernel math: adding a kernel here (``@register_kernel``) makes it
available to every backend with no name-sniffing anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Declarative, hashable description of a kernel: (kind, static params).

    This is what backends receive instead of a Python object whose class name
    would have to be sniffed; ``params`` is a sorted tuple of (name, value)
    pairs so specs are hashable (usable as static jit/pallas arguments).
    """

    kind: str
    params: tuple[tuple[str, float], ...] = ()

    def as_dict(self) -> dict:
        return dict(self.params)


def _sqdist_of(ab: Array, a2: Array, b2: Array) -> Array:
    """||a||^2 + ||b||^2 - 2 a.b, clamped at 0 for numerical safety."""
    return jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)


def tile_transform(ab: Array, a2: Array, b2: Array, spec: KernelSpec) -> Array:
    """Map matmul precursors to a Gram tile for any registered kernel kind.

    ``ab`` is (m, n) = A @ B^T; ``a2`` is (m, 1); ``b2`` is (1, n). Shared by
    the jnp reference path, the oracle in ``repro.kernels.ref``, and the Pallas
    kernel bodies — one formula per kernel, everywhere.
    """
    p = spec.as_dict()
    kind = spec.kind
    if kind == "gaussian":
        sigma = p.get("sigma", 1.0)
        return jnp.exp(-0.5 / (sigma * sigma) * _sqdist_of(ab, a2, b2))
    if kind == "laplacian":
        sigma = p.get("sigma", 1.0)
        d = jnp.sqrt(_sqdist_of(ab, a2, b2) + 1e-12)
        return jnp.exp(-d / sigma)
    if kind == "matern32":
        sigma = p.get("sigma", 1.0)
        r = jnp.sqrt(_sqdist_of(ab, a2, b2) + 1e-12)
        a = jnp.sqrt(3.0) * r / sigma
        return (1.0 + a) * jnp.exp(-a)
    if kind == "linear":
        scale = p.get("scale", 1.0)
        return ab / (scale * scale)
    if kind == "polynomial":
        scale = p.get("scale", 1.0)
        return (ab / (scale * scale) + p.get("c", 1.0)) ** int(p.get("degree", 2))
    raise ValueError(f"unknown kernel kind {spec.kind!r}; have {sorted(_REGISTRY)}")


def tile_eval(spec: KernelSpec, X: Array, Y: Array) -> Array:
    """K(X, Y) from a spec — the dense jnp evaluation every kernel's
    ``__call__`` reduces to (one matmul + VPU elementwise)."""
    a2 = jnp.sum(X * X, axis=-1, keepdims=True)            # (n, 1)
    b2 = jnp.sum(Y * Y, axis=-1, keepdims=True).T          # (1, m)
    ab = X @ Y.T                                           # (n, m)  MXU
    return tile_transform(ab, a2, b2, spec)


def _sqdist(X: Array, Y: Array) -> Array:
    """Pairwise squared euclidean distances, (n, d) x (m, d) -> (n, m)."""
    xx = jnp.sum(X * X, axis=-1, keepdims=True)
    yy = jnp.sum(Y * Y, axis=-1, keepdims=True).T
    return jnp.maximum(xx + yy - 2.0 * (X @ Y.T), 0.0)


class KernelFn(Protocol):
    def __call__(self, X: Array, Y: Array) -> Array: ...

    @property
    def kappa_sq(self) -> float: ...

    @property
    def spec(self) -> KernelSpec: ...


_REGISTRY: dict[str, type] = {}


def _make_spec(self) -> KernelSpec:
    return KernelSpec(
        kind=type(self).kind,
        params=tuple(sorted((f.name, getattr(self, f.name))
                            for f in dataclasses.fields(self))),
    )


def register_kernel(kind: str):
    """Register a kernel dataclass under ``kind`` and attach its ``spec``."""
    def deco(cls):
        cls.kind = kind
        cls.spec = property(_make_spec)
        _REGISTRY[kind] = cls
        return cls
    return deco


def spec_of(kernel) -> KernelSpec:
    """The KernelSpec of a kernel object (the only sanctioned way for a
    backend to learn what kernel it is running)."""
    spec = getattr(kernel, "spec", None)
    if isinstance(spec, KernelSpec):
        return spec
    if isinstance(kernel, KernelSpec):
        return kernel
    raise TypeError(
        f"{type(kernel).__name__} carries no KernelSpec; register it with "
        "@register_kernel in repro.core.kernels")


@register_kernel("gaussian")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GaussianKernel:
    """K(x, y) = exp(-||x - y||^2 / (2 sigma^2)).  kappa^2 = 1."""

    sigma: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        return tile_eval(self.spec, X, Y)

    @property
    def kappa_sq(self) -> float:
        return 1.0


@register_kernel("laplacian")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LaplacianKernel:
    """K(x, y) = exp(-||x - y|| / sigma).  kappa^2 = 1."""

    sigma: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        return tile_eval(self.spec, X, Y)

    @property
    def kappa_sq(self) -> float:
        return 1.0


@register_kernel("matern32")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Matern32Kernel:
    """Matern nu=3/2: (1 + sqrt(3) r / sigma) exp(-sqrt(3) r / sigma)."""

    sigma: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        return tile_eval(self.spec, X, Y)

    @property
    def kappa_sq(self) -> float:
        return 1.0


@register_kernel("linear")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LinearKernel:
    """K(x, y) = x.y / scale^2 (used for the YELP sparse-3gram experiment)."""

    scale: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        return tile_eval(self.spec, X, Y)

    @property
    def kappa_sq(self) -> float:  # bounded only on bounded domains; nominal
        return 1.0


@register_kernel("polynomial")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PolynomialKernel:
    """K(x, y) = (x.y / scale^2 + c)^degree."""

    degree: int = dataclasses.field(metadata=dict(static=True), default=2)
    c: float = dataclasses.field(metadata=dict(static=True), default=1.0)
    scale: float = dataclasses.field(metadata=dict(static=True), default=1.0)

    def __call__(self, X: Array, Y: Array) -> Array:
        return tile_eval(self.spec, X, Y)

    @property
    def kappa_sq(self) -> float:
        return 1.0


def make_kernel(name: str, **kwargs) -> KernelFn:
    if name not in _REGISTRY:
        raise ValueError(f"unknown kernel {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def available_kernels() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
