"""Blocked and distributed K_nM matvecs — thin veneer over ``repro.ops``.

The primitive (paper Alg. 1 ``KnM_times_vector``) is, for block b of X:

    w += K(X_b, C)^T (K(X_b, C) u + v_b)

so one sweep over the data computes ``K_nM^T (K_nM u + v)`` in O(M * block)
memory without ever materializing K_nM. Since the KernelOps refactor the
actual implementations live in the pluggable backend layer:

* ``repro.ops.jnp_backend``    — lax.scan reference (impl="jnp")
* ``repro.ops.pallas_backend`` — single-pass fused Pallas sweep
                                 (impl="pallas"; each Gram tile computed once)

This module keeps the historical functional API (``knm_matvec``,
``knm_apply``) as one-line delegates. The distributed sweep that used to
live here (``make_distributed_matvec``, a seed-era one-off shard_map
wrapper) is retired: distribution is now a composable backend —
``repro.ops.DistributedOps`` wraps any registered ``KernelOps`` and
shard_maps its sweep over the mesh data axes with one (M, p) psum per call,
so fit/path/streaming/serving all inherit it through the registry instead
of through a special matvec.

``make_knm_cache`` / ``cached_knm_matvec`` / ``cached_knm_apply`` are the
functional face of the materialized-K_nM cache (``repro.ops.KernelCache``):
evaluate the kernel entries once, then answer every later matvec/apply over
the SAME (X, C) pair as a GEMM from the stored tiles.
"""
from __future__ import annotations

import jax

from repro.ops import KernelCache, PrecisionPolicy, get_ops  # noqa: F401

from .kernels import KernelFn

Array = jax.Array


def knm_matvec(
    X: Array,
    C: Array,
    u: Array,
    v: Array | None,
    kernel: KernelFn,
    *,
    block_size: int = 2048,
    impl: str = "jnp",
    precision: "str | PrecisionPolicy" = "fp32",
) -> Array:
    """Return ``K_nM^T (K_nM u + v)`` with blocked O(M * block) memory.

    ``u``: (M,) or (M, p); ``v``: (n,) or (n, p) or None (treated as 0).
    """
    ops = get_ops(impl, kernel, block_size=block_size, precision=precision)
    return ops.sweep(X, C, u, v)


def knm_apply(
    X: Array,
    C: Array,
    u: Array,
    kernel: KernelFn,
    *,
    block_size: int = 2048,
    impl: str = "jnp",
    precision: "str | PrecisionPolicy" = "fp32",
) -> Array:
    """Return ``K_nM u`` (prediction path), blocked over rows of X."""
    ops = get_ops(impl, kernel, block_size=block_size, precision=precision)
    return ops.apply(X, C, u)


def make_knm_cache(
    X: Array,
    C: Array,
    kernel: KernelFn,
    *,
    block_size: int = 2048,
    impl: str = "jnp",
    precision: "str | PrecisionPolicy" = "fp32",
    tier: str | None = None,
) -> KernelCache:
    """Materialize K(X, C) once; later sweeps/applies are pure GEMMs.

    The functional entry to :class:`repro.ops.KernelCache` (the class API
    and ``FalkonConfig(knm_cache=...)`` are the composable routes). ``tier``
    forces residency ("device"/"host"); None auto-routes by the
    ``plan_cache`` budgets and raises if the plan says "off" — at this
    call site the caller has explicitly asked to cache.
    """
    from repro.ops import plan_cache

    ops = get_ops(impl, kernel, block_size=block_size, precision=precision)
    plan = plan_cache(
        int(X.shape[0]), int(C.shape[0]), policy=ops.policy, tier=tier
    )
    return KernelCache(ops, X, C, plan=plan)


def cached_knm_matvec(cache: KernelCache, u: Array, v: Array | None = None) -> Array:
    """``K_nM^T (K_nM u + v)`` from a cache's stored entries (zero kernel
    evaluations) — the cached twin of :func:`knm_matvec`."""
    return cache.sweep(u, v)


def cached_knm_apply(cache: KernelCache, u: Array) -> Array:
    """``K_nM u`` from stored entries — the cached twin of :func:`knm_apply`."""
    return cache.apply(u)


def streaming_knm_matvec(
    loader,
    C: Array,
    u: Array,
    kernel: KernelFn,
    *,
    use_targets: bool = False,
    block_size: int = 2048,
    impl: str = "jnp",
    precision: "str | PrecisionPolicy" = "fp32",
) -> Array:
    """``K_nM^T (K_nM u + v)`` with X streamed chunk-by-chunk from the host.

    ``loader`` re-iterates (X_chunk, y_chunk) pairs (repro.data.streaming);
    with ``use_targets=True`` the chunk targets play the role of v. Runs on
    whichever KernelOps backend ``impl`` names — the jnp backend is the
    reference semantics for the chunked == in-core identity.
    """
    from repro.data.streaming import streaming_sweep

    ops = get_ops(impl, kernel, block_size=block_size, precision=precision)
    return streaming_sweep(ops, loader, C, u, use_targets=use_targets)


def streaming_knm_apply(
    loader,
    C: Array,
    u: Array,
    kernel: KernelFn,
    *,
    block_size: int = 2048,
    impl: str = "jnp",
    precision: "str | PrecisionPolicy" = "fp32",
) -> Array:
    """``K_nM u`` over streamed chunks of X, concatenated in order."""
    from repro.data.streaming import streaming_apply

    ops = get_ops(impl, kernel, block_size=block_size, precision=precision)
    return streaming_apply(ops, loader, C, u)
