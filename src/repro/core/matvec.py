"""Blocked and distributed K_nM matvecs — the O(nMt) hot loop of FALKON.

The primitive (paper Alg. 1 ``KnM_times_vector``) is, for block b of X:

    w += K(X_b, C)^T (K(X_b, C) u + v_b)

so one sweep over the data computes ``K_nM^T (K_nM u + v)`` in O(M * block)
memory without ever materializing K_nM. Three implementations:

* ``knm_matvec``      — jnp, lax.scan over row blocks (reference/CPU path).
* Pallas              — ``repro.kernels.ops.fused_knm_matvec`` (TPU target),
                        selected via ``impl="pallas"``.
* ``make_distributed_matvec`` — shard_map over the mesh data axes: each device
  sweeps its local shard and contributions are psum-reduced. This is how the
  single-machine paper algorithm becomes a multi-pod one: the sweep is
  embarrassingly data-parallel in n, the psum is the only communication
  (M floats per iteration).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .kernels import KernelFn

Array = jax.Array


def _pad_blocks(X: Array, v: Array | None, block_size: int):
    """Pad rows of X (and v) to a multiple of block_size; return mask."""
    n = X.shape[0]
    nb = -(-n // block_size)
    pad = nb * block_size - n
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    mask = jnp.pad(jnp.ones((n,), X.dtype), (0, pad))
    vp = None
    if v is not None:
        widths = ((0, pad),) + ((0, 0),) * (v.ndim - 1)
        vp = jnp.pad(v, widths)
    return Xp.reshape(nb, block_size, X.shape[1]), mask.reshape(nb, block_size), vp, nb


def knm_matvec(
    X: Array,
    C: Array,
    u: Array,
    v: Array | None,
    kernel: KernelFn,
    *,
    block_size: int = 2048,
    impl: str = "jnp",
) -> Array:
    """Return ``K_nM^T (K_nM u + v)`` with blocked O(M * block) memory.

    ``u``: (M,) or (M, p); ``v``: (n,) or (n, p) or None (treated as 0).
    """
    if impl == "pallas":
        from repro.kernels.ops import fused_knm_matvec
        return fused_knm_matvec(X, C, u, v, kernel, block_size=block_size)

    n = X.shape[0]
    Xb, mask, vp, nb = _pad_blocks(X, v, block_size)
    out_shape = (C.shape[0],) + u.shape[1:]
    if vp is not None:
        vb = vp.reshape((nb, block_size) + v.shape[1:])

    def body(carry, inp):
        if v is None:
            xb, mb = inp
            Kb = kernel(xb, C) * mb[:, None]          # mask padded rows
            t = Kb @ u
        else:
            xb, mb, vblk = inp
            Kb = kernel(xb, C) * mb[:, None]
            # Kb's zeroed rows already null padded contributions in Kb.T @ t;
            # masking v too keeps t finite for arbitrary padded v.
            t = Kb @ u + vblk * (mb[:, None] if vblk.ndim > 1 else mb)
        return carry + Kb.T @ t, None

    init = jnp.zeros(out_shape, X.dtype)
    xs = (Xb, mask) if v is None else (Xb, mask, vb)
    w, _ = jax.lax.scan(body, init, xs)
    return w


def knm_apply(
    X: Array,
    C: Array,
    u: Array,
    kernel: KernelFn,
    *,
    block_size: int = 2048,
) -> Array:
    """Return ``K_nM u`` (prediction path), blocked over rows of X."""
    n = X.shape[0]
    Xb, mask, _, nb = _pad_blocks(X, None, block_size)

    def body(xb):
        return kernel(xb, C) @ u

    out = jax.lax.map(body, Xb)
    out = out.reshape((nb * Xb.shape[1],) + u.shape[1:])
    return out[:n]


def make_distributed_matvec(
    mesh: Mesh,
    data_axes: tuple[str, ...],
    kernel: KernelFn,
    *,
    block_size: int = 2048,
    impl: str = "jnp",
) -> Callable:
    """shard_map-wrapped ``K_nM^T (K_nM u + v)`` over the mesh data axes.

    X, v are sharded over ``data_axes``; C, u replicated; output replicated
    (psum over data axes). One call = one full data sweep = 4 * n_local * M
    flops per device + one (M, p) psum.
    """
    from jax.experimental.shard_map import shard_map

    def local(Xl, C, u, vl):
        w = knm_matvec(Xl, C, u, vl, kernel, block_size=block_size, impl=impl)
        return jax.lax.psum(w, data_axes)

    xspec = P(data_axes)
    return shard_map(
        local, mesh=mesh,
        in_specs=(xspec, P(), P(), xspec),
        out_specs=P(),
        check_rep=False,
    )
