"""FALKON core — the paper's primary contribution as a composable JAX module.

Public API:
    FalkonConfig, falkon_fit, falkon_solve, FalkonEstimator
    falkon_fit_streaming, falkon_solve_streaming   (out-of-core n)
    make_preconditioner, Preconditioner
    conjugate_gradient, conjugate_gradient_host
    select_centers, uniform_centers, leverage_score_centers,
    approximate_leverage_scores, exact_leverage_scores
    make_kernel, KernelSpec, spec_of, GaussianKernel, LaplacianKernel,
    Matern32Kernel, LinearKernel, PolynomialKernel
    knm_matvec, knm_apply, make_distributed_matvec,
    streaming_knm_matvec, streaming_knm_apply        (KernelOps delegates)
    baselines: krr_direct, krr_gradient, nystrom_direct, nystrom_gradient

Kernel compute is pluggable: the ``repro.ops`` KernelOps registry ("jnp"
reference / "pallas" fused) backs every sweep, apply and gram above.
"""
from .baselines import (krr_direct, krr_gradient, nystrom_direct,
                        nystrom_gradient)
from .cg import CGResult, conjugate_gradient, conjugate_gradient_host
from .falkon import (FalkonConfig, FalkonEstimator, FalkonState, falkon_fit,
                     falkon_fit_streaming, falkon_solve,
                     falkon_solve_streaming)
from .kernels import (GaussianKernel, KernelFn, KernelSpec, LaplacianKernel,
                      LinearKernel, Matern32Kernel, PolynomialKernel,
                      available_kernels, make_kernel, spec_of)
from .matvec import (knm_apply, knm_matvec, make_distributed_matvec,
                     streaming_knm_apply, streaming_knm_matvec)
from .nystrom import (NystromCenters, approximate_leverage_scores,
                      exact_leverage_scores, leverage_score_centers,
                      select_centers, uniform_centers)
from .preconditioner import Preconditioner, make_preconditioner
