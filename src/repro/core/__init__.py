"""FALKON core — the paper's primary contribution as a composable JAX module.

Public API:
    FalkonConfig, falkon_fit, falkon_solve, FalkonEstimator
    falkon_fit_streaming, falkon_solve_streaming   (out-of-core n)
    falkon_fit_path, falkon_solve_path, FalkonPathResult,
    falkon_fit_path_streaming, falkon_solve_path_streaming
        (lam-path: one data sweep serves every regularizer)
    falkon_fit_minibatch, falkon_fit_minibatch_streaming,
    MinibatchConfig, MinibatchResult, MinibatchState,
    minibatch_solve, minibatch_solve_stream
        (delayed-projection stochastic solve; FalkonEstimator.partial_fit
        warm-starts it from a deployed model at chunk-sweep cost)
    make_preconditioner, Preconditioner
    make_preconditioner_path, PreconditionerPath   (batched (L,q,q) A stack)
    conjugate_gradient, conjugate_gradient_host
    select_centers, uniform_centers, leverage_score_centers,
    approximate_leverage_scores, exact_leverage_scores,
    build_leverage_pilot, leverage_scores_from_pilot,
    approximate_leverage_scores_path               (shared pilot-Gram build)
    make_kernel, KernelSpec, spec_of, GaussianKernel, LaplacianKernel,
    Matern32Kernel, LinearKernel, PolynomialKernel
    knm_matvec, knm_apply,
    streaming_knm_matvec, streaming_knm_apply        (KernelOps delegates)
    make_knm_cache, cached_knm_matvec, cached_knm_apply
        (materialized-K_nM cache: kernel entries evaluated once, every
        later matvec/apply a GEMM — FalkonConfig(knm_cache=...) is the
        fit-level route)
    (the distributed sweep is a backend now: ``repro.ops.DistributedOps``,
    selected via ``FalkonConfig(mesh=..., data_axes=...)``)
    baselines: krr_direct, krr_gradient, nystrom_direct, nystrom_gradient

Kernel compute is pluggable: the ``repro.ops`` KernelOps registry ("jnp"
reference / "pallas" fused) backs every sweep, apply and gram above.
"""
from .baselines import (krr_direct, krr_gradient, nystrom_direct, nystrom_gradient)
from .cg import (
    CGResult, active_columns, col_dot, conjugate_gradient, conjugate_gradient_host
)
from .falkon import (
    FalkonConfig,
    FalkonEstimator,
    FalkonPathResult,
    FalkonPathState,
    FalkonState,
    falkon_fit,
    falkon_fit_minibatch,
    falkon_fit_minibatch_streaming,
    falkon_fit_path,
    falkon_fit_path_streaming,
    falkon_fit_streaming,
    falkon_solve,
    falkon_solve_path,
    falkon_solve_path_streaming,
    falkon_solve_streaming,
)
from .minibatch import (
    MinibatchConfig,
    MinibatchResult,
    MinibatchState,
    minibatch_solve,
    minibatch_solve_stream,
)
from .kernels import (
    GaussianKernel,
    KernelFn,
    KernelSpec,
    LaplacianKernel,
    LinearKernel,
    Matern32Kernel,
    PolynomialKernel,
    available_kernels,
    make_kernel,
    spec_of,
)
from .matvec import (
    cached_knm_apply,
    cached_knm_matvec,
    knm_apply,
    knm_matvec,
    make_knm_cache,
    streaming_knm_apply,
    streaming_knm_matvec,
)
from .nystrom import (
    LeveragePilot,
    NystromCenters,
    approximate_leverage_scores,
    approximate_leverage_scores_path,
    build_leverage_pilot,
    exact_leverage_scores,
    leverage_score_centers,
    leverage_scores_from_pilot,
    select_centers,
    uniform_centers,
)
from .preconditioner import (
    Preconditioner, PreconditionerPath, make_preconditioner, make_preconditioner_path
)
