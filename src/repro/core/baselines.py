"""Baselines the paper compares against (Table 1 / Sect. 2).

* ``krr_direct``      — exact KRR, O(n^3) direct solve of (K_nn + lam n I) a = y.
* ``krr_gradient``    — Eq. (6) gradient iteration on the exact problem.
* ``nystrom_direct``  — basic Nystrom (Eq. 8), direct solve of H a = z.
* ``nystrom_gradient``— NYTRO-style [23]: gradient iteration on the Nystrom
                        problem *without* FALKON's preconditioner (what FALKON's
                        conditioning analysis beats).

All return a predictor ``f(X) -> yhat`` plus coefficients, and are used by the
Table 1/2/3 benchmarks and by tests as ground truth.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import KernelFn
from .matvec import knm_apply, knm_matvec

Array = jax.Array


class KernelPredictor(NamedTuple):
    centers: Array
    alpha: Array
    kernel: KernelFn

    def predict(self, X: Array, block_size: int = 2048) -> Array:
        return knm_apply(
            X, self.centers, self.alpha, self.kernel, block_size=block_size
        )


def krr_direct(X: Array, y: Array, kernel: KernelFn, lam: float) -> KernelPredictor:
    n = X.shape[0]
    Knn = kernel(X, X)
    alpha = jnp.linalg.solve(Knn + lam * n * jnp.eye(n, dtype=X.dtype), y)
    return KernelPredictor(centers=X, alpha=alpha, kernel=kernel)


def krr_gradient(
    X: Array, y: Array, kernel: KernelFn, lam: float, t: int, tau: float | None = None
) -> KernelPredictor:
    """Eq. (6): a_{k} = a_{k-1} - tau/n [ (K a - y) + lam n a ]."""
    n = X.shape[0]
    Knn = kernel(X, X)
    if tau is None:
        # ||Knn||/n + lam bounds the operator's largest eigenvalue
        op_norm = jnp.linalg.norm(Knn, ord=2) / n + lam
        tau = 1.0 / op_norm

    def step(a, _):
        grad = (Knn @ a - y) / n + lam * a
        return a - tau * grad, None

    a, _ = jax.lax.scan(step, jnp.zeros_like(y), None, length=t)
    return KernelPredictor(centers=X, alpha=a, kernel=kernel)


def nystrom_direct(
    X: Array,
    y: Array,
    centers: Array,
    kernel: KernelFn,
    lam: float,
    jitter: float = 1e-9,
) -> KernelPredictor:
    """Eq. (8): (K_nM^T K_nM + lam n K_MM) a = K_nM^T y, dense direct solve."""
    n = X.shape[0]
    KnM = kernel(X, centers)
    KMM = kernel(centers, centers)
    H = KnM.T @ KnM + lam * n * KMM
    H = H + jitter * jnp.trace(H) / H.shape[0] * jnp.eye(H.shape[0], dtype=X.dtype)
    z = KnM.T @ y
    # LU, not Cholesky: H has a large dynamic range and fp32 chol can fail
    # even though H is PSD in exact arithmetic.
    alpha = jnp.linalg.solve(H, z)
    return KernelPredictor(centers=centers, alpha=alpha, kernel=kernel)


def nystrom_gradient(
    X: Array,
    y: Array,
    centers: Array,
    kernel: KernelFn,
    lam: float,
    t: int,
    block_size: int = 2048,
) -> KernelPredictor:
    """NYTRO-like: plain gradient descent on the (unpreconditioned) Nystrom
    objective. Needs O(cond(H)) iterations — the gap FALKON closes."""
    n = X.shape[0]
    M = centers.shape[0]
    KMM = kernel(centers, centers)
    # crude step size from H's norm upper bound
    KnM_norm_sq = knm_matvec(
        X, centers, jnp.ones((M,), X.dtype) / M, None, kernel, block_size=block_size
    )
    op_bound = jnp.linalg.norm(KnM_norm_sq) * M / n + lam * jnp.linalg.norm(KMM, ord=2)
    tau = 1.0 / jnp.maximum(op_bound, 1e-30)

    def step(a, _):
        Ha = knm_matvec(X, centers, a, None, kernel, block_size=block_size) / n \
            + lam * (KMM @ a)
        z = knm_matvec(
            X, centers, jnp.zeros_like(a), y, kernel, block_size=block_size
        ) / n
        return a - tau * (Ha - z), None

    a, _ = jax.lax.scan(step, jnp.zeros((M,) + y.shape[1:], X.dtype), None, length=t)
    return KernelPredictor(centers=centers, alpha=a, kernel=kernel)
