from .optimizers import (
    adafactor,
    adamw,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
    sgdm,
    warmup_cosine,
)
