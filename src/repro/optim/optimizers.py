"""Optimizers (optax-like, self-contained): AdamW, Adafactor, SGD-momentum.

State trees mirror the param tree, so optimizer state inherits the params'
PartitionSpecs (ZeRO-style: sharded states come for free). Adafactor keeps
row/col second-moment factors — the sublinear-memory choice for the >=70B
assigned archs.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Array], tuple[Any, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def global_norm(tree) -> Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


def adamw(
    b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, state_dtype=jnp.float32
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        step = state["step"] + 1
        bc1 = 1.0 - b1**step.astype(jnp.float32)
        bc2 = 1.0 - b2**step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(state_dtype)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(state_dtype)
            return (p.astype(state_dtype) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_p = jax.tree.map(
            lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_m = jax.tree.map(
            lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_v = jax.tree.map(
            lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        return new_p, {"mu": new_m, "nu": new_v, "step": step}

    return Optimizer(init, update)


def adafactor(eps=1e-30, clip_threshold=1.0, decay=0.8, weight_decay=0.0) -> Optimizer:
    """Factored second moments for >=2D params (rows+cols), full for 1D —
    O(n+m) state instead of O(nm) for matrices (Shazeer & Stern 2018)."""
    def init(params):
        def f(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(f, params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        beta = 1.0 - step.astype(jnp.float32) ** -decay

        def upd(g, s, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = g32 / jnp.sqrt((vr / denom)[..., None] * vc[..., None, :] + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 / jnp.sqrt(v + eps)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            delta = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_s

        out = jax.tree.map(
            upd,
            grads,
            state["f"],
            params,
            is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x),
        )
        is_pair = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        new_s = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return new_p, {"f": new_s, "step": step}

    return Optimizer(init, update)


def sgdm(momentum=0.9, weight_decay=0.0) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32) + weight_decay * p.astype(
                jnp.float32
            )
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m
        out = jax.tree.map(upd, grads, state["mu"], params)
        is_pair = lambda x: isinstance(x, tuple)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return new_p, {"mu": new_m, "step": state["step"] + 1}

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[name](**kw)


# -- schedules ---------------------------------------------------------------
def warmup_cosine(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr
