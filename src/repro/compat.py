"""JAX API compatibility shims (repo pin: jax==0.4.37).

JAX moves fast; these wrappers give tests/benchmarks one stable import for
APIs that have migrated across versions:

* ``enable_x64``  — ``jax.enable_x64`` (newer) -> ``jax.experimental.enable_x64``
                    (0.4.x). Context manager: ``with enable_x64(True): ...``
* ``use_mesh``    — ``jax.sharding.use_mesh`` -> ``jax.set_mesh`` ->
                    entering the ``Mesh`` object itself (0.4.x context
                    manager). Context manager: ``with use_mesh(mesh): ...``
* ``shard_map``   — ``jax.shard_map`` (newer) ->
                    ``jax.experimental.shard_map.shard_map`` (0.4.x), with
                    the replication-check kwarg (``check_rep`` ->
                    ``check_vma`` rename) normalized away. This is the one
                    entry point the distributed KernelOps backend uses.
"""
from __future__ import annotations

import inspect

import jax


def enable_x64(new_val: bool = True):
    """Context manager enabling (or disabling) 64-bit types."""
    fn = getattr(jax, "enable_x64", None)
    if fn is not None:
        return fn(new_val)
    from jax.experimental import enable_x64 as _enable_x64
    return _enable_x64(new_val)


def use_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh."""
    fn = getattr(jax.sharding, "use_mesh", None)
    if fn is None:
        fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh  # jax.sharding.Mesh is its own context manager on 0.4.x


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map``/``jax.experimental.shard_map.shard_map`` across
    jax versions (the module moved out of experimental after 0.4.x).

    The per-shard functions this repo maps contain ``psum`` reductions whose
    replication the static checker cannot always prove (the pre-refactor
    wrapper already ran ``check_rep=False``), so the check is disabled under
    whichever keyword spelling this jax uses (``check_rep`` on 0.4.x,
    ``check_vma`` after the rename, or neither).
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    kwargs = {}
    for kw in ("check_rep", "check_vma"):
        if _accepts_kwarg(fn, kw):
            kwargs[kw] = False
            break
    # A genuine TypeError from the call (bad specs, wrong arity) propagates
    # untouched — the kwarg was chosen by signature, not by probing.
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def _accepts_kwarg(fn, name: str) -> bool:
    """True if ``fn``'s signature names ``name`` as an explicit keyword (a
    bare ``**kwargs`` does NOT count — passing the wrong rename through it
    would fail later, far from here)."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False
    p = params.get(name)
    return p is not None and p.kind in (
        inspect.Parameter.POSITIONAL_OR_KEYWORD, inspect.Parameter.KEYWORD_ONLY
    )


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict across JAX versions.

    Older versions returned a per-program list of dicts (often length 1);
    newer ones return the dict directly (or None for trivial programs).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
