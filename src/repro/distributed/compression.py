"""Gradient compression: int8 symmetric quantization with error feedback.

For cross-pod gradient reduction the wire format matters: bf16 gradients at
~400GB/step (kimi) over ~50 GB/s ICI links dominate step time on the "pod"
axis. int8 + per-tensor scale halves the bytes; the error-feedback residual
(Karimireddy et al. 2019) keeps SGD convergence unbiased in the long run.

Implementation note: expressed as quantize -> psum -> dequantize around the
data/pod-axis mean so XLA moves int8 (not bf16) over the slow axis. Applied
optionally in train_step (cfg/train flag); numerics covered by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_int8(x: Array) -> tuple[Array, Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads, residuals):
    """Error-feedback compress: g' = Q(g + r); r' = (g + r) - deQ(g')."""
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        q, s = quantize_int8(acc)
        deq = dequantize_int8(q, s)
        return (q, s), acc - deq

    pairs = jax.tree.map(one, grads, residuals)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
    qs = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
    new_res = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
    return qs, new_res


def decompress_tree(qs, dtype=jnp.float32):
    is_q = lambda x: isinstance(x, tuple) and len(x) == 2
    return jax.tree.map(lambda t: dequantize_int8(t[0], t[1], dtype), qs, is_leaf=is_q)


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grads(grads, residuals, dtype=jnp.float32):
    """Round-trip compress/decompress with error feedback (the psum itself is
    inserted by pjit around the loss mean; this bounds the wire precision)."""
    qs, new_res = compress_tree(grads, residuals)
    return decompress_tree(qs, dtype), new_res
