"""Logical-axis sharding rules with divisibility fallback.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"ff", ...). The rules engine maps each logical axis to mesh axes, checking
divisibility of the actual dim against the mesh axis size and *degrading to
replication* when it does not divide (e.g. gemma3-1b's 4 query heads on a
16-way model axis). This is what makes one model codebase serve all 10
assigned architectures on the production mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

# logical axis -> candidate mesh axes, tried in order; tuple entries mean
# "shard over the product of these axes" (e.g. batch over pod+data).
DEFAULT_RULES: dict[str, tuple] = {
    "batch":    (("pod", "data"), ("data",)),
    "fsdp":     (("pod", "data"), ("data",)),  # param dims when cfg.fsdp
    "heads":    (("model",),),
    "kv_heads": (("model",),),
    "ff":       (("model",),),
    "experts":  (("model",),),
    "vocab":    (("model",),),
    "embed":    (),                      # replicated (FSDP overrides below)
    "seq":      (),                      # replicated in training activations
    "kv_seq":   (("model",),),           # decode cache seq (flash-decoding)
    "cache_seq": (("data", "model"), ("model",),),  # long-context cache
    # capacity dim: when the expert dim itself can't shard (e.g. 40 experts
    # on a 16-way model axis) the capacity dim absorbs the model axis too.
    "expert_cap": (("pod", "data", "model"), ("data", "model"),
                   ("pod", "data"), ("data",)),
    "conv":     (),
    "state":    (),
}

# FSDP mode additionally shards "embed"-tagged *parameter* dims over data
# (activations never get it: their batch dim claims the data axes first).
FSDP_EXTRA: dict[str, tuple] = {
    "embed": (("pod", "data"), ("data",)),
}

# Resolution priority: lower resolves first (greedy mesh-axis allocation).
_PRIORITY = {
    "batch": 0,
    "heads": 1,
    "kv_heads": 1,
    "ff": 1,
    "experts": 1,
    "vocab": 1,
    "kv_seq": 2,
    "cache_seq": 2,
    "expert_cap": 2,
    "fsdp": 3,
    "embed": 4,
}


@dataclasses.dataclass(frozen=True)
class AxisRules:
    mesh: Mesh | None
    rules: dict[str, tuple] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES)
    )
    fsdp: bool = False

    def axis_size(self, names: Sequence[str]) -> int:
        s = 1
        for nm in names:
            s *= self.mesh.shape[nm]
        return s

    def spec_for(self, dims: Sequence[int], axes: Sequence[str | None]) -> P:
        """Resolve logical axes to a PartitionSpec.

        Dims resolve in priority order (model-parallel dims before fallback
        dims) with greedy mesh-axis allocation; a dim that does not divide the
        mesh extent is replicated — the divisibility fallback."""
        if self.mesh is None:
            return P()
        assert len(dims) == len(axes), (dims, axes)
        rules = dict(self.rules)
        if self.fsdp:
            for k, v in FSDP_EXTRA.items():
                rules[k] = v + rules.get(k, ())
        order = sorted(range(len(dims)), key=lambda i: _PRIORITY.get(axes[i] or "", 9))
        used: set[str] = set()
        out: list = [None] * len(dims)
        for i in order:
            dim, name = dims[i], axes[i]
            if name is None:
                continue
            if name in ("fsdp",) and not self.fsdp:
                continue
            for cand in rules.get(name, ()):
                cand = tuple(a for a in cand if a in self.mesh.shape)
                if not cand or any(a in used for a in cand):
                    continue
                if dim % self.axis_size(cand) == 0:
                    used.update(cand)
                    out[i] = cand if len(cand) > 1 else cand[0]
                    break
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding_for(self, dims, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(dims, axes))


_local = threading.local()


def current_rules() -> AxisRules:
    return getattr(_local, "rules", AxisRules(mesh=None))


@contextlib.contextmanager
def use_rules(rules: AxisRules):
    prev = getattr(_local, "rules", None)
    _local.rules = rules
    try:
        yield rules
    finally:
        if prev is None:
            del _local.rules
        else:
            _local.rules = prev


def lshard(x: Array, axes: Sequence[str | None]) -> Array:
    """Annotate x with logical axes; no-op when no mesh rules are active."""
    rules = current_rules()
    if rules.mesh is None:
        return x
    spec = rules.spec_for(x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
