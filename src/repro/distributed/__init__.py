from .mesh import AxisRules, current_rules, data_axes, lshard, use_rules
