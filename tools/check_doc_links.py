"""Gate: every internal markdown link in the docs resolves.

Scans ``README.md`` and ``docs/**/*.md`` for inline links/images
``[text](target)`` and checks, for every *internal* target, that

* a relative file path exists on disk (resolved against the linking file),
* a ``#fragment`` names a real heading in the target file, using GitHub's
  slug rules (lowercase, punctuation stripped, spaces -> hyphens,
  ``-<n>`` suffixes for duplicates).

External targets (``http(s)://``, ``mailto:``) and relative paths that
escape the repository root (GitHub web paths like the CI badge's
``../../actions/...``) are skipped — this gate is about the docs being
internally navigable from a checkout, nothing more. No dependencies
beyond the stdlib; CI runs it from the lint job:

    python tools/check_doc_links.py
"""
from __future__ import annotations

import pathlib
import re
import sys
import urllib.parse

ROOT = pathlib.Path(__file__).resolve().parent.parent

#: inline links and images; ignores fenced/inline code by construction of
#: the docs (no link syntax inside code spans there) — good enough for a
#: lint gate, and false positives fail loudly with file:line to fix.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")
_CODESPAN = re.compile(r"`[^`]*`")


def _slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->hyphens."""
    text = _CODESPAN.sub(lambda m: m.group(0).strip("`"), heading.strip())
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(md_path: pathlib.Path) -> set[str]:
    anchors: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match (line)
        if not m:
            continue
        base = _slug(m.group(1))
        n = counts.get(base, 0)
        counts[base] = n + 1
        anchors.add(base if n == 0 else f"{base}-{n}")
    return anchors


def _check_file(md_path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    text = md_path.read_text(encoding="utf-8")
    for lineno, line in enumerate(text.splitlines(), start=1):
        for m in _LINK.finditer(line):
            target = m.group(1)
            parsed = urllib.parse.urlsplit(target)
            if parsed.scheme or parsed.netloc:
                continue                      # external URL
            path_part, fragment = parsed.path, parsed.fragment
            where = f"{md_path.relative_to(ROOT)}:{lineno}"
            if path_part:
                dest = (md_path.parent / urllib.parse.unquote(path_part))
                dest = dest.resolve()
                if not dest.is_relative_to(ROOT):
                    continue                  # GitHub web path (e.g. badge)
                if not dest.exists():
                    errors.append(f"{where}: broken link -> {target}")
                    continue
            else:
                dest = md_path                # same-file #fragment
            if fragment:
                if dest.suffix.lower() != ".md" or not dest.is_file():
                    continue                  # fragments into non-md: skip
                if fragment.lower() not in _anchors(dest):
                    errors.append(
                        f"{where}: missing anchor #{fragment} in "
                        f"{dest.relative_to(ROOT)}")
    return errors


def main() -> int:
    files = sorted((ROOT / "docs").rglob("*.md")) + [ROOT / "README.md"]
    files = [f for f in files if f.is_file()]
    errors: list[str] = []
    links = 0
    for f in files:
        links += len(_LINK.findall(f.read_text(encoding="utf-8")))
        errors.extend(_check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {links} links across {len(files)} files: " f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
