"""Quickstart: fit FALKON on a synthetic regression problem in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import FalkonConfig, falkon_fit, krr_direct


def main():
    # data: y = sin(<w, x>) + noise
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    n, d = 8_000, 10
    X = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (d,))
    y = jnp.sin(X @ w) + 0.1 * jax.random.normal(k3, (n,))
    Xtr, ytr, Xte, yte = X[:6000], y[:6000], X[6000:], y[6000:]

    # paper hyperparameters: lam = 1/sqrt(n), M = O(sqrt(n)), t = O(log n)
    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", 3.0),),
        lam=float(1 / jnp.sqrt(len(Xtr))),
        num_centers=300,
        iterations=15,
    )
    est, state = falkon_fit(jax.random.PRNGKey(1), Xtr, ytr, cfg)

    mse = float(jnp.mean((est.predict(Xte) - yte) ** 2))
    print(f"FALKON   test MSE: {mse:.4f}   cond(W)={float(state.cond_estimate):.1f}"
          f"   CG residual={float(state.residual_norms[-1]):.2e}")

    # exact KRR reference on a subsample (O(n^3) — keep it small)
    kr = krr_direct(Xtr[:2000], ytr[:2000], cfg.make_kernel(), cfg.lam)
    mse_kr = float(jnp.mean((kr.predict(Xte) - yte) ** 2))
    print(f"exact KRR (n=2000) test MSE: {mse_kr:.4f}")


if __name__ == "__main__":
    main()
