"""FALKON at 'large' scale with the Pallas hot loop and a device mesh.

    PYTHONPATH=src python examples/falkon_large_scale.py [--n 100000]
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/falkon_large_scale.py --mesh 4x2

Demonstrates the paper's headline setting (n in the 10^5-10^6 range, M ~ sqrt
n) end to end: uniform Nystrom centers, Cholesky preconditioner, blocked CG
sweeps — optionally routed through the fused Pallas kernel (interpret mode
on CPU). Data-parallelism is one config field: ``FalkonConfig(mesh=...)``
wraps whichever backend is selected in ``repro.ops.DistributedOps``, which
shard_maps every sweep row-wise over the mesh data axes (one (M, p) psum
per CG iteration — see docs/architecture.md for the comm model and the
rest of the subsystem map).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import FalkonConfig, falkon_fit
from repro.data.synthetic import KernelTask, make_kernel_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--d", type=int, default=20)
    ap.add_argument("--centers", type=int, default=0, help="0 = 3*sqrt(n)")
    ap.add_argument("--iters", type=int, default=15)
    ap.add_argument("--mesh", default=None, help="e.g. 8 or 4x2")
    ap.add_argument(
        "--pallas",
        action="store_true",
        help="use the fused single-pass Pallas sweep backend",
    )
    ap.add_argument(
        "--precision",
        default="fp32",
        choices=("fp32", "bf16"),
        help="bf16 = bf16 inputs / fp32 accumulation",
    )
    args = ap.parse_args()

    n = args.n
    M = args.centers or int(3 * n**0.5)
    task = KernelTask(
        "big", n=n, d=args.d, task="regression", sigma=4.0, lam=0.0, num_centers=0
    )
    X, y = make_kernel_dataset(jax.random.PRNGKey(0), task)
    Xte, yte = make_kernel_dataset(jax.random.PRNGKey(1), task, n=5000)

    mesh = None
    data_axes = ("data",)
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(dims)]
        mesh = jax.make_mesh(dims, axes)
        data_axes = axes[:1]
        print(f"mesh: {dict(zip(axes, dims))} over {len(jax.devices())} devices")

    cfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", 4.0),),
        lam=float(1 / n**0.5),
        num_centers=M,
        iterations=args.iters,
        block_size=4096,
        ops_impl="pallas" if args.pallas else "jnp",
        precision=args.precision,
        mesh=mesh,
        data_axes=data_axes,
    )
    print(f"n={n} d={args.d} M={M} t={args.iters} lam={cfg.lam:.2e} "
          f"impl={cfg.impl} precision={cfg.precision}")
    t0 = time.perf_counter()
    est, state = falkon_fit(jax.random.PRNGKey(2), X, y, cfg)
    jax.block_until_ready(est.alpha)
    dt = time.perf_counter() - t0
    mse = float(jnp.mean((est.predict(Xte) - yte) ** 2))
    print(f"fit in {dt:.1f}s; test MSE {mse:.4f}; "
          f"cond(W)={float(state.cond_estimate):.1f}; "
          f"final CG residual {float(state.residual_norms[-1]):.2e}")


if __name__ == "__main__":
    main()
