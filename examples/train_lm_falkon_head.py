"""End-to-end driver: train a ~100M-param LM for a few hundred steps, then fit
a FALKON head on its features (the paper's IMAGENET recipe: kernel method on
frozen deep features).

    PYTHONPATH=src python examples/train_lm_falkon_head.py [--steps 300]

Uses the full production substrate: Trainer (checkpoint/restart, straggler
monitor), the synthetic token pipeline, and the FALKON core as the adaptation
head. CPU-sized by default (a ~10M reduced config); pass --d-model 768
--layers 12 for the true ~100M run if you have the patience.
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import FalkonConfig, falkon_fit
from repro.data import TokenStreamConfig, token_stream
from repro.models.model import _backbone
from repro.train import TrainConfig, Trainer, TrainerConfig


def make_lm(d_model: int, layers: int, vocab: int) -> ModelConfig:
    return ModelConfig(
        name=f"lm-{d_model}x{layers}",
        family="dense",
        n_layers=layers,
        d_model=d_model,
        n_heads=max(4, d_model // 64),
        n_kv_heads=max(2, d_model // 128),
        d_head=64,
        d_ff=4 * d_model,
        vocab=vocab,
        vocab_pad_multiple=64,
        dtype="float32",
        remat="none",
        dense_attn_max_seq=4096,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    vocab = 512
    cfg = make_lm(args.d_model, args.layers, vocab)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  ({n_params/1e6:.1f}M params)")

    tcfg = TrainConfig(learning_rate=3e-4, warmup_steps=20, total_steps=args.steps)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        rcfg = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=100)
        trainer = Trainer(cfg, tcfg, rcfg)
        stream = token_stream(
            TokenStreamConfig(vocab=vocab, seq_len=args.seq, batch=args.batch)
        )
        hist = trainer.fit(stream, steps=args.steps)
        first, last = hist[0]["loss"], hist[-1]["loss"]
        print(f"train loss: {first:.3f} -> {last:.3f} over {len(hist)} steps "
              f"({len(trainer.straggler_events)} straggler events)")
        assert last < first, "LM did not learn"
        params = trainer.state.params

    # ---- FALKON head on frozen features (paper Sect. 5, IMAGENET setup) ----
    # task: predict next-token top-class family from the hidden state.
    stream = token_stream(
        TokenStreamConfig(vocab=vocab, seq_len=args.seq, batch=args.batch), seed=7
    )
    feats, targets = [], []
    for _ in range(8):
        b = next(stream)
        h = _backbone(params, cfg, {"tokens": b["tokens"]})  # (B,S,D)
        feats.append(h.reshape(-1, cfg.d_model))
        targets.append((b["tokens"] % 8).reshape(-1))        # 8-way task
    X = jnp.concatenate(feats)
    ylab = jnp.concatenate(targets)
    Y = jax.nn.one_hot(ylab, 8)
    ntr = int(0.8 * X.shape[0])

    fcfg = FalkonConfig(
        kernel="gaussian",
        kernel_params=(("sigma", 4.0),),
        lam=1e-6,
        num_centers=512,
        iterations=15,
    )
    est, state = falkon_fit(jax.random.PRNGKey(0), X[:ntr], Y[:ntr], fcfg)
    pred = jnp.argmax(est.predict(X[ntr:]), -1)
    acc = float(jnp.mean(pred == ylab[ntr:]))
    print(f"FALKON head: {acc*100:.1f}% acc on 8-way feature task "
          f"(chance 12.5%), cond(W)={float(state.cond_estimate):.1f}")
    assert acc > 0.2


if __name__ == "__main__":
    main()
