"""Serve a small model with batched requests: prefill + decode loop.

    PYTHONPATH=src python examples/serve_batched.py [--arch gemma3-1b]

Uses the reduced config of any assigned architecture (prefill builds the KV /
SSM caches, decode_step generates token-by-token for the whole batch). Shows
hybrid/SSM caches working identically to attention caches through one API.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, reduced_config
from repro.models import decode_step, model_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    import dataclasses
    cfg = reduced_config(args.arch)
    if cfg.frontend == "embeds":
        cfg = dataclasses.replace(cfg, frontend="tokens")
    params = model_params(jax.random.PRNGKey(0), cfg)

    B, P, G = args.batch, args.prompt_len, args.gen
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.frontend == "tokens+vision":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_vision)
        ) * 0.05

    logits, cache = prefill(params, cfg, batch, S_max=P + G)
    print(f"{args.arch}: prefill of {B}x{P} tokens done "
          f"(cache pos={int(cache['pos'])})")

    step = jax.jit(lambda c, t: decode_step(params, cfg, c, {"token": t}))
    tok = jnp.argmax(logits, -1)
    generated = [tok]
    for _ in range(G - 1):
        logits, cache = step(cache, tok)
        tok = jnp.argmax(logits, -1)
        generated.append(tok)
    out = jnp.stack(generated, 1)
    assert out.shape == (B, G) and bool(jnp.all(out >= 0))
    print(f"generated {G} tokens per request; first row: " f"{out[0, :12].tolist()}...")


if __name__ == "__main__":
    main()
